"""Three-term roofline from a compiled dry-run artifact.

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * link_bw)

cost_analysis() reports per-device numbers for SPMD modules; collective bytes
are parsed from the optimized HLO text (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand sizes).
"""
from __future__ import annotations

import dataclasses
import re

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """'bf16[8,128]{1,0}' -> byte size; tuples handled by caller."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.groups()
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum of *output* shape bytes per collective kind in the optimized HLO.

    Output bytes are the tensor sizes the collectives materialize; for
    all-reduce in/out match, for all-gather the output is the gathered size
    (an upper bound on per-device link traffic; consistent across variants).
    """
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        # Match 'x = TYPE[...] all-reduce(...)' & fused variants ('-start').
        m = re.match(r"^[%\w.\-]+\s*=\s*(\(?[\w\[\],{}\s]*\)?)\s*([\w-]+)", s)
        if not m:
            continue
        shape_str, op = m.groups()
        base = next((c for c in _COLLECTIVES if op == c or op == c + "-start"),
                    None)
        if base is None:
            continue
        out[base] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict[str, int]
    chips: int
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / self.peak_flops

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / self.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / self.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Roofline step time (no overlap assumed = worst case ... the
        overlap-optimistic bound is max(); we report both)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    def compute_fraction(self, model_flops_per_device: float) -> float:
        """MODEL_FLOPS / (step_time * peak): the roofline fraction score."""
        if self.step_time == 0:
            return 0.0
        return model_flops_per_device / (self.step_time * self.peak_flops)

    def as_dict(self) -> dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "coll_breakdown": {k: v for k, v in self.coll_breakdown.items() if v},
        }


# Which roof each measured engine phase is judged against: the exchange is
# wire traffic (ICI links); every other phase is host/device memory
# streaming (HBM). See benchmarks/phase_profile.py for the producer.
PHASE_ROOFS = {
    "map": "hbm", "encode": "hbm", "exchange": "ici",
    "decode": "hbm", "reduce": "hbm",
}


@dataclasses.dataclass(frozen=True)
class PhaseRoofline:
    """A measured phase (seconds + bytes moved) against its bandwidth roof.

    ``fraction`` is the %-of-roofline number: achieved bandwidth over the
    roof bandwidth, i.e. how close the measured phase runs to the best the
    bounding resource allows. Measured on CPU this is a *methodology*
    fidelity number (the roofs are the TPU v5e constants in launch/mesh.py);
    on real hardware the same spans produce the real figure.
    """

    phase: str
    seconds: float
    bytes_moved: float
    roof: str                    # "hbm" | "ici"
    chips: int = 1
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW

    @property
    def roof_bw(self) -> float:
        bw = self.hbm_bw if self.roof == "hbm" else self.ici_bw
        return bw * self.chips

    @property
    def achieved_bw(self) -> float:
        return self.bytes_moved / self.seconds if self.seconds > 0 else 0.0

    @property
    def roof_seconds(self) -> float:
        return self.bytes_moved / self.roof_bw

    @property
    def fraction(self) -> float:
        """Achieved / roof bandwidth (the %-of-roofline figure)."""
        return self.achieved_bw / self.roof_bw

    def as_dict(self) -> dict:
        return {"phase": self.phase, "seconds": self.seconds,
                "bytes_moved": self.bytes_moved, "roof": self.roof,
                "achieved_bw": self.achieved_bw,
                "roofline_fraction": self.fraction}


def phase_roofline(phase: str, seconds: float, bytes_moved: float, *,
                   chips: int = 1) -> PhaseRoofline:
    """Judge one measured phase against its roof (see `PHASE_ROOFS`)."""
    short = phase.split(".")[-1]
    if short not in PHASE_ROOFS:
        raise ValueError(
            f"unknown phase {phase!r}; known: {sorted(PHASE_ROOFS)}")
    return PhaseRoofline(short, seconds, bytes_moved, PHASE_ROOFS[short],
                         chips=chips)


def from_compiled(compiled, chips: int) -> Roofline:
    """Trip-aware terms from the optimized HLO (see hlo_analysis.py: XLA's
    cost_analysis counts scan bodies once, 24-62x off for deep stacks)."""
    from .hlo_analysis import analyze
    cost = analyze(compiled.as_text())
    breakdown = {k: int(v) for k, v in cost.coll_breakdown.items()}
    return Roofline(cost.flops, cost.bytes_accessed, cost.collective_bytes,
                    breakdown, chips)


def from_compiled_xla(compiled, chips: int) -> Roofline:
    """The raw (trip-blind) XLA numbers - kept for comparison/debugging."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):              # jax 0.4.x: one dict per computation
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    breakdown = collective_bytes(compiled.as_text())
    coll = float(sum(breakdown.values()))
    return Roofline(flops, byts, coll, breakdown, chips)
