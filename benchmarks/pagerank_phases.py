"""Paper Fig. 7 / Remark 10: per-phase execution model of coded PageRank.

Measures actual wall time of Map (kernelized SpMV) and Shuffle (bit volume /
modeled link bandwidth) per r, fits T(r) = r T_map + T_shuffle / r + T_red,
and reports the best r against the r* = sqrt(Ts/Tm) heuristic.

Also measures the compile-once/execute-many ShufflePlan engine against the
literal per-group reference on multi-iteration coded PageRank - the schedule
is fixed by (graph, allocation), so compiling it once and replaying packed
XOR arrays each iteration must beat re-deriving it every round."""
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.loads import optimal_r
from repro.core.shuffle_plan import compile_plan
from repro.kernels.spmv import ops as spmv_ops

# Modeled phase costs (deterministic; wall-clock interpret-mode timings vary
# 10x run-to-run on this CPU). Both constants model the paper's EC2 regime:
# Python-rate per-edge Map work and a Shuffle-dominant 100Mbps-class link
# scaled to the n=300 validation graph.
LINK_BYTES_PER_SEC = 1.25e5
PER_EDGE_MAP_S = 1e-5


def plan_vs_reference(report, smoke=False):
    """Compile-once/execute-many speedup on multi-iteration coded PageRank.

    Full size is the acceptance point (n=256 -> 360 after divisibility,
    K=10, r=3, 10 iterations); smoke shrinks everything so CI stays fast.
    Both paths are run end-to-end and must agree bit-for-bit on state and
    on shuffle bits - the speedup is only reported if they do.
    """
    if smoke:
        K, r, iters, n_req, p = 4, 2, 3, 40, 0.2
    else:
        K, r, iters, n_req, p = 10, 3, 10, 256, 0.05
    n = divisible_n(n_req, K, r)
    g = gm.erdos_renyi(n, p, seed=7)
    alloc = er_allocation(n, K, r)
    prog = algo.pagerank()

    with obs.stopwatch() as sw_ref:
        ref = engine.run(prog, g, alloc, iters, mode="coded-ref")
    t_ref = sw_ref.s

    with obs.stopwatch() as sw_compile:
        plan = compile_plan(g.adj, alloc)
    t_compile = sw_compile.s
    # A/B against the literal reference on the same dense Reduce, so the
    # speedup isolates the compiled Shuffle (the sparse Reduce is measured
    # separately below and in benchmarks/scale_sweep.py).
    with obs.stopwatch() as sw_plan:
        fast = engine.run(prog, g, alloc, iters, mode="coded", plan=plan,
                          path="dense")
    t_plan = sw_plan.s + t_compile

    assert np.array_equal(ref.state, fast.state), "plan diverged from reference"
    assert ref.shuffle_bits == fast.shuffle_bits, "plan load accounting diverged"
    speedup = t_ref / t_plan
    report(f"plan_coded_pagerank_{iters}it_n{n}_K{K}_r{r}", t_plan * 1e6,
           f"ref_s={t_ref:.3f} plan_s={t_plan:.3f} compile_s={t_compile:.3f} "
           f"speedup={speedup:.1f}x")

    with obs.stopwatch() as sw_sparse:
        sparse = engine.run(prog, g, alloc, iters, mode="coded", plan=plan)
    t_sparse = sw_sparse.s
    assert sparse.shuffle_bits == ref.shuffle_bits
    # Compare run time against run time (both reuse the same compiled plan).
    vs_dense = (t_plan - t_compile) / t_sparse
    report(f"plan_sparse_pagerank_{iters}it_n{n}_K{K}_r{r}", t_sparse * 1e6,
           f"sparse_s={t_sparse:.3f} vs_dense_plan={vs_dense:.1f}x")
    return {"n": n, "K": K, "r": r, "iters": iters, "t_ref_s": t_ref,
            "t_plan_s": t_plan, "t_compile_s": t_compile,
            "t_sparse_s": t_sparse, "speedup": speedup}


def run(report, smoke=False):
    plan_stats = plan_vs_reference(report, smoke=smoke)
    # The T(r) sweep runs on the sparse O(edges) engine path, so full mode
    # can afford n in the thousands (the paper's EC2 runs used n ~ 1e4).
    K, p = 5, 0.12
    n = divisible_n(60 if smoke else 2000, K, 2)
    g = gm.erdos_renyi(n, p, seed=3)
    prog = algo.pagerank()

    # Map phase: measure the kernelized SpMV (reported for reference), but
    # the T(r) model uses the deterministic per-edge cost above. The dense
    # interpret-mode kernel tile is capped at 512 vertices; t_map scales off
    # the real edge count.
    n_spmv = min(n, 512)
    adj = jnp.array(g.adj[:n_spmv, :n_spmv], jnp.float32)
    rank = jnp.array(prog.init(g)[:n_spmv])
    spmv_us = obs.timeit(
        lambda: spmv_ops.pagerank_step(adj, rank).block_until_ready(),
        reps=3, warmup=1)
    t_map1 = g.num_edges / K * PER_EDGE_MAP_S            # per-server share
    report("map_phase_spmv", spmv_us,
           f"n={n_spmv} modeled_t_map={t_map1:.4f}s")

    rows = []
    for r in range(1, K + 1):
        alloc = er_allocation(n, K, r)
        res = engine.run(prog, g, alloc, 1, mode="coded-fast")
        shuffle_bytes = res.shuffle_bits / 8
        t_shuffle = shuffle_bytes / LINK_BYTES_PER_SEC
        t_total = r * t_map1 + t_shuffle
        rows.append((r, t_total))
        report(f"fig7_total_r{r}", t_total * 1e6,
               f"shuffle_s={t_shuffle:.4f}")
    best_r = min(rows, key=lambda t: t[1])[0]
    alloc1 = er_allocation(n, K, 1)
    s1 = engine.run(prog, g, alloc1, 1, "uncoded").shuffle_bits / 8 / LINK_BYTES_PER_SEC
    r_star = optimal_r(t_map1, s1)
    report("remark10_r_star", 0.0,
           f"best_measured_r={best_r} r_star={r_star:.2f}")
    return {"best_r": best_r, "r_star": r_star, "plan": plan_stats}
