"""Observability: phase tracing, metrics, and shared benchmark timing.

Zero-dependency (stdlib-only) on purpose — ``core/`` imports this and
must stay importable without jax.  Three pieces:

* :mod:`repro.obs.trace` — nestable spans with a no-op disabled path,
  Chrome-trace/perfetto export, deterministic span trees.
* :mod:`repro.obs.metrics` — counters / gauges / fixed-bucket histograms
  with a Prometheus-text exporter; backs ``serve.ServeStats``.
* :mod:`repro.obs.bench` — the one warmup + R-reps timing helper all
  ``benchmarks/*.py`` records flow through.

Enable tracing either with ``REPRO_TRACE=1`` in the environment or
``obs.get_tracer().enable()`` at runtime.
"""
from .bench import Measurement, measure, stopwatch, timeit
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      default_latency_buckets, get_registry, set_registry)
from .trace import Span, Tracer, get_tracer, set_tracer

__all__ = [
    "Counter", "Gauge", "Histogram", "Measurement", "MetricsRegistry",
    "Span", "Tracer", "default_latency_buckets", "get_registry",
    "get_tracer", "measure", "set_registry", "set_tracer", "stopwatch",
    "timeit",
]
