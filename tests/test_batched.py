"""Batched multi-query parity: B payload columns on ONE coded Shuffle.

The schedule is value-agnostic, so batching must be a pure payload change:
B=1 batched is bitwise the unbatched path, column b of a B>1 run is bitwise
the standalone run of that query for exact programs (sssp - min reductions)
and within-ulp for float sums (pagerank), and `bits_sent` scales with B
only through payload width - the schedule (group count, slot layout,
leftovers) never changes. Covered per mode (coded / uncoded / coded-fast)
and backend (numpy / spmv in process; fused on 8 forced host devices in a
subprocess, same pattern as test_fused_sparse.py).
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.core.bitcodec import floats_to_words
from repro.core.shuffle_plan import compile_plan_csr

MODES = ("coded", "uncoded", "coded-fast")


def _case(n=60, K=4, r=2, p=0.15, seed=11):
    n = divisible_n(n, K, r)
    return graphs.erdos_renyi(n, p, seed=seed), er_allocation(n, K, r)


# ---------------------------------------------------------------------------
# Plan-executor level: [nnz, B] through the same schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("execute", ["execute_coded_sparse",
                                     "execute_uncoded_sparse",
                                     "execute_fast_sparse"])
def test_executor_batched_columns_bitwise_and_bits_scale(execute):
    g, alloc = _case()
    plan = compile_plan_csr(g.csr, alloc)
    tables = plan.edge_tables(g.csr, alloc)
    rng = np.random.default_rng(3)
    B = 4
    vals = rng.random((g.csr.nnz, B)).astype(np.float32)
    fn = getattr(plan, execute)
    rB = fn(vals, tables)
    assert rB.values.shape[1:] == (B,)
    assert rB.batch == B
    r0 = fn(vals[:, 0], tables)
    # B=1 parity: a batched run's column IS the unbatched run, bit for bit.
    assert np.array_equal(floats_to_words(rB.values[:, 0]),
                          floats_to_words(r0.values))
    for b in range(B):
        rb = fn(vals[:, b], tables)
        assert np.array_equal(floats_to_words(rB.values[:, b]),
                              floats_to_words(rb.values))
    # Payload-width-only bits scaling; per-query normalized load invariant.
    assert rB.bits_sent == B * r0.bits_sent
    assert rB.normalized_load == pytest.approx(r0.normalized_load)


def test_executor_batched_delivered_dict_refuses():
    g, alloc = _case()
    plan = compile_plan_csr(g.csr, alloc)
    tables = plan.edge_tables(g.csr, alloc)
    res = plan.execute_coded_sparse(
        np.ones((g.csr.nnz, 2), dtype=np.float32), tables)
    with pytest.raises(ValueError, match="batched"):
        res.delivered()


def test_segment_reduce_batched_columns_match_standalone():
    g, _ = _case()
    rng = np.random.default_rng(5)
    vals = rng.random((g.csr.nnz, 3)).astype(np.float32)
    for ufunc, ident in ((np.add, 0.0), (np.minimum, np.inf)):
        batched = algo.segment_reduce(ufunc, vals, g.csr.indptr, ident)
        for b in range(3):
            col = algo.segment_reduce(ufunc, vals[:, b], g.csr.indptr, ident)
            assert np.array_equal(batched[:, b], col)


# ---------------------------------------------------------------------------
# Engine level: multi_sssp / personalized_pagerank per mode and backend
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", MODES)
def test_multi_sssp_columns_bitwise_per_mode(mode):
    g, alloc = _case()
    roots = [0, 7, 19]
    sess = engine.compile(algo.multi_sssp(roots), g, alloc, mode)
    rB = sess.run(6)
    assert rB.state.shape == (g.n, len(roots))
    bits1 = None
    for b, s in enumerate(roots):
        r1 = engine.compile(algo.sssp(s), g, alloc, mode,
                            plan=sess.plan).run(6)
        assert np.array_equal(rB.state[:, b], r1.state), (mode, b)
        bits1 = r1.shuffle_bits
    assert rB.shuffle_bits == len(roots) * bits1
    assert rB.batch == len(roots)
    assert rB.normalized_load == pytest.approx(r1.normalized_load)


@pytest.mark.parametrize("mode", MODES)
def test_personalized_pagerank_columns_within_ulp_per_mode(mode):
    g, alloc = _case()
    rng = np.random.default_rng(9)
    prefs = rng.random((g.n, 3)).astype(np.float32)
    prefs /= prefs.sum(axis=0)
    rB = engine.compile(algo.personalized_pagerank(prefs),
                        g, alloc, mode).run(5)
    for b in range(3):
        r1 = engine.compile(algo.personalized_pagerank(prefs[:, b]),
                            g, alloc, mode).run(5)
        # Float sums: the per-column reduceat order is identical, so this
        # is within-ulp by construction (empirically bitwise on numpy).
        np.testing.assert_allclose(rB.state[:, b], r1.state[:, 0],
                                   rtol=1e-6, atol=1e-9)
    assert rB.shuffle_bits == 3 * r1.shuffle_bits


def test_b1_batched_sssp_bitwise_vs_current_unbatched_path():
    g, alloc = _case()
    for mode in MODES:
        rB = engine.compile(algo.multi_sssp([5]), g, alloc, mode).run(6)
        r1 = engine.compile(algo.sssp(5), g, alloc, mode).run(6)
        assert rB.state.shape == (g.n, 1)
        assert np.array_equal(rB.state[:, 0], r1.state)
        assert rB.shuffle_bits == r1.shuffle_bits


def test_spmv_backend_batched_ppr_matches_numpy_backend():
    g, alloc = _case()
    prefs = algo.uniform_prefs(g.n, B=3)
    prog = algo.personalized_pagerank(prefs)
    r_np = engine.compile(prog, g, alloc, "coded").run(4)
    r_sp = engine.compile(prog, g, alloc, "coded", backend="spmv",
                          bm=32).run(4)
    assert r_sp.state.shape == (g.n, 3)
    np.testing.assert_allclose(r_sp.state, r_np.state, rtol=1e-5, atol=1e-8)
    # spmv accounts schedule bits per payload column like the real movers.
    assert r_sp.shuffle_bits == r_np.shuffle_bits


def test_no_per_query_recompile_schedule_shared():
    g, alloc = _case()
    sess = engine.compile(algo.multi_sssp([0]), g, alloc, "coded")
    plan = sess.plan
    bits1 = sess.run(4).shuffle_bits
    for B in (2, 5):
        wide = sess.with_program(algo.multi_sssp(list(range(B))))
        assert wide.plan is plan            # same compiled schedule object
        assert wide.tables is sess.tables   # cached edge tables shared
        assert wide.run(4).shuffle_bits == B * bits1


def test_batched_programs_refuse_dense_path():
    g, alloc = _case()
    with pytest.raises(ValueError, match="sparse"):
        engine.compile(algo.multi_sssp([0, 1]), g, alloc, "coded",
                       path="dense").run(1)


def test_run_batch_validates_and_stacks():
    g, alloc = _case()
    sess = engine.compile(algo.multi_sssp([0]), g, alloc, "coded")
    with pytest.raises(ValueError, match=rf"n={g.n}"):
        sess.run_batch(np.zeros((3, 2), dtype=np.float32), 1)
    prog = algo.multi_sssp([0, 9])
    cols = list(prog.init(g).T)             # sequence-of-columns form
    r_seq = sess.with_program(prog).run_batch(cols, 5)
    r_arr = sess.with_program(prog).run_batch(prog.init(g), 5)
    assert np.array_equal(r_seq.state, r_arr.state)


def test_multi_sssp_and_ppr_validate_inputs():
    g, _ = _case()
    with pytest.raises(ValueError, match="at least one"):
        algo.multi_sssp([])
    with pytest.raises(ValueError, match="out of range"):
        algo.multi_sssp([0, g.n]).init(g)
    with pytest.raises(ValueError, match="n="):
        algo.personalized_pagerank(np.ones(7, dtype=np.float32)).init(g)


# ---------------------------------------------------------------------------
# xor_code batched-column route (jax on CPU, in process)
# ---------------------------------------------------------------------------

def test_xor_encode_columns_batched_payload_axis():
    import jax.numpy as jnp

    from repro.kernels.xor_code import ops as xops

    rng = np.random.default_rng(7)
    slot = rng.integers(0, 2**32, size=(37, 3, 4), dtype=np.uint32)
    out = np.asarray(xops.xor_encode_columns(jnp.asarray(slot),
                                             use_kernel=False))
    assert out.shape == (37, 4)
    for b in range(4):
        col = np.asarray(xops.xor_encode_columns(jnp.asarray(slot[:, :, b]),
                                                 use_kernel=False))
        assert np.array_equal(out[:, b], col)
    # Empty schedule stays shape-correct.
    empty = np.asarray(xops.xor_encode_columns(
        jnp.zeros((0, 3, 4), jnp.uint32), use_kernel=False))
    assert empty.shape == (0, 4)


# ---------------------------------------------------------------------------
# Fused multi-device exchange (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

SCRIPT_FUSED = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.core.bitcodec import floats_to_words
from repro.core.fused_shuffle import FusedSparseShuffle
from repro.core.shuffle_plan import compile_plan_csr

out = {}
n = divisible_n(48, 4, 2)
g = graphs.erdos_renyi(n, 0.2, seed=11)
alloc = er_allocation(n, 4, 2)
plan = compile_plan_csr(g.csr, alloc)
tables = plan.edge_tables(g.csr, alloc)
fx = FusedSparseShuffle(plan, g.csr, alloc)

rng = np.random.default_rng(2)
vals = rng.random((g.csr.nnz, 3)).astype(np.float32)

# Word-level: batched fused delivery vs the NumPy executor, bitwise, and
# vs its own unbatched route per column (B=1 parity included).
ref = plan.execute_coded_sparse(vals, tables)
res = fx.execute(vals)
out["words_bitwise"] = bool(np.array_equal(floats_to_words(ref.values),
                                           floats_to_words(res.values)))
out["bits_scale"] = bool(res.bits_sent == ref.bits_sent
                         and res.bits_sent
                         == 3 * fx.execute(vals[:, 0]).bits_sent)
percol = True
for b in range(3):
    r1 = fx.execute(vals[:, b])
    percol = percol and np.array_equal(floats_to_words(res.values[:, b]),
                                       floats_to_words(r1.values))
out["per_column_bitwise"] = bool(percol)

# Engine level: batched multi-root SSSP, fused == numpy == standalone runs.
roots = [0, 5, 11]
sess = engine.compile(algo.multi_sssp(roots), g, alloc, "coded",
                      backend="fused")
rB = sess.run(5)
rn = engine.compile(algo.multi_sssp(roots), g, alloc, "coded",
                    plan=plan).run(5)
out["engine_batched_bitwise"] = bool(np.array_equal(rB.state, rn.state))
standalone = True
for b, s in enumerate(roots):
    r1 = engine.compile(algo.sssp(s), g, alloc, "coded", plan=plan,
                        backend="fused").run(5)
    standalone = standalone and np.array_equal(rB.state[:, b], r1.state)
out["engine_columns_standalone"] = bool(standalone)
out["engine_bits_scale"] = bool(rB.shuffle_bits == 3 * r1.shuffle_bits)
print(json.dumps(out))
"""


def test_fused_batched_exchange_parity_on_8_host_devices():
    proc = subprocess.run([sys.executable, "-c", SCRIPT_FUSED],
                          capture_output=True, text=True, timeout=900,
                          env={"PYTHONPATH": "src",
                               "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-4000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["words_bitwise"]
    assert res["bits_scale"]
    assert res["per_column_bitwise"]
    assert res["engine_batched_bitwise"]
    assert res["engine_columns_standalone"]
    assert res["engine_bits_scale"]
