"""TPU-idiomatic fused coded Shuffle (DESIGN.md §3, 'fused' path).

The literal scheme multicasts per (r+1)-group columns one at a time - fine on
an Ethernet bus, wrong on an ICI torus. Here every server packs ALL its coded
columns (across all groups it serves) into one dense uint32 buffer and a
single jax.lax.all_gather moves every buffer to every server in one fused
collective; receivers slice their groups and XOR-strip locally (kernels/
xor_code). Bit volume on the wire equals the literal schedule's (padding
aside); latency collapses from O(#groups * #columns) transmissions to one
collective phase - this is the hardware adaptation of the paper's shared-bus
assumption.

Runs under shard_map on a ('servers',) mesh; devices = servers.
"""
from __future__ import annotations

import functools
import itertools

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from .allocation import Allocation
from .coded_shuffle import group_need
from .graph_models import Graph


def build_schedule(adj: np.ndarray, alloc: Allocation):
    """Static (graph-dependent, data-independent) coded schedule.

    For each server s: the list of (group, column, receiver->(i, j)) slots it
    encodes, padded to a common buffer length so the all_gather is dense.
    Returns numpy index tensors consumed by the jitted exchange.
    """
    K, r = alloc.K, alloc.r
    plans = {s: [] for s in range(K)}
    for S in itertools.combinations(range(K), r + 1):
        Z = {k: group_need(adj, alloc, S, k) for k in S}
        for s in S:
            receivers = [k for k in S if k != s]
            ncols = max((len(Z[k]) for k in receivers), default=0)
            for c in range(ncols):
                slot = {k: (int(Z[k][c][0]), int(Z[k][c][1]))
                        for k in receivers if c < len(Z[k])}
                plans[s].append((S, c, slot))
    width = max((len(p) for p in plans.values()), default=0)
    # Encode tensors: for slot t of server s, the XOR of values v[i,j] over
    # receivers. We express it as up-to-r (i, j) index pairs (-1 padded).
    enc_idx = np.full((K, width, r, 2), -1, dtype=np.int32)
    for s, plan in plans.items():
        for t, (S, c, slot) in enumerate(plan):
            for ri, (k, (i, j)) in enumerate(sorted(slot.items())):
                enc_idx[s, t, ri] = (i, j)
    # Decode map: receiver k strips every other member's value from the slot.
    # For each (sender s, slot t) useful to k: target (i, j) plus the strip
    # list; represent as target idx and r-1 strip idx pairs.
    dec = {k: [] for k in range(K)}
    for s, plan in plans.items():
        for t, (S, c, slot) in enumerate(plan):
            for k, (i, j) in slot.items():
                strips = [slot[k2] for k2 in slot if k2 != k]
                dec[k].append((s, t, (i, j), strips))
    dwidth = max((len(d) for d in dec.values()), default=0)
    dec_src = np.zeros((K, dwidth, 2), dtype=np.int32)       # (sender, slot)
    dec_tgt = np.full((K, dwidth, 2), -1, dtype=np.int32)    # (i, j)
    dec_strip = np.full((K, dwidth, r - 1, 2), -1, dtype=np.int32) \
        if r > 1 else np.zeros((K, dwidth, 0, 2), np.int32)
    for k, items in dec.items():
        for t, (s, slot_t, (i, j), strips) in enumerate(items):
            dec_src[k, t] = (s, slot_t)
            dec_tgt[k, t] = (i, j)
            for ri, (i2, j2) in enumerate(strips):
                dec_strip[k, t, ri] = (i2, j2)
    return enc_idx, dec_src, dec_tgt, dec_strip


def _as_words(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _as_floats(w):
    return jax.lax.bitcast_convert_type(w, jnp.float32)


def fused_exchange(values: jnp.ndarray, enc_idx, dec_src, dec_tgt, dec_strip,
                   mesh: Mesh):
    """One coded Shuffle as a single all_gather of packed XOR buffers.

    values [n, n] float32 (replicated Map output; each server only reads its
    own columns through the schedule indices). Returns [n, n] recovered
    missing values (0 where not delivered) - identical on every server.
    """
    words = _as_words(values)

    def per_server(enc_s, dec_src_s, dec_tgt_s, dec_strip_s):
        # enc_s [1, W, r, 2] on this shard.
        enc_s = enc_s[0]
        valid = enc_s[:, :, 0] >= 0
        vals = words[jnp.clip(enc_s[:, :, 0], 0), jnp.clip(enc_s[:, :, 1], 0)]
        buf = jnp.where(valid, vals, jnp.uint32(0))
        coded = jax.lax.reduce(buf, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        allbufs = jax.lax.all_gather(coded, "servers")       # [K, W]
        # Decode this server's targets.
        d_src, d_tgt, d_strip = dec_src_s[0], dec_tgt_s[0], dec_strip_s[0]
        got = allbufs[d_src[:, 0], d_src[:, 1]]
        sv = d_strip[:, :, 0] >= 0
        strip_vals = words[jnp.clip(d_strip[:, :, 0], 0),
                           jnp.clip(d_strip[:, :, 1], 0)]
        strip = jax.lax.reduce(jnp.where(sv, strip_vals, jnp.uint32(0)),
                               jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        rec = got ^ strip
        out = jnp.zeros(words.shape, jnp.uint32)
        tgt_ok = d_tgt[:, 0] >= 0
        out = out.at[jnp.clip(d_tgt[:, 0], 0),
                     jnp.clip(d_tgt[:, 1], 0)].set(
            jnp.where(tgt_ok, rec, jnp.uint32(0)))
        return jax.lax.psum(out, "servers")   # union of per-server recoveries

    f = jax.shard_map(per_server, mesh=mesh,
                      in_specs=(P("servers"), P("servers"), P("servers"),
                                P("servers")),
                      out_specs=P())
    out_words = f(jnp.asarray(enc_idx), jnp.asarray(dec_src),
                  jnp.asarray(dec_tgt), jnp.asarray(dec_strip))
    return _as_floats(out_words)


def run_fused(g: Graph, values: np.ndarray, alloc: Allocation, mesh: Mesh):
    """Convenience wrapper: schedule + exchange; returns recovered matrix."""
    sched = build_schedule(g.adj, alloc)
    return fused_exchange(jnp.asarray(values, jnp.float32), *sched, mesh=mesh)
