"""Coded PageRank with fault injection - the paper's EC2 experiment (SSVI)
re-created, plus the fault-tolerance story (DESIGN.md SS5).

Reproduces the shape of Fig. 7: total-time model T(r) = r*T_map + T_shuffle/r
fitted from measured per-phase loads, optimal r* = sqrt(T_shuffle/T_map)
(Remark 10), and a mid-run server failure that the r-fold Map redundancy
absorbs with zero re-Mapping. Runs on the sparse O(edges) engine path, so n
in the thousands is cheap - and still bit-exact against the oracle.

    PYTHONPATH=src python examples/coded_pagerank.py
"""
import numpy as np

from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.loads import optimal_r, total_time_model

K, p, iters = 6, 0.15, 3
n = divisible_n(1260, K, 3)
g = gm.erdos_renyi(n, p, seed=7)
prog = algo.pagerank()
oracle = algo.reference_run(prog, g, iters)

# ---- phase-time model (paper SSVI / Remark 10) ----
# Map time ~ r (each server Maps r*n/K vertices); Shuffle time ~ load.
alloc1 = er_allocation(n, K, 1)
base_shuffle = engine.compile(prog, g, alloc1,
                              "uncoded").run(1).normalized_load
t_map, t_shuffle = 1.0, base_shuffle / 0.01   # normalized units
print(f"T_map={t_map:.2f}  T_shuffle={t_shuffle:.2f}  "
      f"r* = sqrt(Ts/Tm) = {optimal_r(t_map, t_shuffle):.2f}\n")

print(f"{'r':>2} {'coded load':>11} {'T(r) model':>11}")
best = (None, float("inf"))
for r in range(1, K + 1):
    alloc = er_allocation(n, K, r)
    # Session per (graph, allocation): the plan compiles once here and is
    # replayed for every iteration of the run.
    res = engine.compile(prog, g, alloc, "coded-fast").run(iters)
    np.testing.assert_array_equal(res.state, oracle)
    t = total_time_model(r, t_map, res.normalized_load / 0.01, 0.1)
    if t < best[1]:
        best = (r, t)
    print(f"{r:2d} {res.normalized_load:11.4f} {t:11.2f}")
print(f"\nbest computation load r = {best[0]} (paper: 4-5 in its scenarios)")

# ---- mid-run failure ----
alloc = er_allocation(n, K, 2)
res, stats = faults.run_with_failure(prog, g, alloc, iters, failed=(3,),
                                     fail_at_iter=1)
np.testing.assert_array_equal(res.state, oracle)
print(f"\nserver 3 failed at iter 1: result still bit-exact; "
      f"re-Mapped vertices: {stats.remapped_vertices} (r=2 redundancy), "
      f"recovery bits: {stats.recovery_bits}")
