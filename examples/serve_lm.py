"""Serve a small model with batched requests: prompt cache-fill + greedy
decode, for one attention arch and one SSM arch (O(1)-state decode).

    PYTHONPATH=src python examples/serve_lm.py
"""
import jax
import numpy as np

from repro import configs
from repro.launch.serve import generate
from repro.models import transformer as tfm
from repro.models.layers import init_params

for arch in ("internlm2-20b", "mamba2-370m"):
    cfg = configs.get(arch).reduced()
    params = init_params(tfm.model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab)
    toks = generate(cfg, params, prompts, max_new=12)
    assert toks.shape == (4, 12) and (toks >= 0).all() and (toks < cfg.vocab).all()
    print(f"{arch:16s} batch=4 prompt=8 -> 12 new tokens per request")
    print("  sample:", toks[0].tolist())
print("\nbatched serving OK (lockstep decode; KV cache for attention, "
      "O(1) state for SSM).")
