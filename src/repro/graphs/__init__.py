"""CSR-native graph subsystem: O(edges) samplers + real-dataset ingestion.

This package is the production front door for graphs. Everything it
produces is a CSR-native `core.graph_models.Graph` - only (indptr, indices)
in memory - so the sparse engine path runs end to end at n >= 1e5 without
any [n, n] buffer ever being allocated (the dense view stays behind the
`DENSE_LIMIT` materialization guard; see `core.graph_models`).

  * `samplers`: streaming counterparts of the four dense reference samplers
    (ER via geometric edge-skipping, Chung-Lu power-law without the dense
    outer product, SBM/RB as per-block ER).
  * `io`: SNAP-style edge-list ingestion with a normalization pass (dedup,
    symmetrize, self-loop strip, contiguous relabel, optional largest
    connected component) plus the committed karate-club fixture.
  * `allocate`: pads an arbitrary-n graph with virtual isolated vertices to
    the allocation's divisibility requirement, so real datasets drop
    straight into the coded engine.
"""
from __future__ import annotations

from ..core.allocation import Allocation, er_allocation
from ..core.graph_models import Graph
from .delta import EdgeDelta
from .io import (fixture_path, load_fixture, load_graph, normalize_edges,
                 read_edge_list, write_edge_list)
from .samplers import (erdos_renyi, power_law, random_bipartite, sample,
                       stochastic_block)

__all__ = [
    "erdos_renyi", "random_bipartite", "stochastic_block", "power_law",
    "sample", "read_edge_list", "normalize_edges", "load_graph",
    "load_fixture", "fixture_path", "write_edge_list", "allocate",
    "EdgeDelta",
]


def allocate(g: Graph, K: int, r: int,
             interleave: bool = False) -> tuple[Graph, Allocation]:
    """(padded graph, ER allocation) for an arbitrary-n graph.

    Rounds n up to `divisible_n(n, K, r)` with virtual isolated vertices
    (no edges -> no Map values, no Shuffle traffic), which is how real
    datasets of awkward size meet the paper's Remark-1 divisibility
    requirement. Returns the graph unchanged when n already divides.
    """
    alloc = er_allocation(g.n, K, r, interleave=interleave, pad=True)
    return g.padded(alloc.n), alloc
