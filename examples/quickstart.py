"""Quickstart: the paper's coded scheme on a small ER graph, end to end.

Runs one distributed PageRank with the uncoded baseline and the coded scheme,
verifies both match the single-machine oracle bit-exactly, and prints the
communication loads against the paper's theory curves (Theorem 1).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import divisible_n, er_allocation

K, p = 5, 0.1
n = divisible_n(300, K, 2)
print(f"ER(n={n}, p={p}) on K={K} servers\n")

g = gm.erdos_renyi(n, p, seed=0)
prog = algo.pagerank()
oracle = algo.reference_run(prog, g, iters=3)

print(f"{'r':>2} {'L_uncoded':>10} {'L_coded':>10} {'gain':>6} "
      f"{'theory_uc':>10} {'theory_c':>9}")
for r in range(1, K + 1):
    alloc = er_allocation(n, K, r)
    res_uc = engine.run(prog, g, alloc, 3, mode="uncoded")
    res_c = engine.run(prog, g, alloc, 3, mode="coded")
    # Bit-exact distributed execution: both must equal the oracle.
    np.testing.assert_array_equal(res_uc.state, oracle)
    np.testing.assert_array_equal(res_c.state, oracle)
    lu, lc = res_uc.normalized_load, res_c.normalized_load
    gain = lu / lc if lc else float("inf")
    print(f"{r:2d} {lu:10.4f} {lc:10.4f} {gain:6.2f} "
          f"{loads.uncoded_load_er(p, r, K):10.4f} "
          f"{loads.coded_load_er_asymptotic(p, r, K):9.4f}")

print("\nAll runs matched the single-machine oracle bit-exactly.")
print("Coded shuffle achieves ~1/r of the uncoded load (Theorem 1).")
