"""shard_map expert parallelism == dense einsum dispatch, on a real
(data=2, model=2) mesh (subprocess keeps the device flag contained)."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses, json
import numpy as np
import jax, jax.numpy as jnp

from repro import configs
from repro.models import moe as moe_mod
from repro.models.layers import init_params
from repro.sharding import rules
from repro.launch.mesh import make_local_mesh

cfg0 = configs.get("llama4-maverick-400b-a17b").reduced()
# 4 experts over data=2; generous capacity so dense/EP drop nothing.
cfg = dataclasses.replace(cfg0, moe=dataclasses.replace(
    cfg0.moe, capacity_factor=8.0, num_shared=0))
spec = moe_mod.moe_spec(cfg)
params = init_params(spec, jax.random.PRNGKey(0), jnp.float32)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model), jnp.float32)

mesh = make_local_mesh(data=2, model=2)
rules.set_mesh(mesh)
with mesh:
    dense = moe_mod.moe_ffn(params, cfg, x)
    cfg_ep = dataclasses.replace(cfg, moe=dataclasses.replace(cfg.moe, ep=True))
    ep = jax.jit(lambda p, xx: moe_mod.moe_ffn(p, cfg_ep, xx))(params, x)
    # And gradients flow through the a2a.
    g = jax.grad(lambda p: jnp.sum(moe_mod.moe_ffn(p, cfg_ep, x) ** 2))(params)
rules.set_mesh(None)
err = float(jnp.abs(jnp.asarray(dense) - jnp.asarray(ep)).max())
gnorm = float(sum(jnp.sum(jnp.abs(v)) for v in jax.tree.leaves(g)))
print(json.dumps({"err": err, "scale": float(jnp.abs(dense).max()),
                  "gnorm": gnorm}))
"""


def test_moe_ep_matches_dense_dispatch():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=420,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-3000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["err"] < 1e-4 * max(res["scale"], 1.0), res
    assert res["gnorm"] > 0 and res["gnorm"] < float("inf")
