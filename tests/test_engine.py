"""Engine correctness: every mode must equal the single-machine oracle."""
import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)

PROGRAMS = [algo.pagerank(), algo.sssp(0), algo.connected_components(),
            algo.degree_count()]


@pytest.mark.parametrize("prog", PROGRAMS, ids=lambda p: p.name)
@pytest.mark.parametrize("mode", ["uncoded", "coded", "coded-fast"])
@pytest.mark.parametrize("path", ["auto", "dense"])
def test_engine_matches_oracle_er(prog, mode, path):
    """Each execution path must be bitwise equal to its same-path oracle
    ("auto" resolves to the sparse O(edges) form for the built-ins)."""
    K, r = 5, 2
    n = divisible_n(50, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=11)
    alloc = er_allocation(n, K, r)
    ref = algo.reference_run(prog, g, 4, path=path)
    res = engine.run(prog, g, alloc, 4, mode=mode, path=path)
    np.testing.assert_array_equal(res.state, ref)


@pytest.mark.parametrize("model,kw", [
    ("rb", dict(n1=48, n2=24, q=0.3)),
    ("sbm", dict(n1=48, n2=24, p=0.25, q=0.1)),
])
def test_engine_matches_oracle_two_cluster(model, kw):
    g = gm.sample(model, seed=5, **kw)
    alloc = bipartite_allocation(48, 24, 6, 2)
    prog = algo.pagerank()
    ref = algo.reference_run(prog, g, 3)
    for mode in ["uncoded", "coded"]:
        res = engine.run(prog, g, alloc, 3, mode=mode)
        np.testing.assert_array_equal(res.state, ref)


def test_engine_matches_oracle_power_law():
    n = divisible_n(60, 5, 2)
    g = gm.power_law(n, 2.5, seed=9)
    alloc = er_allocation(n, 5, 2)
    prog = algo.pagerank()
    ref = algo.reference_run(prog, g, 3)
    res = engine.run(prog, g, alloc, 3, mode="coded")
    np.testing.assert_array_equal(res.state, ref)


def test_coded_never_sends_more_than_uncoded():
    for seed in range(3):
        n = divisible_n(60, 5, 3)
        g = gm.erdos_renyi(n, 0.15, seed=seed)
        alloc = er_allocation(n, 5, 3)
        prog = algo.pagerank()
        lu = engine.run(prog, g, alloc, 1, "uncoded").shuffle_bits
        lc = engine.run(prog, g, alloc, 1, "coded").shuffle_bits
        assert lc <= lu


def test_pagerank_mass_conserved_and_converges():
    g = gm.erdos_renyi(60, 0.3, seed=1)
    alloc = er_allocation(60, 5, 2)
    prog = algo.pagerank(damping=0.15)
    res = engine.run(prog, g, alloc, 50, mode="coded-fast")
    # Stationary: one more iteration moves nothing (to fp32 tolerance).
    res2 = engine.run(prog, g, alloc, 51, mode="coded-fast")
    assert np.abs(res.state - res2.state).max() < 1e-6
    assert res.state.sum() == pytest.approx(1.0, abs=1e-3)


def test_sssp_matches_dijkstra():
    n = divisible_n(40, 4, 2)
    g = gm.erdos_renyi(n, 0.2, seed=4)
    w = g.weights()
    # Plain Dijkstra oracle.
    import heapq
    dist = np.full(g.n, np.inf)
    dist[0] = 0.0
    pq = [(0.0, 0)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v in np.flatnonzero(g.adj[u]):
            nd = d + w[u, v]
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    alloc = er_allocation(n, 4, 2)
    res = engine.run(algo.sssp(0), g, alloc, g.n, mode="coded-fast")
    np.testing.assert_allclose(res.state, dist.astype(np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# CompiledEngine session API (engine.compile) and backend_opts validation
# ---------------------------------------------------------------------------

def test_compile_run_equals_one_shot_run():
    n = divisible_n(60, 4, 2)
    g = gm.erdos_renyi(n, 0.15, seed=2)
    alloc = er_allocation(n, 4, 2)
    for mode in ("uncoded", "coded", "coded-fast", "coded-ref", "single"):
        sess = engine.compile(algo.pagerank(), g, alloc, mode)
        res = sess.run(3)
        ref = engine.run(algo.pagerank(), g, alloc, 3, mode)
        assert np.array_equal(res.state, ref.state), mode
        assert res.shuffle_bits == ref.shuffle_bits, mode


def test_compiled_engine_reuses_plan_across_runs_and_programs():
    n = divisible_n(60, 4, 2)
    g = gm.erdos_renyi(n, 0.15, seed=2)
    alloc = er_allocation(n, 4, 2)
    sess = engine.compile(algo.pagerank(), g, alloc, "coded")
    plan = sess.plan
    r1, r2 = sess.run(2), sess.run(2)
    assert sess.plan is plan                    # no recompile between runs
    assert np.array_equal(r1.state, r2.state)
    other = sess.with_program(algo.sssp(0))
    assert other.plan is plan                   # program swap is free
    assert other.tables is sess.tables
    assert np.array_equal(
        other.run(4).state,
        engine.run(algo.sssp(0), g, alloc, 4, "coded").state)


def test_compiled_engine_loads_match_result_loads():
    n = divisible_n(60, 4, 2)
    g = gm.erdos_renyi(n, 0.15, seed=2)
    alloc = er_allocation(n, 4, 2)
    sess = engine.compile(algo.pagerank(), g, alloc, "coded")
    loads = sess.loads()
    res = sess.run(1)
    assert res.normalized_load == pytest.approx(
        loads["coded"] + loads["coded_leftover_unicast"])


def test_backend_opts_unknown_keys_raise_with_accepted_set():
    n = divisible_n(40, 4, 2)
    g = gm.erdos_renyi(n, 0.2, seed=1)
    alloc = er_allocation(n, 4, 2)
    prog = algo.pagerank()
    # numpy accepts nothing: the old silent-ignore bug must now raise.
    with pytest.raises(ValueError, match=r"'numpy' got unknown option.*bm"):
        engine.run(prog, g, alloc, 1, backend_opts={"bm": 8})
    with pytest.raises(ValueError, match=r"accepted: \['bm', 'interpret'\]"):
        engine.run(prog, g, alloc, 1, backend="spmv",
                   backend_opts={"mesh": None})
    with pytest.raises(ValueError,
                       match=r"accepted: \['encode', 'interpret', 'mesh'\]"):
        engine.compile(prog, g, alloc, "coded", backend="fused", bm=8)
    with pytest.raises(ValueError, match="unknown backend"):
        engine.run(prog, g, alloc, 1, backend="cuda")
    # Valid options still pass through (inline form == backend_opts form).
    a = engine.compile(prog, g, alloc, "coded", backend="spmv", bm=32).run(2)
    b = engine.run(prog, g, alloc, 2, backend="spmv",
                   backend_opts={"bm": 32})
    assert np.array_equal(a.state, b.state)
