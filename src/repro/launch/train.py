"""End-to-end training driver.

At full scale this runs under the production mesh; on CPU it drives the
reduced configs (examples/train_lm.py uses it to train a ~few-M-param model
for a few hundred steps and show the loss dropping). Fault tolerance:
checkpoint every N steps (async), restart-safe data pipeline, and restore
onto a different mesh if the job was rescaled.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import numpy as np

from .. import configs
from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig, ShapeSpec
from ..data.pipeline import DataConfig, batch_for_step
from ..models import transformer as tfm
from ..models.layers import init_params
from ..sharding import rules
from ..train.optimizer import AdamWConfig, init_state
from ..train.step import make_train_step
from .mesh import make_local_mesh


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps: int
    restored_from: int | None


def train(cfg: ModelConfig, shape: ShapeSpec, steps: int, *,
          opt: AdamWConfig | None = None, ckpt_dir: str | None = None,
          ckpt_every: int = 50, seed: int = 0, accum: int = 1,
          chunk: int = 1024, log_every: int = 10, mesh=None,
          verbose: bool = True) -> TrainResult:
    opt = opt or AdamWConfig(total_steps=steps)
    mesh = mesh or make_local_mesh()
    rules.set_mesh(mesh)
    try:
        params = init_params(tfm.model_spec(cfg), jax.random.PRNGKey(seed))
        opt_state = init_state(params)
        start = 0
        restored = None
        mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
        if mgr and mgr.latest() is not None:
            start, params, opt_state, _ = mgr.restore(params, opt_state)
            restored = start
            if verbose:
                print(f"restored from step {start}")
        step_fn = make_train_step(cfg, opt, accum=accum, chunk=chunk)
        losses = []
        t0 = time.time()
        with mesh:
            for step in range(start, steps):
                batch = batch_for_step(cfg, shape, step, DataConfig(seed=seed))
                params, opt_state, loss = step_fn(params, opt_state, batch)
                if step % log_every == 0 or step == steps - 1:
                    losses.append((step, float(loss)))
                    if verbose:
                        print(f"step {step:5d} loss {float(loss):.4f} "
                              f"({time.time() - t0:.1f}s)", flush=True)
                if mgr and (step + 1) % ckpt_every == 0:
                    mgr.save(step + 1, params, opt_state)
        if mgr:
            mgr.save(steps, params, opt_state, blocking=True)
        return TrainResult(losses, steps, restored)
    finally:
        rules.set_mesh(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = configs.get(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", args.seq_len, args.batch, "train")
    train(cfg, shape, args.steps, ckpt_dir=args.ckpt_dir, chunk=64)


if __name__ == "__main__":
    main()
