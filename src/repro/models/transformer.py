"""Composable model assembly: param specs, scan-over-layers forward passes
(train / prefill / decode) for every assigned architecture family.

Layer stacks are jax.lax.scan over stacked params (HLO size O(1) in depth).
Per-layer attention flavor (local/global window) rides along as a traced
int array; heterogeneous stacks (llama4's dense/MoE interleave, zamba2's
shared-attention insertion) are expressed as multi-block scan units and
lax.cond respectively.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..sharding.rules import constrain
from . import mla as mla_mod
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import (ParamSpec, attend, chunked_attend, cross_entropy, geglu,
                     rms_norm, rope)


# ---------------- param specs ----------------

def attn_spec(cfg: ModelConfig) -> dict:
    d, H, G, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    return {
        "q": ParamSpec((d, H, Dh), ("embed", "heads", None)),
        "k": ParamSpec((d, G, Dh), ("embed", "kv_heads", None)),
        "v": ParamSpec((d, G, Dh), ("embed", "kv_heads", None)),
        "o": ParamSpec((H, Dh, d), ("heads", None, "embed")),
    }


def dense_ffn_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "mlp")),
        "w_up": ParamSpec((d, f), ("embed", "mlp")),
        "w_down": ParamSpec((f, d), ("mlp", "embed")),
    }


def block_spec(cfg: ModelConfig, *, moe_layer: bool) -> dict:
    spec: dict = {"attn_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
                  "ffn_norm": ParamSpec((cfg.d_model,), ("embed",), "zeros")}
    spec["attn"] = mla_mod.mla_spec(cfg) if cfg.mla else attn_spec(cfg)
    spec["ffn"] = moe_mod.moe_spec(cfg) if moe_layer else dense_ffn_spec(cfg)
    return spec


def ssm_block_spec(cfg: ModelConfig) -> dict:
    return {"norm": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
            "mixer": ssm_mod.ssm_spec(cfg)}


def _stacked(spec, L: int):
    return jax.tree.map(
        lambda p: ParamSpec((L,) + p.shape, ("layers",) + p.axes, p.init),
        spec, is_leaf=lambda x: isinstance(x, ParamSpec))


def moe_interleave(cfg: ModelConfig) -> int:
    """Layers per scan unit (llama4: dense/MoE alternation -> 2)."""
    return cfg.moe_every if cfg.moe else 1


def model_spec(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    spec: dict = {
        "embed": ParamSpec((cfg.vocab, d), ("vocab", "embed")),
        "final_norm": ParamSpec((d,), ("embed",), "zeros"),
    }
    if cfg.family in ("ssm", "hybrid"):
        spec["layers"] = _stacked(ssm_block_spec(cfg), cfg.n_layers)
        if cfg.family == "hybrid" and cfg.attn_every:
            shared = block_spec(cfg, moe_layer=False)
            spec["shared_attn"] = shared
    else:
        unit = moe_interleave(cfg)
        n_units = cfg.n_layers // unit
        if unit == 1:
            spec["layers"] = _stacked(block_spec(cfg, moe_layer=bool(cfg.moe)),
                                      n_units)
        else:
            spec["layers"] = {
                "dense": _stacked(block_spec(cfg, moe_layer=False), n_units),
                "moe": _stacked(block_spec(cfg, moe_layer=True), n_units),
            }
    if cfg.frontend == "vision":
        spec["patch_proj"] = ParamSpec((d, d), ("embed", None))
    if cfg.frontend == "audio":
        spec["frame_proj"] = ParamSpec((d, d), ("embed", None))
    return spec


# ---------------- attention block ----------------

def _window_arr(cfg: ModelConfig, n: int, offset: int = 0, stride: int = 1):
    kinds = cfg.layer_kinds()
    return jnp.array([cfg.window if kinds[offset + i * stride] == "local" else -1
                      for i in range(n)], jnp.int32)


def gqa_forward(p, cfg: ModelConfig, x, positions, window, *, chunk=1024):
    """Train/prefill attention. window: traced scalar (-1 = global).

    Activation sharding picks head-parallel attention when head counts divide
    the tensor axis, else kv-sequence-parallel (ragged-head archs: llama4's
    40H, internvl2's 14H) - XLA then computes the softmax over a sharded key
    axis with partial reductions instead of replicating a [*, S, S] tile.
    """
    from ..sharding.rules import tp_size
    q = jnp.einsum("btd,dhk->bthk", x, p["q"])
    k = jnp.einsum("btd,dgk->btgk", x, p["k"])
    v = jnp.einsum("btd,dgk->btgk", x, p["v"])
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    tp = tp_size()
    if cfg.n_heads % tp == 0 and cfg.n_kv_heads % tp == 0:
        q = constrain(q, "batch", None, "act_heads", None)
        k = constrain(k, "batch", None, "act_kv", None)
        v = constrain(v, "batch", None, "act_kv", None)
    else:
        k = constrain(k, "batch", "act_seq_tp", None, None)
        v = constrain(v, "batch", "act_seq_tp", None, None)
    out = chunked_attend(q, k, v, positions, positions, chunk=chunk,
                         causal=not cfg.encoder_only, window=window,
                         softcap=cfg.attn_softcap)
    return jnp.einsum("bthk,hkd->btd", out, p["o"]), (k, v)


def gqa_decode(p, cfg: ModelConfig, x, pos, cache_k, cache_v, window):
    """x [B,1,d]; cache_k/v [B,Smax,G,Dh]; pos [B,1] current position."""
    q = rope(jnp.einsum("btd,dhk->bthk", x, p["q"]), pos, cfg.rope_theta)
    k = rope(jnp.einsum("btd,dgk->btgk", x, p["k"]), pos, cfg.rope_theta)
    v = jnp.einsum("btd,dgk->btgk", x, p["v"])
    t = pos[0, 0]
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), t, 1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), t, 1)
    kpos = jnp.arange(cache_k.shape[1])[None]
    kv_valid = kpos <= t
    out = attend(q, cache_k, cache_v, pos, kpos, causal=True,
                 window=window, softcap=cfg.attn_softcap, kv_valid=kv_valid)
    return jnp.einsum("bthk,hkd->btd", out, p["o"]), cache_k, cache_v


def _ffn(p, cfg: ModelConfig, x, *, moe_layer: bool):
    if moe_layer:
        return moe_mod.moe_ffn(p, cfg, x)
    return geglu(x, p["w_gate"], p["w_up"], p["w_down"], act=cfg.act)


def block_forward(p, cfg, x, positions, window, *, moe_layer, chunk=1024):
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.mla:
        attn_out, kv = mla_mod.mla_attention(p["attn"], cfg, h, positions,
                                             chunk=chunk)
    else:
        attn_out, kv = gqa_forward(p["attn"], cfg, h, positions, window,
                                   chunk=chunk)
    x = x + attn_out
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    x = x + _ffn(p["ffn"], cfg, h, moe_layer=moe_layer)
    return constrain(x, "batch", None, None), kv


def block_decode(p, cfg, x, pos, cache, window, *, moe_layer):
    h = rms_norm(x, p["attn_norm"], cfg.norm_eps)
    if cfg.mla:
        attn_out, lat, rp = mla_mod.mla_decode(
            p["attn"], cfg, h, pos, cache["lat"], cache["rope"],
            kv_valid=jnp.arange(cache["lat"].shape[1])[None] <= pos[0, 0])
        new_cache = {"lat": lat, "rope": rp}
    else:
        attn_out, ck, cv = gqa_decode(p["attn"], cfg, h, pos,
                                      cache["k"], cache["v"], window)
        new_cache = {"k": ck, "v": cv}
    x = x + attn_out.astype(x.dtype)   # cache dtype may differ (f32 serving)
    h = rms_norm(x, p["ffn_norm"], cfg.norm_eps)
    x = x + _ffn(p["ffn"], cfg, h, moe_layer=moe_layer)
    return x, new_cache


# ---------------- stacks ----------------

def _attn_stack(params, cfg: ModelConfig, x, positions, *, remat: bool,
                chunk=1024):
    unit = moe_interleave(cfg)
    # PERF (EXPERIMENTS.md SSPerf, gemma2/train_4k iter 1): carry the scan
    # residual in f32 when remat is on. jax.checkpoint saves the carry per
    # trip; with a bf16 carry XLA wraps the saved-activation stack in a
    # full-stack bf16<->f32 convert sandwich *every layer trip* (~2.9GB/trip
    # for gemma2) because the backward consumers are f32. An f32 carry costs
    # one extra 2x slice write per trip and removes the sandwich.
    carry_t = jnp.float32 if remat else x.dtype
    model_t = x.dtype

    def wrap(body):
        def wrapped(h, inp):
            h, ys = body(h.astype(model_t), inp)
            return h.astype(carry_t), ys
        return jax.checkpoint(wrapped) if remat else wrapped

    if unit == 1:
        windows = _window_arr(cfg, cfg.n_layers)

        def body(h, inp):
            lp, w = inp
            h, _ = block_forward(lp, cfg, h, positions, w,
                                 moe_layer=bool(cfg.moe), chunk=chunk)
            return h, None

        x, _ = jax.lax.scan(wrap(body), x.astype(carry_t),
                            (params["layers"], windows))
        return x.astype(model_t)

    n_units = cfg.n_layers // unit
    w_dense = _window_arr(cfg, n_units, 0, unit)
    w_moe = _window_arr(cfg, n_units, 1, unit)

    def body(h, inp):
        lp, wd, wm = inp
        h, _ = block_forward(lp["dense"], cfg, h, positions, wd,
                             moe_layer=False, chunk=chunk)
        h, _ = block_forward(lp["moe"], cfg, h, positions, wm,
                             moe_layer=True, chunk=chunk)
        return h, None

    x, _ = jax.lax.scan(wrap(body), x.astype(carry_t),
                        (params["layers"], w_dense, w_moe))
    return x.astype(model_t)


def hybrid_segments(cfg: ModelConfig) -> list[tuple[int, int]]:
    """Layer ranges between shared-attention insertion points (zamba2):
    the shared block runs *before* each segment of attn_every ssm layers."""
    if cfg.family != "hybrid" or not cfg.attn_every:
        return [(0, cfg.n_layers)]
    return [(s, min(s + cfg.attn_every, cfg.n_layers))
            for s in range(0, cfg.n_layers, cfg.attn_every)]


def _tree_slice(tree, a: int, b: int):
    return jax.tree.map(lambda v: v[a:b], tree)


def _ssm_stack(params, cfg: ModelConfig, x, positions, *, remat: bool,
               chunk=1024):
    use_shared = cfg.family == "hybrid" and cfg.attn_every

    def seg_scan(lp_seg, h):
        def body(h, lp):
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            out, _ = ssm_mod.mamba2_block(lp["mixer"], cfg, hn)
            return h + out, None

        f = jax.checkpoint(body) if remat else body
        h, _ = jax.lax.scan(f, h, lp_seg)
        return h

    for a, b in hybrid_segments(cfg):
        if use_shared:
            x, _ = block_forward(params["shared_attn"], cfg, x, positions,
                                 jnp.int32(-1), moe_layer=False, chunk=chunk)
        x = seg_scan(_tree_slice(params["layers"], a, b), x)
    return x


def _embed_inputs(params, cfg: ModelConfig, batch: dict):
    scale = jnp.sqrt(jnp.float32(cfg.d_model)).astype(jnp.bfloat16)
    if cfg.frontend == "audio":
        x = jnp.einsum("btd,de->bte", batch["frames"], params["frame_proj"])
    elif cfg.frontend == "vision":
        pe = jnp.einsum("bpd,de->bpe", batch["patches"], params["patch_proj"])
        te = params["embed"][batch["tokens"]] * scale
        x = jnp.concatenate([pe, te.astype(pe.dtype)], axis=1)
    else:
        x = params["embed"][batch["tokens"]] * scale
    return constrain(x, "batch", None, None)


def forward_hidden(params, cfg: ModelConfig, batch: dict, *, remat=False,
                   chunk=1024):
    """Embed + stack + final norm -> hidden [B, S, d] (no logits)."""
    x = _embed_inputs(params, cfg, batch)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    stack = _ssm_stack if cfg.family in ("ssm", "hybrid") else _attn_stack
    x = stack(params, cfg, x, positions, remat=remat, chunk=chunk)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def forward(params, cfg: ModelConfig, batch: dict, *, remat=False, chunk=1024):
    """Full-sequence forward -> logits [B, S, vocab] (fp32)."""
    x = forward_hidden(params, cfg, batch, remat=remat, chunk=chunk)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"])
    return constrain(logits.astype(jnp.float32), "batch", None, "vocab")


def _chunked_ce(x, embed, labels, vocab, softcap, *, seq_chunk=512):
    """CE over sequence chunks so the f32 logits tensor (B*S*vocab, the
    largest activation for 256k vocabs) is never materialized whole
    (PERF: gemma2/train_4k iter 4). Chunk body is rematerialized."""
    from .layers import cross_entropy
    B, S, d = x.shape
    if S % seq_chunk:
        seq_chunk = S                      # ragged: fall back to one chunk
    n = S // seq_chunk
    xs = x.reshape(B, n, seq_chunk, d).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, seq_chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, inp):
        xc, lc = inp
        logits = jnp.einsum("btd,vd->btv", xc, embed)
        return acc + cross_entropy(logits, lc, vocab, softcap), None

    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xs, ls))
    return tot / n


def loss_fn(params, cfg: ModelConfig, batch: dict, *, remat=True, chunk=1024):
    x = forward_hidden(params, cfg, batch, remat=remat, chunk=chunk)
    labels = batch["labels"]
    if cfg.frontend == "vision":            # loss on text positions only
        x = x[:, cfg.num_patches:]
    if not cfg.encoder_only and cfg.frontend != "audio":
        x, labels = x[:, :-1], labels[:, 1:]
    return _chunked_ce(x, params["embed"], labels, cfg.vocab,
                       cfg.logit_softcap)
