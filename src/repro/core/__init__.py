"""Core: the paper's coded distributed graph-analytics scheme.

Subgraph/computation allocation (§IV-A), bit-exact XOR coded Shuffle (Fig. 6),
the distributed MapReduce-on-graph engine (§II-B), theory bounds (Thms 1-4),
and r-redundancy fault tolerance.
"""
from . import algorithms, allocation, bitcodec, coded_shuffle, engine  # noqa: F401
from . import faults, graph_models, loads, shuffle_plan, uncoded_shuffle  # noqa: F401
