"""Schedule-invariant checks on the compiled ShufflePlan (tier-1).

Four invariants lock the whole plan/schedule layer down; each is a plain
check function over one (graph, allocation) pair so the hypothesis suite
(`test_properties.py`) can drive the same bodies over random pairs while
this module pins a deterministic seeded matrix that runs everywhere
(hypothesis is an optional dependency):

  * completeness - the plan's delivery set is exactly what each Reducer is
    missing, re-derived through the *legacy dense* `missing_pairs` (an
    independent code path from the compiler's edge pass);
  * word conservation - bits-on-the-wire of an executed Shuffle equal the
    plan's compile-time accounting, column widths re-derived from slot-mask
    popcounts, leftovers 32 bits each - i.e. `coded_load` is exactly what
    the wire carries, never recomputed from data;
  * compile identity - `compile_plan` (dense adjacency) and
    `compile_plan_csr` (adjacency-free) emit bitwise-identical plans;
  * delivery equality - the sparse [nnz]-vector executors deliver the same
    (k, i, j, value) arrays, bit for bit, as the dense [n, n] executors,
    in every plan mode;
  * hierarchical per-level completeness + word conservation - every flat
    delivery of a two-level plan is routed exactly once (in-rack source
    that really Mapped the vertex, or a matching rack-level stream entry),
    the rack-level stream is fully consumed, the per-level bit split is
    exactly what the executor reports, and `Topology.flat(K)` degenerates
    to the flat plan bitwise.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation, random_allocation)
from repro.core.bitcodec import T_BITS
from repro.core.shuffle_plan import (compile_hierarchical, compile_plan,
                                     compile_plan_csr)
from repro.core.uncoded_shuffle import missing_pairs
from repro.launch.mesh import Topology

PLAN_MODES = ("uncoded", "coded", "coded-fast")


def _popcount32(a: np.ndarray) -> np.ndarray:
    """Per-element popcount of a uint32 array (np.bitwise_count needs
    numpy >= 2.0; pyproject allows 1.26, so count via unpackbits)."""
    a = np.ascontiguousarray(a, dtype=np.uint32)
    return np.unpackbits(a.view(np.uint8)).reshape(*a.shape, 32).sum(axis=-1)


# ---- check bodies (shared with the hypothesis suite) ----


def check_schedule_complete(g, alloc):
    """Delivery set == per-Reducer missing set (legacy dense derivation),
    and the covered/leftover split partitions it."""
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    for k in range(alloc.K):
        need = missing_pairs(g.adj, alloc, k)            # independent path
        a, b = int(plan.ptr[k]), int(plan.ptr[k + 1])
        got = np.column_stack([plan.all_i[a:b], plan.all_j[a:b]])
        assert got.shape == need.shape and (got == need).all(), f"server {k}"
        assert (plan.all_k[a:b] == k).all()
    pos = np.concatenate([plan.pos_covered, plan.pos_left])
    assert np.array_equal(np.sort(pos), np.arange(plan.all_k.size))
    return plan


def check_word_conservation(g, alloc):
    """Executed bits == compile-time accounting == slot-mask re-derivation.

    The schedule fixes the wire volume: a coded column is as wide as its
    widest occupied segment (popcount of the slot keep-masks), a leftover
    is one full word, and what `execute_coded` reports must be exactly
    that - for any values, so the check runs the executor on real Map
    output and on a second, different value matrix.
    """
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    widths = _popcount32(plan.slot_mask).max(axis=1)
    assert np.array_equal(widths.astype(np.int64), plan.col_width)
    assert plan.coded_bits == int(plan.col_width.sum())
    assert plan.leftover_bits == plan.left_k.size * T_BITS
    assert plan.uncoded_bits == plan.all_k.size * T_BITS
    denom = plan.n * plan.n * T_BITS
    assert plan.coded_load() * denom == pytest.approx(plan.coded_bits,
                                                      rel=1e-12)
    assert plan.uncoded_load() * denom == pytest.approx(plan.uncoded_bits,
                                                        rel=1e-12)
    prog = algo.pagerank()
    values = np.asarray(prog.map_values(g, prog.init(g)), np.float32)
    rng = np.random.default_rng(0)
    for vals in (values, rng.normal(size=values.shape).astype(np.float32)):
        res = plan.execute_coded(vals)
        assert res.bits_sent == plan.coded_bits + plan.leftover_bits
        assert plan.execute_uncoded(vals).bits_sent == plan.uncoded_bits
    # (coded <= uncoded is a *statistical* property of the ER allocation,
    # not a schedule invariant - unbalanced allocations can pad columns
    # past the unicast cost; test_coded_load_never_exceeds_uncoded covers
    # the allocation family the theorems speak about.)
    return plan


def check_plan_csr_identity(g, alloc):
    """compile_plan(adj) and compile_plan_csr(csr): every array bitwise."""
    pa = compile_plan(g.adj, alloc, validate=False)
    pc = compile_plan_csr(g.csr, alloc, validate=False)
    for f in dataclasses.fields(pa):
        va, vb = getattr(pa, f.name), getattr(pc, f.name)
        if isinstance(va, np.ndarray):
            assert vb is not None and va.dtype == vb.dtype, f.name
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
        else:
            assert va == vb, f.name
    return pc


def check_sparse_dense_delivery_equal(g, alloc):
    """Sparse [nnz] executors deliver bitwise what the dense ones do."""
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    tables = plan.edge_tables(g.csr, alloc)
    prog = algo.sssp(0)   # exercises edge_weights (hardest bitwise contract)
    values = np.asarray(prog.map_values(g, prog.init(g)), np.float32)
    edge_vals = prog.map_edge_values(g, prog.init(g)).astype(np.float32)
    # The two Map forms agree on scheduled entries (garbage elsewhere).
    np.testing.assert_array_equal(values[g.csr.rows, g.csr.indices],
                                  edge_vals)
    for mode in PLAN_MODES:
        rd = plan.execute(values, mode)
        rs = plan.execute_sparse(edge_vals, mode, tables)
        np.testing.assert_array_equal(
            rd.values.view(np.uint32), rs.values.view(np.uint32),
            err_msg=mode)
        assert rd.bits_sent == rs.bits_sent
        for arr in ("k", "i", "j", "ptr"):
            np.testing.assert_array_equal(getattr(rd, arr), getattr(rs, arr))
    return plan


def _assert_plans_bitwise_equal(pa, pb, label):
    for f in dataclasses.fields(pa):
        va, vb = getattr(pa, f.name), getattr(pb, f.name)
        if isinstance(va, np.ndarray):
            assert vb is not None and va.dtype == vb.dtype, (label, f.name)
            np.testing.assert_array_equal(va, vb,
                                          err_msg=f"{label}.{f.name}")
        else:
            assert va == vb, (label, f.name)


def check_flat_degeneracy(g, alloc):
    """`Topology.flat(K)` compiles to exactly today's plan: the flat
    sub-plan AND the rack-level plan are bitwise `compile_plan_csr`, and
    every delivery routes through the (degenerate) inter level."""
    hp = compile_hierarchical(g.csr, alloc, Topology.flat(alloc.K),
                              validate=False)
    pc = compile_plan_csr(g.csr, alloc, validate=False)
    _assert_plans_bitwise_equal(hp.flat, pc, "flat")
    _assert_plans_bitwise_equal(hp.inter, pc, "inter")
    assert hp.intra_words == 0 and hp.intra_rack_bits == 0
    assert (hp.inter_pos >= 0).all() and (hp.intra_src == -1).all()
    assert hp.inter_rack_bits == pc.coded_bits + pc.leftover_bits
    return hp


def check_hierarchical_levels(g, alloc, topology):
    """Per-level completeness + word conservation of a two-level plan.

    Completeness: the flat delivery stream partitions exactly into
    intra-rack deliveries (an in-rack source that really Mapped the
    vertex, same rack as the receiver) and inter-rack ones (a matching
    (rack, i, j) entry of the rack-level stream), the split agreeing with
    the rack union Map sets; the rack-level stream is consumed exactly
    (no dangling entries - deliveries are unique per (i, j), so the
    mapping is a bijection). Conservation: the per-level bit accounting
    recomposes from the sub-plans and is exactly what the executor
    reports, with delivered words bitwise equal to the flat executor.
    """
    hplan = compile_hierarchical(g.csr, alloc, topology)
    flat = compile_plan_csr(g.csr, alloc, validate=False)
    _assert_plans_bitwise_equal(hplan.flat, flat, "flat-subplan")
    rack_of = topology.rack_of()
    inter = hplan.inter
    intra = hplan.inter_pos < 0
    # Exactly one routing per flat delivery.
    assert np.array_equal(intra, hplan.intra_src >= 0)
    # The split agrees with the rack union Map sets (in-rack copy iff
    # some member of the receiver's rack Mapped the vertex).
    has = hplan.rack_alloc.map_sets
    d_rho = rack_of[flat.all_k]
    assert np.array_equal(intra, has[d_rho, flat.all_j])
    # Intra: the designated source is in the receiver's rack and Mapped j.
    src = hplan.intra_src[intra]
    assert (rack_of[src] == d_rho[intra]).all()
    assert alloc.map_sets[src, flat.all_j[intra]].all()
    # Inter: the rack-level entry matches (rack, i, j) and every entry of
    # the rack-level stream is consumed exactly once.
    pos = hplan.inter_pos[~intra]
    assert (inter.all_k[pos] == d_rho[~intra]).all()
    assert (inter.all_i[pos] == flat.all_i[~intra]).all()
    assert (inter.all_j[pos] == flat.all_j[~intra]).all()
    used = np.zeros(inter.all_k.size, dtype=bool)
    used[pos] = True
    assert used.all() and pos.size == inter.all_k.size
    # Word conservation per level.
    assert hplan.inter_rack_bits == inter.coded_bits + inter.leftover_bits
    assert hplan.intra_rack_bits == hplan.intra_words * T_BITS
    assert hplan.total_bits == hplan.inter_rack_bits + hplan.intra_rack_bits
    prog = algo.sssp(0)
    ev = prog.map_edge_values(g, prog.init(g)).astype(np.float32)
    tables = hplan.edge_tables(g.csr, alloc)
    res = hplan.execute_coded_sparse(ev, tables)
    ref = flat.execute_coded_sparse(ev, flat.edge_tables(g.csr, alloc))
    np.testing.assert_array_equal(res.values.view(np.uint32),
                                  ref.values.view(np.uint32))
    assert res.bits_sent == hplan.total_bits
    return hplan


CHECKS = {
    "complete": check_schedule_complete,
    "words": check_word_conservation,
    "csr-identity": check_plan_csr_identity,
    "delivery": check_sparse_dense_delivery_equal,
}


# ---- deterministic seeded matrix (tier-1; hypothesis optional) ----


def _cases():
    cases = []
    for seed in range(3):
        K, r = 4, 2
        n = divisible_n(40 + 10 * seed, K, r)
        g = gm.erdos_renyi(n, 0.15 + 0.1 * seed, seed=seed)
        cases.append((f"er{seed}", g, er_allocation(n, K, r)))
    K, r = 5, 3
    n = divisible_n(50, K, r)
    cases.append(("er-interleave", gm.erdos_renyi(n, 0.2, seed=3),
                  er_allocation(n, K, r, interleave=True)))
    cases.append(("random-alloc", gm.erdos_renyi(divisible_n(40, 4, 2),
                                                 0.2, seed=4),
                  random_allocation(divisible_n(40, 4, 2), 4, 2, seed=4)))
    cases.append(("pl", gm.power_law(divisible_n(48, 4, 2), 2.5, seed=5),
                  er_allocation(divisible_n(48, 4, 2), 4, 2)))
    cases.append(("rb-spill", gm.random_bipartite(48, 24, 0.3, seed=5),
                  bipartite_allocation(48, 24, 6, 3)))   # real leftovers
    cases.append(("r1", gm.erdos_renyi(divisible_n(40, 4, 1), 0.25, seed=6),
                  er_allocation(divisible_n(40, 4, 1), 4, 1)))
    return cases


_CASES = _cases()


@pytest.mark.parametrize("check", CHECKS, ids=list(CHECKS))
@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_schedule_invariant(case, check):
    _, g, alloc = case
    CHECKS[check](g, alloc)


def _topos_for(K):
    """Non-flat rack shapes of K servers (R x S = K, S > 1), including the
    degenerate one-rack form (everything intra)."""
    return [Topology(K // S, S) for S in range(2, K + 1) if K % S == 0]


@pytest.mark.parametrize("case", _CASES, ids=[c[0] for c in _CASES])
def test_hierarchical_per_level_invariants(case):
    _, g, alloc = case
    check_flat_degeneracy(g, alloc)
    for topo in _topos_for(alloc.K):
        check_hierarchical_levels(g, alloc, topo)


def test_hierarchical_one_rack_is_all_intra():
    """R=1 puts every server in one rack: the union Map set covers every
    batch, so nothing crosses and the inter level is empty."""
    _, g, alloc = next(c for c in _CASES if c[0] == "er0")
    hp = check_hierarchical_levels(g, alloc, Topology(1, alloc.K))
    assert hp.inter_rack_bits == 0 and (hp.inter_pos == -1).all()
    assert hp.intra_rack_bits > 0


def test_spill_case_really_has_leftovers():
    """Guard the matrix itself: the rb-spill case must exercise the
    unicast-leftover branch of every invariant."""
    _, g, alloc = next(c for c in _CASES if c[0] == "rb-spill")
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    assert plan.left_k.size > 0 and plan.pair_k.size > 0
