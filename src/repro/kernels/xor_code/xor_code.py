"""XOR alignment-table packing as a Pallas TPU kernel.

The coded Shuffle's encode is a masked XOR-reduce over the r table rows
(paper Fig. 6). On TPU this is a VPU bitwise op over [bc, W] uint32 tiles in
VMEM; r is small and static, so the row loop is unrolled. The same kernel
serves decode (strip) because XOR is its own inverse.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _xor_kernel(rows_ref, valid_ref, o_ref, *, r: int):
    acc = jnp.zeros_like(o_ref)
    for i in range(r):                       # r is static: unrolled on the VPU
        seg = rows_ref[i]
        mask = valid_ref[i][..., None]
        acc = jnp.bitwise_xor(acc, jnp.where(mask, seg, jnp.uint32(0)))
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("bc", "interpret"))
def xor_encode_pallas(rows: jnp.ndarray, valid: jnp.ndarray, *, bc: int = 256,
                      interpret: bool = True) -> jnp.ndarray:
    """rows [r, C, W] uint32, valid [r, C] bool -> coded [C, W] uint32."""
    r, c, w = rows.shape
    pad = (-c) % bc
    if pad:
        rows = jnp.pad(rows, ((0, 0), (0, pad), (0, 0)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    cp = c + pad
    out = pl.pallas_call(
        functools.partial(_xor_kernel, r=r),
        grid=(cp // bc,),
        in_specs=[
            pl.BlockSpec((r, bc, w), lambda i: (0, i, 0)),
            pl.BlockSpec((r, bc), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((bc, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cp, w), jnp.uint32),
        interpret=interpret,
    )(rows, valid.astype(jnp.bool_))
    return out[:c]
