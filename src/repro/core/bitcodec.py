"""Bit-exact (de)serialization of intermediate values for the coded Shuffle.

The paper splits each T-bit intermediate value v_{i,j} into r segments of T/r
bits. We represent values as float32 (T = 32) and operate on their exact bit
patterns so XOR coding and recovery are bit-perfect for *any* r (segment
boundaries need not divide 32 evenly; segments are the ceil/floor split).
"""
from __future__ import annotations

import numpy as np

T_BITS = 32


def floats_to_bits(x: np.ndarray) -> np.ndarray:
    """[m] float32 -> [m, 32] uint8 in {0,1} (big-endian bit order)."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    return np.unpackbits(x.view(np.uint8).reshape(-1, 4), axis=1)


def bits_to_floats(bits: np.ndarray) -> np.ndarray:
    """[m, 32] uint8 bits -> [m] float32."""
    packed = np.packbits(bits.astype(np.uint8), axis=1)
    return packed.reshape(-1, 4).copy().view(np.float32).ravel()


def floats_to_words(x: np.ndarray) -> np.ndarray:
    """[m] float32 -> [m] uint32 in *codec bit order*.

    Bit w of the codec bit-stream (floats_to_bits column w) is bit (31 - w) of
    the word, so a segment [a, b) left-aligned into a column is just
    ``(word << a) & top_mask(b - a)`` - the representation the ShufflePlan
    executor and the xor_code kernels operate on.
    """
    return np.ascontiguousarray(x, dtype=np.float32).view(np.uint32).byteswap()


def words_to_floats(w: np.ndarray) -> np.ndarray:
    """[m] codec-order uint32 -> [m] float32 (inverse of floats_to_words)."""
    return np.ascontiguousarray(w, dtype=np.uint32).byteswap().view(np.float32)


def segment_words(r: int, t_bits: int = T_BITS) -> tuple[np.ndarray, np.ndarray]:
    """Per-segment (left-shift, keep-mask) for codec-order uint32 words.

    Segment s of a value word v travels left-aligned as
    ``(v << shift[s]) & mask[s]``; ``>> shift[s]`` puts it back in place.
    Shifts are clipped below t_bits so zero-width segments (r > t_bits) stay
    defined; their mask is 0.
    """
    bounds = segment_bounds(r, t_bits)
    lens = np.array([b - a for a, b in bounds], dtype=np.uint64)
    shifts = np.minimum([a for a, _ in bounds], t_bits - 1).astype(np.uint32)
    masks = (((np.uint64(1) << lens) - np.uint64(1))
             << (np.uint64(t_bits) - lens)).astype(np.uint32)
    return shifts, masks


def segment_bounds(r: int, t_bits: int = T_BITS) -> list[tuple[int, int]]:
    """Split [0, t_bits) into r near-equal contiguous segments."""
    edges = np.linspace(0, t_bits, r + 1).round().astype(int)
    return [(int(edges[s]), int(edges[s + 1])) for s in range(r)]


def split_segments(bits: np.ndarray, r: int) -> list[np.ndarray]:
    """[m, 32] bits -> r arrays [m, seg_len_s]."""
    return [bits[:, a:b] for a, b in segment_bounds(r, bits.shape[1])]
