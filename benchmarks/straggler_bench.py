"""Straggler tolerance: coded load degradation as senders straggle
(the CDC-lineage property the r-fold Map redundancy buys; DESIGN.md SS5).

Dense-free: one CSR plan compile feeds the base coded/uncoded loads
(`empirical_loads`) AND the per-straggler-count degraded loads
(`faults.straggler_coded_load_plan`), so the sweep runs at any n the
sparse engine handles - no `g.adj` anywhere."""
from repro import graphs
from repro.core.allocation import divisible_n, er_allocation
from repro.core.faults import straggler_coded_load_plan
from repro.core.loads import empirical_loads
from repro.core.shuffle_plan import compile_plan_csr


def run(report):
    K, r, p = 6, 3, 0.15
    n = divisible_n(240, K, r)
    g = graphs.erdos_renyi(n, p, seed=11)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    measured = empirical_loads(plan, alloc)
    base, unc = measured["coded"], measured["uncoded"]
    report("straggler_0", 0.0, f"coded={base:.4f} uncoded={unc:.4f}")
    for s in range(1, r):
        load = straggler_coded_load_plan(plan, tuple(range(s)))
        report(f"straggler_{s}", 0.0,
               f"load={load:.4f} overhead={load / base - 1:+.1%} "
               f"still<{'uncoded' if load < unc else 'UNCODED!'}")
