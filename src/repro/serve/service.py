"""Admission-batching request queue over one compiled coded-Shuffle session.

Serving shape: queries arrive one at a time, but the exchange is cheapest
per query when B of them ride one Shuffle (schedule bits are paid once per
payload column, never per compile). The queue therefore trades a bounded
admission delay (`max_wait_s`) for batch width (`max_batch`), exactly the
admission-batching pattern of inference servers.

Batches must share a program family and an iteration count to fuse into one
run, so the queue keeps one lane per (kind, iters) pair and admits from the
fullest lane first. Per admitted batch it builds the batched program
(`multi_sssp` over the collected roots, `personalized_pagerank` over the
stacked preference columns) and rebinds it on the session via
`CompiledEngine.with_program` - no plan recompile, no re-jit of the fused
exchange - then fans `state[:, b]` back to each caller's future.

Hardening (failure semantics, locked by `tests/test_serve.py`):

  * **per-query deadlines** - `submit(..., deadline_s=...)` queries that are
    still queued when their deadline lapses fail with `TimeoutError` at
    admission instead of riding (and paying for) the batch.
  * **batch bisection** - a failing batch is split in half and each half
    retried, recursively, so ONE poison query costs O(log B) extra runs and
    fails only its own future; every batchmate still resolves.
  * **fault injection** - a `faults.FaultSchedule` fires at admitted-batch
    boundaries: crashes swap in the repaired coded session
    (`CompiledEngine.fail` - still coded, no recompile-from-scratch),
    recovers swap the original back, stragglers re-price the runs.
  * **no stranded futures** - `close(wait=False)` cancels every queued
    future (callers see `CancelledError`, not a hang) while the in-flight
    batch still resolves; if the worker thread dies outside `_run_batch`,
    the error fans out to every queued future.
  * **live graph mutations** - `update(delta)` queues an `EdgeDelta` and
    resolves its future at the next batch boundary: the session is rebound
    incrementally (`CompiledEngine.update`, O(plan + delta), bitwise-equal
    to a fresh compile) with no serving gap, and a bad delta fails only its
    own future. Composes with crashes: the degraded session is re-derived
    from the mutated base.

`ServeStats` counts all of it (failures, expiries, retries, crashes,
recoveries) next to the throughput counters.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future

import numpy as np

from ..core import algorithms, engine
from ..core.allocation import Allocation
from ..core.graph_models import Graph
from ..core.shuffle_plan import ShufflePlan
from ..obs import MetricsRegistry, get_tracer

QUERY_KINDS = ("sssp", "ppr")


class ServeStats:
    """Service-lifetime counters, backed by an `obs.MetricsRegistry`.

    Reads keep the plain-attribute API (`stats.queries`, `stats.retries`,
    ...) but every counter lives in the registry under a `serve_*` metric
    name, so `stats.to_prometheus_text()` exposes the whole set - plus the
    per-query latency histogram (submit -> future resolution) behind
    `latency_p50` / `latency_p95` / `latency_p99`.

    All mutation goes through the `record_*` methods so each fact is
    counted in exactly one place - in particular `record_success` is the
    ONLY place `shuffle_bits` and `queries` grow, which is what keeps
    `bits_per_query` consistent under bisection retries (each successful
    half-batch run is counted exactly once; failed runs add nothing).
    """

    def __init__(self, registry: MetricsRegistry | None = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        r = self.registry
        self._queries = r.counter(
            "serve_queries_total", "queries resolved successfully")
        self._batches = r.counter(
            "serve_batches_total",
            "successful batched runs (incl. retry halves)")
        self._bits = r.counter(
            "serve_shuffle_bits_total", "shuffle bits over successful runs")
        self._failed = r.counter(
            "serve_failed_queries_total",
            "futures failed with the query's own error")
        self._expired = r.counter(
            "serve_expired_queries_total", "deadline lapsed while queued")
        self._retries = r.counter(
            "serve_retries_total", "bisection re-runs after a batch failure")
        self._mutations = r.counter(
            "serve_mutations_total", "graph deltas applied to the session")
        self._crashes = r.counter(
            "serve_crashes_total", "fault-schedule crash events applied")
        self._recoveries = r.counter(
            "serve_recoveries_total", "fault-schedule recover events applied")
        self._latency = r.histogram(
            "serve_query_latency_seconds",
            "submit-to-resolution latency of successful queries")

    # -- mutation (one method per fact) ---------------------------------
    def record_success(self, queries: int, shuffle_bits: int,
                       latencies_s=()) -> None:
        """One successful (sub-)batch run: its queries, its bits, once."""
        self._queries.inc(queries)
        self._batches.inc()
        self._bits.inc(shuffle_bits)
        for s in latencies_s:
            self._latency.observe(s)

    def record_failed(self) -> None:
        self._failed.inc()

    def record_expired(self) -> None:
        self._expired.inc()

    def record_retries(self, count: int) -> None:
        self._retries.inc(count)

    def record_mutation(self) -> None:
        self._mutations.inc()

    def record_crash(self) -> None:
        self._crashes.inc()

    def record_recovery(self) -> None:
        self._recoveries.inc()

    # -- reads (back-compat attribute API) ------------------------------
    @property
    def queries(self) -> int:
        return int(self._queries.value)

    @property
    def batches(self) -> int:
        return int(self._batches.value)

    @property
    def shuffle_bits(self) -> int:
        return int(self._bits.value)

    @property
    def failed_queries(self) -> int:
        return int(self._failed.value)

    @property
    def expired_queries(self) -> int:
        return int(self._expired.value)

    @property
    def retries(self) -> int:
        return int(self._retries.value)

    @property
    def mutations(self) -> int:
        return int(self._mutations.value)

    @property
    def crashes(self) -> int:
        return int(self._crashes.value)

    @property
    def recoveries(self) -> int:
        return int(self._recoveries.value)

    @property
    def mean_batch(self) -> float:
        """Realized amortization: queries served per Shuffle-sharing run."""
        return self.queries / self.batches if self.batches else 0.0

    @property
    def bits_per_query(self) -> float:
        return self.shuffle_bits / self.queries if self.queries else 0.0

    @property
    def latency_p50(self) -> float:
        return self._latency.quantile(0.50)

    @property
    def latency_p95(self) -> float:
        return self._latency.quantile(0.95)

    @property
    def latency_p99(self) -> float:
        return self._latency.quantile(0.99)

    def latency_percentiles(self) -> dict:
        return self._latency.percentiles((50, 95, 99))

    def to_prometheus_text(self) -> str:
        return self.registry.to_prometheus_text()

    def __repr__(self) -> str:
        return (f"ServeStats(queries={self.queries}, batches={self.batches}, "
                f"shuffle_bits={self.shuffle_bits}, "
                f"failed={self.failed_queries}, "
                f"expired={self.expired_queries}, retries={self.retries}, "
                f"mutations={self.mutations}, crashes={self.crashes}, "
                f"recoveries={self.recoveries})")


class GraphService:
    """Batched query server on one graph + allocation.

    Usage::

        with GraphService(g, alloc, max_batch=8, max_wait_s=0.005) as svc:
            futs = [svc.submit("sssp", root, iters=10) for root in roots]
            dists = [f.result() for f in futs]

    One background worker admits batches; `submit` is thread-safe and
    returns a `concurrent.futures.Future` resolving to that query's [n]
    result column. Query kinds: "sssp" (arg = root vertex id) and "ppr"
    (arg = [n] preference vector). `fault_schedule` injects deterministic
    crash/straggle/recover events at admitted-batch boundaries (see module
    docstring).
    """

    def __init__(self, g: Graph, alloc: Allocation, mode: str = "coded", *,
                 backend: str = "numpy", max_batch: int = 8,
                 max_wait_s: float = 0.005, plan: ShufflePlan | None = None,
                 backend_opts: dict | None = None, fault_schedule=None,
                 registry: MetricsRegistry | None = None, **opts):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
        merged = dict(backend_opts or {})
        merged.update(opts)
        # The session is compiled once against a placeholder program; every
        # admitted batch swaps its own program in via `with_program` (the
        # plan/tables/fused exchange never depend on it).
        self.session = engine.compile(
            algorithms.multi_sssp([0]), g, alloc, mode, path="sparse",
            backend=backend, plan=plan, backend_opts=merged)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.stats = ServeStats(registry)
        self._fault_schedule = fault_schedule
        self._fault_idx = 0
        self._batch_no = 0                    # admitted-batch boundary clock
        self._failed: set[int] = set()
        self._straggling: set[int] = set()
        self._active = self.session           # degraded session after crashes
        self._lanes: dict[tuple, collections.deque] = collections.defaultdict(
            collections.deque)
        self._mutations: collections.deque = collections.deque()
        self._inflight: list[Future] = []
        self._cv = threading.Condition()
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, name="graph-serve", daemon=True)
        self._worker.start()

    # -- client side -------------------------------------------------------

    def submit(self, kind: str, arg, iters: int = 10,
               deadline_s: float | None = None) -> Future:
        """Enqueue one query; returns a Future of its [n] result column.

        `deadline_s` bounds the time the query may sit in the queue: if it
        has not been admitted into a batch within that many seconds, its
        future fails with `TimeoutError` (counted in
        `stats.expired_queries`) instead of riding a late batch.
        """
        n = self.session.g.n
        if kind == "sssp":
            arg = int(arg)
            if not 0 <= arg < n:
                raise ValueError(f"sssp root {arg} out of range [0, {n})")
        elif kind == "ppr":
            arg = np.asarray(arg, dtype=np.float32)
            if arg.shape != (n,):
                raise ValueError(
                    f"ppr preference vector must be [n={n}]; got {arg.shape}")
        else:
            raise ValueError(
                f"unknown query kind {kind!r}; accepted: {QUERY_KINDS}")
        now = time.monotonic()
        deadline = None if deadline_s is None else now + float(deadline_s)
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("service is closed")
            self._lanes[(kind, int(iters))].append((arg, fut, deadline, now))
            self._cv.notify_all()
        return fut

    def update(self, delta) -> Future:
        """Enqueue one `graphs.EdgeDelta`; returns a Future of its
        `DeltaStats`.

        Mutations are admitted at batch boundaries only, in arrival order:
        batches already admitted run on the pre-mutation graph, every batch
        admitted after the future resolves runs on the mutated one. The
        session swap is the O(delta) incremental path
        (`CompiledEngine.update` - bitwise-equal to a fresh compile on the
        mutated graph, fused exchange re-lowered only if the partition
        shapes moved), so a mutation costs far less than the recompile it
        replaces. A bad delta (deleting an absent edge, inserting a present
        one) fails only its own future; the service keeps serving the
        un-mutated graph.
        """
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("service is closed")
            self._mutations.append((delta, fut))
            self._cv.notify_all()
        return fut

    def loads(self) -> dict[str, float]:
        """Schedule loads of the underlying session (per payload column)."""
        return self.session.loads()

    def close(self, *, wait: bool = True) -> None:
        """Stop admitting. `wait=True` drains already-queued queries and
        joins the worker; `wait=False` cancels every still-queued future
        (callers get `CancelledError` immediately) while the in-flight
        batch, if any, still resolves on the worker before it exits."""
        if wait:
            with self._cv:
                self._closed = True
                self._cv.notify_all()
            self._worker.join()
            return
        with self._cv:
            self._closed = True
            pending = [f for q in self._lanes.values() for _, f, _, _ in q]
            pending += [f for _, f in self._mutations]
            self._lanes.clear()
            self._mutations.clear()
            self._cv.notify_all()
        for f in pending:
            f.cancel()

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker side -------------------------------------------------------

    def _loop(self) -> None:
        try:
            self._loop_inner()
        except BaseException as e:
            # The worker is the only resolver; dying silently would strand
            # every queued caller on .result() forever. Fan the error out -
            # to the admitted-but-unresolved batch as well as the queues.
            with self._cv:
                self._closed = True
                pending = [f for q in self._lanes.values() for _, f, _, _ in q]
                pending += [f for _, f in self._mutations]
                pending += self._inflight
                self._lanes.clear()
                self._mutations.clear()
                self._inflight = []
                self._cv.notify_all()
            for f in pending:
                if not f.done():
                    f.set_exception(e)
            raise

    def _loop_inner(self) -> None:
        while True:
            with self._cv:
                while (not self._closed and not any(self._lanes.values())
                       and not self._mutations):
                    self._cv.wait()
                muts = list(self._mutations)
                self._mutations.clear()
            if muts:                          # batch boundary: swap session
                self._apply_mutations(muts)
            with self._cv:
                if not any(self._lanes.values()):
                    if self._closed and not self._mutations:
                        return
                    continue                  # lanes cleared under us
                lane = max(self._lanes, key=lambda k: len(self._lanes[k]))
                # Admission window: hold the batch open until it is full,
                # the timeout lapses, or the service is draining.
                deadline = time.monotonic() + self.max_wait_s
                while (not self._closed
                       and len(self._lanes[lane]) < self.max_batch):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(timeout=left)
                q = self._lanes.get(lane)
                if q is None:                 # close(wait=False) raced us
                    continue
                batch = [q.popleft()
                         for _ in range(min(self.max_batch, len(q)))]
                if not q:
                    del self._lanes[lane]
                self._inflight = [f for _, f, _, _ in batch]
            if batch:
                self._run_batch(lane, batch)
            with self._cv:
                self._inflight = []

    def _apply_mutations(self, muts: list) -> None:
        """Apply queued deltas in arrival order, between batches.

        Each delta rebinds the base session via `CompiledEngine.update`;
        with crashed servers the degraded serving session is re-derived
        from the updated base, so mutation and repair compose (delta-then-
        fail == fail-then-delta, the plan-level contract). A poison delta
        fails only its own future and leaves the session untouched.
        """
        for delta, fut in muts:
            if fut.cancelled():
                continue
            try:
                with get_tracer().span("serve.update",
                                       inserts=delta.num_insert,
                                       deletes=delta.num_delete):
                    session = self.session.update(delta)
                    self._active = (session if not self._failed
                                    else session.fail(
                                        tuple(sorted(self._failed))))
                    self.session = session
            except Exception as e:
                fut.set_exception(e)
            else:
                self.stats.record_mutation()
                fut.set_result(session.delta_stats)

    def _apply_faults(self) -> None:
        """Fire every not-yet-applied event at or before this boundary."""
        sched = self._fault_schedule
        if sched is None:
            return
        changed = False
        while (self._fault_idx < len(sched.events)
               and sched.events[self._fault_idx].at <= self._batch_no):
            ev = sched.events[self._fault_idx]
            self._fault_idx += 1
            new = set(ev.servers)
            if ev.kind == "crash":
                if new - self._failed:
                    self._failed |= new
                    self._straggling -= new
                    changed = True
                    self.stats.record_crash()
            elif ev.kind == "recover":
                if new & self._failed:
                    self._failed -= new
                    changed = True
                    self.stats.record_recovery()
                self._straggling -= new
            else:                             # "straggle"
                self._straggling |= new - self._failed
        if changed:
            self._active = (self.session if not self._failed
                            else self.session.fail(tuple(sorted(self._failed))))

    def _run_batch(self, lane: tuple, batch: list) -> None:
        kind, iters = lane
        now = time.monotonic()
        live = []
        for arg, fut, dl, ts in batch:
            if fut.cancelled():
                continue
            if dl is not None and now > dl:
                self.stats.record_expired()
                fut.set_exception(TimeoutError(
                    f"{kind} query expired after waiting past its deadline"))
            else:
                live.append((arg, fut, dl, ts))
        if not live:
            return
        self._apply_faults()
        self._batch_no += 1
        with get_tracer().span("serve.batch", kind=kind, iters=iters,
                               B=len(live), batch_no=self._batch_no):
            self._execute_split(kind, live, iters)

    def _execute_split(self, kind: str, entries: list, iters: int) -> None:
        """Run one (sub-)batch; on failure bisect and retry each half.

        A single poison query therefore reaches a singleton sub-batch after
        O(log B) retries, fails alone (`stats.failed_queries`), and every
        other future in the original batch still resolves. Bits accounting:
        `stats.record_success` fires once per *successful* run only - a
        failed run's bits are never recorded, and each half-batch retry
        records exactly its own run's bits - so `shuffle_bits` stays
        consistent with `queries`/`retries` no matter how deep the
        bisection goes.
        """
        futs = [f for _, f, _, _ in entries]
        try:
            res = self._execute(kind, [a for a, _, _, _ in entries], iters)
        except Exception as e:
            if len(entries) == 1:
                self.stats.record_failed()
                if not futs[0].cancelled():
                    futs[0].set_exception(e)
                return
            mid = len(entries) // 2
            self.stats.record_retries(2)
            with get_tracer().span("serve.retry", kind=kind,
                                   B=len(entries)):
                self._execute_split(kind, entries[:mid], iters)
                self._execute_split(kind, entries[mid:], iters)
            return
        done = time.monotonic()
        self.stats.record_success(
            len(entries), res.shuffle_bits,
            [done - ts for _, _, _, ts in entries])
        for b, f in enumerate(futs):
            if not f.cancelled():
                f.set_result(res.state[:, b])

    def _execute(self, kind: str, args: list, iters: int):
        """Build the batched program and run it on the current (possibly
        degraded) session. The seam fault tests monkeypatch."""
        if kind == "sssp":
            prog = algorithms.multi_sssp(list(args))
        else:
            prog = algorithms.personalized_pagerank(np.stack(args, axis=1))
        sched = None
        if self._straggling:
            from ..core.faults import FaultSchedule
            sched = FaultSchedule(
                [(0, "straggle", tuple(sorted(self._straggling)))])
        return self._active.with_program(prog).run(iters,
                                                   fault_schedule=sched)
