"""Counters, gauges, fixed-bucket histograms + a Prometheus-text exporter.

The serving layer's ``ServeStats`` is a *view* over a ``MetricsRegistry``:
every mutation (queries admitted, batches run, shuffle bits spent, retries,
crashes...) lands in exactly one named metric here, and the dataclass-like
attribute API the tests and callers use reads back out of the registry.
Histograms are fixed-bucket (log-spaced by default) so per-query latency
p50/p95/p99 come from linear interpolation inside the owning bucket —
the same estimator Prometheus' ``histogram_quantile`` uses.

Stdlib-only, thread-safe, no background machinery.
"""
from __future__ import annotations

import math
import threading

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "default_latency_buckets", "get_registry", "set_registry",
]


class Counter:
    """Monotonically increasing value."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        with self._lock:
            self._value += amount

    @property
    def value(self) -> float:
        return self._value

    def expose(self) -> str:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} counter")
        lines.append(f"{self.name} {_fmt(self._value)}")
        return "\n".join(lines)


class Gauge:
    """Value that can go up and down."""

    __slots__ = ("name", "help", "_value", "_lock")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, value: float) -> None:
        with self._lock:
            self._value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        with self._lock:
            self._value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)

    @property
    def value(self) -> float:
        return self._value

    def expose(self) -> str:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} gauge")
        lines.append(f"{self.name} {_fmt(self._value)}")
        return "\n".join(lines)


def default_latency_buckets() -> tuple:
    """Log-spaced seconds buckets, 10us .. ~100s (4 per decade)."""
    return tuple(
        round(10 ** (e / 4.0), 10) for e in range(-20, 9)
    )


class Histogram:
    """Fixed-bucket histogram with interpolated quantiles.

    ``buckets`` are the inclusive upper bounds of each bucket; a +Inf
    bucket is always appended. ``quantile(q)`` linearly interpolates
    inside the bucket that holds the q-th observation (Prometheus
    ``histogram_quantile`` semantics), so percentiles are estimates with
    bucket-width resolution — good enough for latency reporting without
    retaining every sample.
    """

    __slots__ = ("name", "help", "buckets", "_counts", "_sum", "_count", "_lock")

    def __init__(self, name: str, help: str = "", buckets=None):
        self.name = name
        self.help = help
        bounds = tuple(sorted(buckets)) if buckets else default_latency_buckets()
        if not bounds:
            raise ValueError("need at least one bucket bound")
        self.buckets = bounds
        self._counts = [0] * (len(bounds) + 1)  # last = +Inf
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        idx = _bucket_index(self.buckets, value)
        with self._lock:
            self._counts[idx] += 1
            self._sum += value
            self._count += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def quantile(self, q: float) -> float:
        """Estimated q-quantile (0 <= q <= 1); 0.0 when empty."""
        if not 0.0 <= q <= 1.0:
            raise ValueError(f"quantile {q} outside [0, 1]")
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = q * total
        cum = 0
        for i, c in enumerate(counts):
            if c == 0:
                continue
            if cum + c >= rank:
                lo = self.buckets[i - 1] if i > 0 else 0.0
                hi = self.buckets[i] if i < len(self.buckets) else self.buckets[-1]
                frac = (rank - cum) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
            cum += c
        return self.buckets[-1]

    def percentiles(self, ps=(50, 95, 99)) -> dict:
        return {f"p{p:g}": self.quantile(p / 100.0) for p in ps}

    def expose(self) -> str:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} histogram")
        cum = 0
        for bound, c in zip(self.buckets, self._counts):
            cum += c
            lines.append(f'{self.name}_bucket{{le="{_fmt(bound)}"}} {cum}')
        lines.append(f'{self.name}_bucket{{le="+Inf"}} {self._count}')
        lines.append(f"{self.name}_sum {_fmt(self._sum)}")
        lines.append(f"{self.name}_count {self._count}")
        return "\n".join(lines)


def _bucket_index(bounds: tuple, value: float) -> int:
    lo, hi = 0, len(bounds)
    while lo < hi:  # first bound >= value
        mid = (lo + hi) // 2
        if bounds[mid] >= value:
            hi = mid
        else:
            lo = mid + 1
    return lo


def _fmt(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class MetricsRegistry:
    """Named metrics, created on first use, exported as Prometheus text."""

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, cls, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(name, **kwargs)
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {type(m).__name__}"
                )
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "", buckets=None) -> Histogram:
        return self._get(name, Histogram, help=help, buckets=buckets)

    def get(self, name: str):
        return self._metrics.get(name)

    def names(self) -> list:
        with self._lock:
            return sorted(self._metrics)

    def to_prometheus_text(self) -> str:
        with self._lock:
            metrics = [self._metrics[n] for n in sorted(self._metrics)]
        return "\n".join(m.expose() for m in metrics) + ("\n" if metrics else "")

    def reset(self) -> None:
        with self._lock:
            self._metrics = {}


_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """The process-local default registry."""
    return _REGISTRY


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-local registry (tests); returns the previous one."""
    global _REGISTRY
    prev = _REGISTRY
    _REGISTRY = registry
    return prev
