"""Mamba2 block (SSD, arXiv:2405.21060) - prefill via the chunked dual form,
decode via O(1) state update. The Pallas kernel (kernels/ssd_scan) is the TPU
hot path; the model default is the mathematically identical pure-jnp chunked
form so dry-run HLO stays representative.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from .layers import ParamSpec, rms_norm


def ssm_spec(cfg: ModelConfig) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    return {
        # in_proj -> [x (di), z gate (di), B (N), C (N), dt (nh)]
        "in_proj": ParamSpec((d, 2 * di + 2 * s.d_state + nh), ("embed", "inner")),
        "conv_w": ParamSpec((s.conv_width, di + 2 * s.d_state), (None, "inner")),
        "dt_bias": ParamSpec((nh,), ("heads",), "ssm_dt"),
        "a_log": ParamSpec((nh,), ("heads",), "ssm_a"),
        "d_skip": ParamSpec((nh,), ("heads",), "ones"),
        "out_norm": ParamSpec((di,), ("inner",), "zeros"),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _split(cfg: ModelConfig, proj):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    x, z, B, C, dt = jnp.split(
        proj, [di, 2 * di, 2 * di + s.d_state, 2 * di + 2 * s.d_state], axis=-1)
    return x, z, B, C, dt, di, nh


def _causal_conv(u, w, state=None):
    """u [B, S, D]; w [W, D] depthwise. Returns (out, new_state [B, W-1, D])."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((u.shape[0], W - 1, u.shape[2]), u.dtype)
    padded = jnp.concatenate([state, u], axis=1)
    out = sum(padded[:, i:i + u.shape[1]] * w[i] for i in range(W))
    return jax.nn.silu(out), padded[:, -(W - 1):]


def _ssd_chunked_jnp(x, dt, A, B, C, D, h0, chunk):
    """Vectorized chunked SSD (same math as kernels/ssd_scan)."""
    g, L, p = x.shape
    n = B.shape[-1]
    ch = L // chunk
    xr = x.reshape(g, ch, chunk, p).astype(jnp.float32)
    dtr = dt.reshape(g, ch, chunk).astype(jnp.float32)
    br = B.reshape(g, ch, chunk, n).astype(jnp.float32)
    cr = C.reshape(g, ch, chunk, n).astype(jnp.float32)
    dta = dtr * A[:, None, None].astype(jnp.float32)
    cum = jnp.cumsum(dta, axis=-1)
    scores = jnp.einsum("gctn,gcsn->gcts", cr, br)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    # Mask inside the exp: the upper triangle would overflow (positive
    # exponents) and poison the backward pass via inf * 0.
    diff = cum[..., :, None] - cum[..., None, :]
    decay = jnp.exp(jnp.where(tri, diff, -1e30))
    m = scores * decay * dtr[..., None, :]
    y_intra = jnp.einsum("gcts,gcsp->gctp", m, xr)
    w = jnp.exp(cum[..., -1:] - cum) * dtr
    S = jnp.einsum("gctn,gctp->gcnp", br * w[..., None], xr)
    G = jnp.exp(cum[..., -1])
    Cexp = cr * jnp.exp(cum)[..., None]

    def combine(a, b):
        ga, sa = a
        gb, sb = b
        return ga * gb, gb[..., None, None] * sa + sb

    Gs, Ss = jax.lax.associative_scan(combine, (G, S), axis=1)
    h0 = jnp.zeros((g, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    h_in = jnp.concatenate(
        [h0[:, None], Gs[:, :-1, None, None] * h0[:, None] + Ss[:, :-1]], axis=1)
    y_inter = jnp.einsum("gctn,gcnp->gctp", Cexp, h_in)
    y = (y_intra + y_inter).reshape(g, L, p) + D[:, None, None] * x
    h_final = Gs[:, -1, None, None] * h0 + Ss[:, -1]
    return y, h_final


def mamba2_block(p, cfg: ModelConfig, u, *, state=None, use_kernel=False):
    """u [B, S, d_model] -> (y, (conv_state, ssm_state)).

    state: None for train, or (conv_state [B,W-1,di+2N], ssm_state [B,nh,N,P]).
    """
    s = cfg.ssm
    proj = jnp.einsum("btd,de->bte", u, p["in_proj"])
    x, z, B_, C_, dt, di, nh = _split(cfg, proj)
    conv_in = jnp.concatenate([x, B_, C_], axis=-1)
    conv_state = None if state is None else state[0]
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], conv_state)
    x, B_, C_ = jnp.split(conv_out, [di, di + s.d_state], axis=-1)

    Bsz, S, _ = u.shape
    P = s.head_dim
    dt_full = jax.nn.softplus(dt.astype(jnp.float32)
                              + p["dt_bias"].astype(jnp.float32))   # [B,S,nh]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))                    # [nh]
    xh = x.reshape(Bsz, S, nh, P)

    # Flatten (batch, head) into the scan group axis.
    xg = xh.transpose(0, 2, 1, 3).reshape(Bsz * nh, S, P)
    dtg = dt_full.transpose(0, 2, 1).reshape(Bsz * nh, S)
    Bg = jnp.broadcast_to(B_[:, None], (Bsz, nh, S, s.d_state)).reshape(
        Bsz * nh, S, s.d_state)
    Cg = jnp.broadcast_to(C_[:, None], (Bsz, nh, S, s.d_state)).reshape(
        Bsz * nh, S, s.d_state)
    Ag = jnp.tile(A, Bsz)
    Dg = jnp.tile(p["d_skip"].astype(jnp.float32), Bsz)
    h0 = None if state is None else state[1].reshape(Bsz * nh, s.d_state, P)

    if S == 1:                                   # decode: O(1) state update
        from ..kernels.ssd_scan.ops import ssd_decode_step
        if h0 is None:
            h0 = jnp.zeros((Bsz * nh, s.d_state, P), jnp.float32)
        y1, hT = ssd_decode_step(xg[:, 0].astype(jnp.float32), dtg[:, 0], Ag,
                                 Bg[:, 0].astype(jnp.float32),
                                 Cg[:, 0].astype(jnp.float32), Dg, h0)
        yg = y1[:, None]
    elif use_kernel:
        from ..kernels.ssd_scan.ops import ssd
        yg, hT = ssd(xg, dtg, Ag, Bg, Cg, Dg, h0, chunk=s.chunk)
    else:
        yg, hT = _ssd_chunked_jnp(xg.astype(jnp.float32), dtg, Ag,
                                  Bg.astype(jnp.float32),
                                  Cg.astype(jnp.float32), Dg, h0, s.chunk)

    y = yg.reshape(Bsz, nh, S, P).transpose(0, 2, 1, 3).reshape(Bsz, S, di)
    y = rms_norm(y.astype(u.dtype) * jax.nn.silu(z), p["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, p["out_proj"])
    new_ssm = hT.reshape(Bsz, nh, s.d_state, P)
    return out, (new_conv, new_ssm)


def empty_state(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    nh = s.n_heads(cfg.d_model)
    conv = jnp.zeros((batch, s.conv_width - 1, di + 2 * s.d_state), dtype)
    ssm = jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32)
    return conv, ssm
