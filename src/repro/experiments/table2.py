"""Reproduce the paper's Table II: measured loads on real datasets.

The paper's EC2 experiments report, per dataset and computation load r, the
measured communication loads of conventional (uncoded) and coded PageRank -
the empirical face of the Theorem-1 inverse-linear trade-off. This harness
is that measurement, dense-free end to end:

    registry.load -> pad to the allocation's divisible n -> compile ONE
    CSR plan per (dataset, r) -> read both Definition-2 loads off it.

Bits-on-the-wire are schedule-only, so no data moves; everything is
O(edges) (`compile_plan_csr` + `loads.empirical_loads`), which is what lets
soc-Epinions1 (~76k vertices, ~500k edges) run where the dense path died at
`dense_limit`. Each row carries the closed-form ER overlays evaluated at
the dataset's empirical density - `uncoded_load_er`,
`coded_load_er_asymptotic`, `coded_load_er_finite`, `lower_bound_er` - so
measured gains are checked against the paper's theory curves the same way
its Table II columns sit next to its analytical section. Results are
emitted as JSON records plus a markdown table (see `to_markdown` /
`main`). The paper's own reported cells can be pinned per dataset via
`Dataset.note`-adjacent metadata once transcribed; the quantitative gate
here is the closed-form match.
"""
from __future__ import annotations

import json
import pathlib
import time

from ..core import loads
from ..core.allocation import er_allocation
from ..core.shuffle_plan import compile_plan_csr
from . import registry

__all__ = ["run_table2", "to_markdown", "main"]


def run_table2(datasets=("karate",), K: int = 6, r_grid=(1, 2, 3),
               cache_dir=None, download: bool | None = None,
               interleave: bool = True, validate: bool = False,
               report=None) -> dict:
    """Measured + closed-form loads for each (dataset, r) cell.

    One CSR plan compile per cell; `interleave=True` spreads batches
    round-robin (the refinement that homogenizes per-group row sizes on
    non-ER degree profiles - real graphs are closer to power-law than ER).
    Returns ``{"K": K, "rows": [...]}``; `report(tag, seconds, text)`
    mirrors the benchmark-driver callback when given.
    """
    rows = []
    for name in datasets:
        t0 = time.perf_counter()
        g = registry.load(name, cache_dir=cache_dir, download=download)
        t_load = time.perf_counter() - t0
        for r in r_grid:
            alloc = er_allocation(g.n, K, r, interleave=interleave, pad=True)
            g2 = g.padded(alloc.n)
            t0 = time.perf_counter()
            plan = compile_plan_csr(g2.csr, alloc, validate=validate)
            t_compile = time.perf_counter() - t0
            measured = loads.empirical_loads(plan, alloc)
            p = g2.density                      # empirical nnz / n_pad^2
            cell = registry.DATASETS[name].paper_cell(r)
            row = {
                "dataset": name, "K": K, "r": r,
                "n": g.n, "n_padded": alloc.n, "edges": g.num_edges,
                "density": p,
                "uncoded": measured["uncoded"],
                "coded": measured["coded"],
                "coded_leftover_unicast": measured["coded_leftover_unicast"],
                "gain": measured["gain"],
                "uncoded_er": loads.uncoded_load_er(p, r, K),
                "coded_er_asymptotic": loads.coded_load_er_asymptotic(p, r, K),
                "coded_er_finite": loads.coded_load_er_finite(alloc.n, p, r, K),
                "lower_bound_er": loads.lower_bound_er(p, r, K),
                # Paper's literal Table II cells (EC2 running-time
                # speedups), where reported for this (dataset, r).
                "paper_shuffle_speedup": cell.shuffle_speedup if cell
                else None,
                "paper_overall_speedup": cell.overall_speedup if cell
                else None,
                "load_s": t_load, "compile_s": t_compile,
            }
            rows.append(row)
            if report is not None:
                report(f"table2_{name}_r{r}", t_compile * 1e6,
                       f"uncoded={row['uncoded']:.5f} coded={row['coded']:.5f} "
                       f"gain={row['gain']:.2f} (theory r={r})")
    return {"K": K, "rows": rows}


def to_markdown(result: dict) -> str:
    """Table II-style markdown: measured loads next to the theory overlay
    and the paper's own reported EC2 speedups (where transcribed)."""
    lines = [
        f"Measured communication loads (Definition 2, K={result['K']}) vs "
        f"the ER closed forms at each dataset's empirical density. The two "
        f"`paper` columns are the literal Table II cells (EC2 Shuffle-time "
        f"and overall-time speedups) from arXiv 1801.05522, printed beside "
        f"the measured gain; `-` where the paper reports no cell.",
        "",
        "| dataset | n | edges | r | L_uncoded | L_coded | gain | "
        "L_uc theory | L_c finite-n | paper shuffle x | paper overall x |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for row in result["rows"]:
        psx = row.get("paper_shuffle_speedup")
        pox = row.get("paper_overall_speedup")
        paper = (f"{psx:.2f} | {pox:.2f}" if psx is not None else "- | -")
        lines.append(
            f"| {row['dataset']} | {row['n']} | {row['edges']} | {row['r']} "
            f"| {row['uncoded']:.5f} | {row['coded']:.5f} "
            f"| {row['gain']:.2f} "
            f"| {row['uncoded_er']:.5f} | {row['coded_er_finite']:.5f} "
            f"| {paper} |")
    return "\n".join(lines) + "\n"


def main(argv: list[str] | None = None) -> int:
    """CLI: ``python -m repro.experiments.table2 --datasets karate ...``"""
    import argparse

    ap = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    ap.add_argument("--datasets", nargs="+", default=["karate"],
                    help="registered dataset names (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="list registered datasets and exit")
    ap.add_argument("--K", type=int, default=6, help="number of servers")
    ap.add_argument("--r", type=int, nargs="+", default=[1, 2, 3],
                    metavar="R", help="computation-load grid")
    ap.add_argument("--cache-dir", default=None,
                    help="dataset cache (default $REPRO_DATA_DIR or "
                         "~/.cache/repro-graphs)")
    ap.add_argument("--download", action="store_true",
                    help="allow network fetches of uncached SNAP datasets "
                         "(also $REPRO_DOWNLOAD=1)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows as JSON")
    ap.add_argument("--markdown", default=None, metavar="PATH",
                    help="write the markdown table")
    args = ap.parse_args(argv)

    if args.list:
        for name, ds in sorted(registry.DATASETS.items()):
            stats = (f"{ds.vertices} vertices, {ds.edges} edges (published)"
                     if ds.vertices else "")
            print(f"{name:<18} {ds.kind:<9} {stats}")
            if ds.note:
                print(f"{'':<18} {ds.note}")
        return 0

    def report(tag, us, derived):
        print(f"{tag},{us:.1f},{derived}", flush=True)

    result = run_table2(args.datasets, K=args.K, r_grid=tuple(args.r),
                        cache_dir=args.cache_dir,
                        download=args.download or None, report=report)
    md = to_markdown(result)
    print("\n" + md)
    if args.json:
        pathlib.Path(args.json).write_text(json.dumps(result, indent=2))
    if args.markdown:
        pathlib.Path(args.markdown).write_text(md)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
