"""Shared neural layers: declarative params, RMSNorm, RoPE, GQA attention
(global/local, softcap, bidirectional), chunked flash-style prefill, GeGLU.

Params are declared as ParamSpec trees (one source of truth for shape,
logical axes and init), so sharding rules and checkpointing never drift from
the model code.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]     # logical axis names, len == len(shape)
    init: str = "normal"             # normal | zeros | ones | ssm_dt | ssm_a

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def init_params(spec, key, dtype=jnp.bfloat16):
    leaves, treedef = jax.tree.flatten(spec, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, p in zip(keys, leaves):
        if p.init == "zeros":
            v = jnp.zeros(p.shape, dtype)
        elif p.init == "ones":
            v = jnp.ones(p.shape, dtype)
        elif p.init == "ssm_dt":
            v = jnp.log(jnp.expm1(jax.random.uniform(k, p.shape, jnp.float32,
                                                     0.001, 0.1))).astype(dtype)
        elif p.init == "ssm_a":
            v = jnp.log(jax.random.uniform(k, p.shape, jnp.float32, 1.0, 16.0)
                        ).astype(dtype)
        else:
            fan_in = p.shape[0] if len(p.shape) > 1 else p.shape[-1]
            v = (jax.random.normal(k, p.shape, jnp.float32)
                 / math.sqrt(fan_in)).astype(dtype)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def axes_tree(spec):
    return jax.tree.map(lambda p: p.axes, spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def abstract_params(spec, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, dtype), spec,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


# ---------------- primitives ----------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x [..., S, H, D]; positions [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freq       # [..., S, half]
    sin, cos = jnp.sin(ang)[..., None, :], jnp.cos(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


def _softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


def _mask(qpos, kpos, *, causal: bool, window):
    """[..., Sq, Sk] bool validity mask from absolute positions.

    window may be None (global), a python int, or a traced scalar where
    values <= 0 mean global (the per-layer local/global pattern rides
    through lax.scan as an int array with -1 = global)."""
    diff = qpos[..., :, None] - kpos[..., None, :]
    m = jnp.ones(diff.shape, bool) if not causal else diff >= 0
    if window is not None:
        m &= jnp.where(window > 0, diff < window, True)
    return m


def attend(q, k, v, qpos, kpos, *, causal=True, window=None, softcap=None,
           kv_valid=None, kt=None, vt=None):
    """q [B,Sq,H,D]; k/v [B,Sk,G,D] (G kv heads, H % G == 0). fp32 softmax.

    kt [B,G,D,Sk] / vt [B,G,Sk,Dv]: optional pre-transposed k/v so callers
    looping over query chunks hoist the layout change out of the loop
    (PERF: gemma2/train_4k iter 3 - XLA re-copied k/v per chunk trip).
    """
    B, Sq, H, D = q.shape
    G = (k if k is not None else kt).shape[2 if kt is None else 1]
    qg = q.reshape(B, Sq, G, H // G, D)
    if kt is None:
        kt = k.transpose(0, 2, 3, 1)
    if vt is None:
        vt = v.transpose(0, 2, 1, 3)
    # Explicit f32 upcast: XLA-CPU cannot *execute* a raw bf16xbf16->f32 dot
    # thunk in some fusion contexts (hybrid stacks hit it); on TPU the
    # converts fold into the native mixed-precision MXU dot.
    scores = jnp.einsum("bqghd,bgdk->bghqk", qg.astype(jnp.float32),
                        kt.astype(jnp.float32))
    scores = _softcap(scores / math.sqrt(D), softcap)
    m = _mask(qpos, kpos, causal=causal, window=window)[:, None, None]
    if kv_valid is not None:
        m &= kv_valid[:, None, None, None, :]
    scores = jnp.where(m, scores, -1e30)
    w = jax.nn.softmax(scores.astype(jnp.float32), axis=-1)
    out = jnp.einsum("bghqk,bgkd->bqghd", w.astype(vt.dtype), vt)
    return out.reshape(B, Sq, H, vt.shape[-1])  # v head dim may differ (MLA)


def chunked_attend(q, k, v, qpos, kpos, *, chunk=1024, **kw):
    """Flash-style prefill: scan over query chunks so the score tile is
    [B, H, chunk, Sk] instead of [B, H, S, S] (fits VMEM/HBM at 32k).

    The chunk body is itself rematerialized (PERF: gemma2/train_4k iter 2) -
    otherwise the backward saves every chunk's f32 score tile (the single
    largest HBM stream in the whole train step); recomputing scores in the
    chunk backward is the flash-attention trade and compute has headroom.
    """
    B, S, H, D = q.shape
    if S <= chunk:
        return attend(q, k, v, qpos, kpos, **kw)
    assert S % chunk == 0, (S, chunk)
    nq = S // chunk
    qs = q.reshape(B, nq, chunk, H, D).transpose(1, 0, 2, 3, 4)
    ps = qpos.reshape(B, nq, chunk).transpose(1, 0, 2)
    kt = k.transpose(0, 2, 3, 1)     # hoisted out of the chunk loop
    vt = v.transpose(0, 2, 1, 3)

    @jax.checkpoint
    def body(_, qc_pc):
        qc, pc = qc_pc
        return None, attend(qc, None, None, pc, kpos, kt=kt, vt=vt, **kw)

    _, outs = jax.lax.scan(body, None, (qs, ps))
    return outs.transpose(1, 0, 2, 3, 4).reshape(B, S, H, vt.shape[-1])


def geglu(x, w_gate, w_up, w_down, act: str = "silu"):
    """Gated MLP: (act(x W_g) * (x W_u)) W_d."""
    g = jnp.einsum("btd,df->btf", x, w_gate)
    u = jnp.einsum("btd,df->btf", x, w_up)
    a = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("btf,fd->btd", a * u, w_down)


def cross_entropy(logits, labels, vocab, softcap=None):
    logits = _softcap(logits.astype(jnp.float32), softcap)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, vocab, dtype=jnp.float32)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))
