"""Per-architecture smoke tests (reduced configs, one step on CPU) plus
decode-vs-forward consistency for every family."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, ShapeSpec, cell_supported
from repro.models import decode as dec
from repro.models import transformer as tfm
from repro.models.layers import init_params

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=16, with_labels=True):
    k1, k2 = jax.random.split(KEY)
    if cfg.frontend == "audio":
        b = {"frames": jax.random.normal(k1, (B, S, cfg.d_model), jnp.bfloat16)}
        if with_labels:
            b["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab)
        return b
    if cfg.frontend == "vision":
        st = S - cfg.num_patches
        b = {"patches": jax.random.normal(k1, (B, cfg.num_patches, cfg.d_model),
                                          jnp.bfloat16),
             "tokens": jax.random.randint(k2, (B, st), 0, cfg.vocab)}
        if with_labels:
            b["labels"] = jax.random.randint(k2, (B, st), 0, cfg.vocab)
        return b
    b = {"tokens": jax.random.randint(k1, (B, S), 0, cfg.vocab)}
    if with_labels:
        b["labels"] = jax.random.randint(k2, (B, S), 0, cfg.vocab)
    return b


@pytest.fixture(scope="module")
def reduced_models():
    out = {}
    for name, full in configs.ARCHS.items():
        cfg = full.reduced()
        out[name] = (cfg, init_params(tfm.model_spec(cfg), KEY))
    return out


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_forward_loss_finite(reduced_models, name):
    cfg, params = reduced_models[name]
    loss = tfm.loss_fn(params, cfg, make_batch(cfg), remat=False, chunk=8)
    assert np.isfinite(float(loss))
    assert 0.0 < float(loss) < 2.0 * np.log(cfg.vocab)


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_train_step_updates_params(reduced_models, name):
    """One SGD step must change params and reduce nothing to NaN."""
    cfg, params = reduced_models[name]
    batch = make_batch(cfg)
    grads = jax.grad(lambda p: tfm.loss_fn(p, cfg, batch, remat=True,
                                           chunk=8))(params)
    flat = jax.tree.leaves(grads)
    assert all(np.isfinite(np.asarray(g, dtype=np.float32)).all() for g in flat)
    assert any(float(jnp.abs(g.astype(jnp.float32)).max()) > 0 for g in flat)


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_decode_matches_forward(reduced_models, name):
    """Greedy token-by-token decode logits == full forward logits."""
    cfg, params = reduced_models[name]
    if cfg.encoder_only or cfg.frontend is not None:
        pytest.skip("decode consistency applies to pure-LM decode paths")
    B, S = 2, 8
    batch = make_batch(cfg, B, S, with_labels=False)
    full_logits = tfm.forward(params, cfg, batch, remat=False, chunk=8)
    cache = dec.init_cache(cfg, ShapeSpec("t", S, B, "decode"))
    for t in range(S):
        logits_t, cache = dec.decode_step(
            params, cfg, cache, {"tokens": batch["tokens"][:, t:t + 1]})
        want = np.asarray(full_logits[:, t], np.float32)
        if cfg.logit_softcap:
            want = cfg.logit_softcap * np.tanh(want / cfg.logit_softcap)
        np.testing.assert_allclose(np.asarray(logits_t), want,
                                   rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_param_count_within_family_budget(name):
    """Full configs land near their advertised sizes."""
    cfg = configs.ARCHS[name]
    targets = {
        "llama4-maverick-400b-a17b": 400e9, "deepseek-v2-236b": 236e9,
        "internlm2-20b": 20e9, "gemma2-27b": 27e9, "gemma3-27b": 27e9,
        "gemma-7b": 8.5e9, "zamba2-1.2b": 1.2e9, "mamba2-370m": 0.37e9,
        "hubert-xlarge": 1.0e9, "internvl2-1b": 0.9e9,
    }
    assert cfg.param_count() == pytest.approx(targets[name], rel=0.5)


def test_cell_support_matrix():
    """40 cells = 31 runnable + 9 documented skips (DESIGN.md §4)."""
    runnable = skips = 0
    for cfg in configs.ARCHS.values():
        for shape in SHAPES.values():
            ok, why = cell_supported(cfg, shape)
            runnable += ok
            skips += not ok
            if not ok:
                assert why
    assert runnable == 31 and skips == 9


@pytest.mark.parametrize("name", sorted(configs.ARCHS))
def test_input_specs_are_abstract(name):
    from repro.configs.base import input_specs
    cfg = configs.ARCHS[name]
    for shape in SHAPES.values():
        if not cell_supported(cfg, shape)[0]:
            continue
        specs = input_specs(cfg, shape)
        assert all(isinstance(v, jax.ShapeDtypeStruct) for v in specs.values())
