"""Gradient compression for the data-parallel all-reduce (int8 + error
feedback), as a shard_map building block.

Standard pjit lets XLA emit fp32/bf16 gradient all-reduces. For
bandwidth-starved interconnects (the paper's whole premise!) we instead
compute per-device gradients inside shard_map, quantize to int8 with a
per-tensor scale, psum the int8 payload (4x fewer bytes on the wire than
fp32), dequantize, and keep the quantization residual locally as error
feedback (Seide et al. / EF-SGD lineage) so the bias vanishes over steps.

`compressed_psum_mean` is the wire primitive; `ef_compress`/`ef_state` wrap
it with the feedback buffer. tests/test_compression.py validates convergence
parity with the uncompressed path on a real multi-device mesh.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """fp -> (int8 payload, fp32 scale). Symmetric per-tensor."""
    x = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Mean-all-reduce of x over `axis_name` moving int8 on the wire.

    int8 payloads are summed in int32 (no overflow for <=2^23 devices);
    scales are psum'd so each shard dequantizes against the global scale sum
    - exact for the sum of per-shard quantized tensors.
    """
    q, scale = quantize(x)
    qsum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    # Each shard quantized with its own scale; reconstruct sum of shards by
    # scaling with the *per-shard* scale before psum instead would double the
    # wire bytes - so we conservatively use a shared max scale.
    scale_max = jax.lax.pmax(scale, axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    # Requantize locally against the shared scale for exact decode.
    q2 = jnp.clip(jnp.round(x.astype(jnp.float32) / scale_max), -127, 127)
    qsum = jax.lax.psum(q2.astype(jnp.int32), axis_name)
    return qsum.astype(jnp.float32) * scale_max / n


def ef_state(params) -> dict:
    """Error-feedback residual buffer, congruent with params."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def ef_compress_tree(grads, residual, axis_name: str):
    """Apply error feedback + compressed mean-psum to a gradient pytree.

    Returns (reduced_grads, new_residual): residual carries this round's
    quantization error into the next step.
    """
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        reduced = compressed_psum_mean(corrected, axis_name)
        # Local error: what this shard failed to transmit.
        q, scale = quantize(corrected)
        new_r = corrected - dequantize(q, scale)
        return reduced, new_r

    out = jax.tree.map(one, grads, residual)
    reduced = jax.tree.map(lambda t: t[0], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda x: isinstance(x, tuple))
    return reduced, new_res


def wire_bytes(params, compressed: bool) -> int:
    """Per-step gradient bytes on the interconnect per device (accounting)."""
    total = sum(int(jnp.size(p)) for p in jax.tree.leaves(params))
    return total * (1 if compressed else 4)
