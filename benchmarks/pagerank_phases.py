"""Paper Fig. 7 / Remark 10: per-phase execution model of coded PageRank.

Measures actual wall time of Map (kernelized SpMV) and Shuffle (bit volume /
modeled link bandwidth) per r, fits T(r) = r T_map + T_shuffle / r + T_red,
and reports the best r against the r* = sqrt(Ts/Tm) heuristic."""
import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.loads import optimal_r, total_time_model
from repro.kernels.spmv import ops as spmv_ops

# Modeled phase costs (deterministic; wall-clock interpret-mode timings vary
# 10x run-to-run on this CPU). Both constants model the paper's EC2 regime:
# Python-rate per-edge Map work and a Shuffle-dominant 100Mbps-class link
# scaled to the n=300 validation graph.
LINK_BYTES_PER_SEC = 1.25e5
PER_EDGE_MAP_S = 1e-5


def run(report):
    K, p = 5, 0.12
    n = divisible_n(300, K, 2)
    g = gm.erdos_renyi(n, p, seed=3)
    prog = algo.pagerank()

    # Map phase: measure the kernelized SpMV (reported for reference), but
    # the T(r) model uses the deterministic per-edge cost above.
    adj = jnp.array(g.adj, jnp.float32)
    rank = jnp.array(prog.init(g))
    spmv_ops.pagerank_step(adj, rank).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(3):
        spmv_ops.pagerank_step(adj, rank).block_until_ready()
    spmv_us = (time.perf_counter() - t0) / 3 * 1e6
    t_map1 = g.num_edges / K * PER_EDGE_MAP_S            # per-server share
    report("map_phase_spmv", spmv_us, f"n={n} modeled_t_map={t_map1:.4f}s")

    rows = []
    for r in range(1, K + 1):
        alloc = er_allocation(n, K, r)
        res = engine.run(prog, g, alloc, 1, mode="coded-fast")
        shuffle_bytes = res.shuffle_bits / 8
        t_shuffle = shuffle_bytes / LINK_BYTES_PER_SEC
        t_total = r * t_map1 + t_shuffle
        rows.append((r, t_total))
        report(f"fig7_total_r{r}", t_total * 1e6,
               f"shuffle_s={t_shuffle:.4f}")
    best_r = min(rows, key=lambda t: t[1])[0]
    alloc1 = er_allocation(n, K, 1)
    s1 = engine.run(prog, g, alloc1, 1, "uncoded").shuffle_bits / 8 / LINK_BYTES_PER_SEC
    r_star = optimal_r(t_map1, s1)
    report("remark10_r_star", 0.0,
           f"best_measured_r={best_r} r_star={r_star:.2f}")
    return {"best_r": best_r, "r_star": r_star}
