"""Distributed MapReduce-on-graph engine (paper §II-B execution model).

Simulates K servers bit-faithfully: each server Maps its subgraph M_k, the
Shuffle phase moves exactly the bits the chosen scheme prescribes, and each
server Reduces R_k using *only* locally-Mapped plus delivered values. Any
divergence from the single-machine oracle is therefore a real bug in the
allocation or coding logic, not a modeling artifact.

Modes:
  single      - oracle, no distribution.
  uncoded     - baseline unicast shuffle   (load ~ p(1 - r/K)).
  coded       - paper's XOR multicast      (load ~ p(1 - r/K)/r), bit-exact.
  coded-fast  - same schedule/loads via coded_load(), values moved directly
                (skips the per-bit XOR simulation; used for large sweeps).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import VertexProgram
from .allocation import Allocation
from .bitcodec import T_BITS
from .coded_shuffle import coded_load, run_coded
from .graph_models import Graph
from .uncoded_shuffle import missing_pairs, run_uncoded


@dataclasses.dataclass
class EngineResult:
    state: np.ndarray
    iters: int
    shuffle_bits: int            # total over all iterations
    mode: str

    @property
    def normalized_load(self) -> float:
        """Average per-iteration Definition-2 load."""
        n = self.state.shape[0]
        return self.shuffle_bits / max(self.iters, 1) / (n * n * T_BITS)


def _reduce_distributed(program: VertexProgram, g: Graph, alloc: Allocation,
                        values: np.ndarray,
                        delivered: dict[int, dict[tuple[int, int], float]],
                        state: np.ndarray) -> np.ndarray:
    """Each server Reduces its rows from local columns + delivered values."""
    new_state = np.empty_like(state)
    for k in range(alloc.K):
        vk = np.full((g.n, g.n), program.identity, dtype=np.float32)
        cols = alloc.map_sets[k]
        vk[:, cols] = values[:, cols]                  # locally Mapped
        for (i, j), v in delivered[k].items():
            vk[i, j] = v
        rk = alloc.reduce_owner == k
        # Verify the server really has everything it needs (catches schedule bugs).
        need = g.adj & rk[:, None]
        have = cols[None, :] | np.zeros((g.n, g.n), dtype=bool)
        for (i, j) in delivered[k]:
            have[i, j] = True
        if (need & ~have).any():
            miss = np.argwhere(need & ~have)[:5]
            raise RuntimeError(f"server {k} missing values, e.g. {miss.tolist()}")
        reduced = program.reduce(vk, g.adj, state, g)
        new_state[rk] = reduced[rk]
    return new_state


def run(program: VertexProgram, g: Graph, alloc: Allocation | None,
        iters: int, mode: str = "coded") -> EngineResult:
    state = program.init(g)
    total_bits = 0
    for _ in range(iters):
        values = program.map_values(g, state).astype(np.float32)
        if mode == "single" or alloc is None:
            state = program.reduce(values, g.adj, state, g)
            continue
        if mode == "uncoded":
            res = run_uncoded(g.adj, values, alloc)
            delivered, bits = res.delivered, res.bits_sent
        elif mode == "coded":
            res = run_coded(g.adj, values, alloc)
            delivered, bits = res.delivered, res.bits_sent
            bits += _unicast_leftovers(g, alloc, values, delivered)
        elif mode == "coded-fast":
            delivered = {k: {} for k in range(alloc.K)}
            for k in range(alloc.K):
                for i, j in missing_pairs(g.adj, alloc, k):
                    delivered[k][(int(i), int(j))] = float(values[i, j])
            bits = int(round(coded_load(g.adj, alloc) * g.n * g.n * T_BITS))
        else:
            raise ValueError(f"unknown mode {mode!r}")
        total_bits += bits
        state = _reduce_distributed(program, g, alloc, values, delivered, state)
    return EngineResult(state, iters, total_bits, mode)


def _unicast_leftovers(g: Graph, alloc: Allocation, values: np.ndarray,
                       delivered: dict[int, dict[tuple[int, int], float]]) -> int:
    """Unicast whatever the coded groups did not cover (e.g. the phase-III
    spill Reducers of the bi-partite allocation, Appendix A)."""
    bits = 0
    for k in range(alloc.K):
        for i, j in missing_pairs(g.adj, alloc, k):
            if (int(i), int(j)) not in delivered[k]:
                delivered[k][(int(i), int(j))] = float(values[i, j])
                bits += T_BITS
    return bits
