"""Straggler tolerance: coded load degradation as senders straggle
(the CDC-lineage property the r-fold Map redundancy buys; DESIGN.md SS5)."""
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.coded_shuffle import coded_load
from repro.core.faults import straggler_coded_load
from repro.core.uncoded_shuffle import uncoded_load


def run(report):
    K, r, p = 6, 3, 0.15
    n = divisible_n(240, K, r)
    g = gm.erdos_renyi(n, p, seed=11)
    alloc = er_allocation(n, K, r)
    base = coded_load(g.adj, alloc)
    unc = uncoded_load(g.adj, alloc)
    report("straggler_0", 0.0, f"coded={base:.4f} uncoded={unc:.4f}")
    for s in range(1, r):
        load = straggler_coded_load(g.adj, alloc, tuple(range(s)))
        report(f"straggler_{s}", 0.0,
               f"load={load:.4f} overhead={load / base - 1:+.1%} "
               f"still<{'uncoded' if load < unc else 'UNCODED!'}")
