"""Theorems 1-4: the inverse-linear computation<->communication trade-off on
all four random graph models (measured coded gain vs r).

Graphs come from the streaming `repro.graphs` samplers and loads are read
off one CSR-compiled ShufflePlan per realization
(`loads.empirical_loads(g, alloc)`) instead of separate subset-enumeration
and per-server scans - no `.adj` anywhere, so the sweep scales past
`dense_limit` by just raising `base`."""
import numpy as np

from repro import graphs, obs
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.loads import empirical_loads

SAMPLES = 3


def _measure(report, tag, gs, alloc):
    lu, lc = [], []
    with obs.stopwatch() as sw:
        for g in gs:
            measured = empirical_loads(g, alloc)
            lu.append(measured["uncoded"])
            lc.append(measured["coded"])
    us = sw.us / len(gs)
    gain = np.mean(lu) / np.mean(lc) if np.mean(lc) else float("nan")
    report(tag, us, f"uncoded={np.mean(lu):.4f} coded={np.mean(lc):.4f} "
           f"gain={gain:.2f}")
    return gain


def run(report, smoke=False):
    K = 6
    base, samples = (60, 1) if smoke else (240, SAMPLES)
    out = {}
    for r in (2, 3):
        # ER (Theorem 1)
        n = divisible_n(base, K, r)
        alloc = er_allocation(n, K, r)
        gs = [graphs.erdos_renyi(n, 0.15, seed=s) for s in range(samples)]
        out[f"er_r{r}"] = _measure(report, f"thm1_er_r{r}", gs, alloc)
        # RB (Theorem 2) - balanced clusters, Appendix-A allocation.
        n1 = n2 = divisible_n(base // 2, K // 2, min(r, K // 2))
        ab = bipartite_allocation(n1, n2, K, r)
        gs = [graphs.random_bipartite(n1, n2, 0.2, seed=s)
              for s in range(samples)]
        out[f"rb_r{r}"] = _measure(report, f"thm2_rb_r{r}", gs, ab)
        # SBM (Theorem 3) - union ER allocation (interleaved batches).
        nn = divisible_n(base, K, r)
        sa = er_allocation(nn, K, r, interleave=True)
        gs = [graphs.stochastic_block(nn // 2, nn // 2, 0.25, 0.08, seed=s)
              for s in range(samples)]
        out[f"sbm_r{r}"] = _measure(report, f"thm3_sbm_r{r}", gs, sa)
        # PL (Theorem 4) - gamma > 2.
        ga = er_allocation(nn, K, r, interleave=True)
        gs = [graphs.power_law(nn, 2.5, seed=s) for s in range(samples)]
        out[f"pl_r{r}"] = _measure(report, f"thm4_pl_r{r}", gs, ga)
    return out
