"""Trip-aware cost analysis of optimized HLO text.

XLA's compiled.cost_analysis() counts every while-loop (lax.scan) body ONCE,
which under-reports FLOPs/bytes/collective traffic by the trip count - fatal
for scan-over-layers models (48-62x off). This module parses the optimized
HLO, recovers each while loop's trip count from its condition computation,
propagates multipliers down the call graph, and accumulates:

  * FLOPs: dot ops (2 * prod(output dims) * prod(contracting dims)) - matmuls
    dominate >99% of model FLOPs; elementwise is ignored like most rooflines.
  * HBM bytes: operand+output sizes of top-level (post-fusion) instructions in
    non-inlined computations - the standard post-fusion traffic approximation.
  * Collective bytes: output shapes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, trip-multiplied.

Verified against unrolled references in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_VIEW_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "iota"}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")


def _shape_dims(type_str: str) -> list[tuple[str, list[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dtype, dims = m.groups()
        out.append((dtype, [int(d) for d in dims.split(",") if d]))
    return out


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _shape_dims(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    op: str
    operands: list[str]
    rest: str              # raw text after the opcode's '('


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    instrs: list[Instr]
    shapes: dict[str, str]


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            cur = Computation(mc.group(2), bool(mc.group(1)), [], {})
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, type_str, op, rest = mi.groups()
        # Operands: %names before the first ')' (operand lists never nest).
        arg_str = rest.split(")")[0]
        operands = re.findall(r"%([\w.\-]+)", arg_str)
        instr = Instr(name, type_str, op, operands, rest)
        cur.instrs.append(instr)
        cur.shapes[name] = type_str
    return comps


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition ~ scan length."""
    best = 1
    for ins in cond.instrs:
        for m in re.finditer(r"constant\((\d+)\)", f"{ins.op}({ins.rest}"):
            best = max(best, int(m.group(1)))
    return best


def _callees(ins: Instr) -> list[tuple[str, str]]:
    """[(computation, kind)] referenced by this instruction."""
    out = []
    for key, kind in (("body", "while_body"), ("condition", "while_cond"),
                      ("calls", "call"), ("to_apply", "apply")):
        m = re.search(rf"{key}=%?([\w.\-]+)", ins.rest)
        if m:
            out.append((m.group(1), kind))
    m = re.search(r"branch_computations=\{([^}]*)\}", ins.rest)
    if m:
        for name in re.findall(r"%?([\w.\-]+)", m.group(1)):
            out.append((name, "branch"))
    return out


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_bytes: float = 0.0
    coll_breakdown: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})


def analyze(text: str) -> HloCost:
    comps = parse_hlo(text)
    entry = next(c for c in comps.values() if c.is_entry)

    # Propagate multipliers ENTRY -> callees; mark inlined (fusion) comps.
    mult: dict[str, float] = {entry.name: 1.0}
    inlined: set[str] = set()
    order = [entry.name]
    seen = {entry.name}
    while order:
        cname = order.pop()
        comp = comps.get(cname)
        if comp is None:
            continue
        m = mult[cname]
        for ins in comp.instrs:
            trips = _trip_count(comps[_ref(ins, "condition")]) \
                if ins.op == "while" and _ref(ins, "condition") in comps else 1
            for callee, kind in _callees(ins):
                if callee not in comps:
                    continue
                factor = trips if kind == "while_body" else 1.0
                mult[callee] = max(mult.get(callee, 0.0), m * factor)
                if kind in ("call", "apply"):
                    inlined.add(callee)
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    cost = HloCost()
    for comp in comps.values():
        m = mult.get(comp.name)
        if m is None:
            continue
        for ins in comp.instrs:
            if ins.op == "dot":
                cost.flops += m * _dot_flops(ins, comp)
            base = next((c for c in _COLLECTIVES
                         if ins.op == c or ins.op == c + "-start"), None)
            if base is not None:
                b = _shape_bytes(ins.type_str)
                cost.collective_bytes += m * b
                cost.coll_breakdown[base] += m * b
            if comp.name not in inlined and ins.op not in _VIEW_OPS \
                    and not ins.op.startswith("copy-"):
                cost.bytes_accessed += m * _instr_bytes(ins, comp, comps)
    return cost


def _instr_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """HBM traffic of one post-fusion instruction.

    Slice-family ops touch only the slice, not the (possibly layer-stacked)
    full operand - counting full operands would overcount scan-over-layers
    weight reads by the trip count. dynamic-update-slice is updated in place
    (aliased), so only the update window moves.
    """
    if ins.op in ("while", "conditional", "call"):
        return 0.0        # the callee's instructions carry the traffic
    if ins.op in ("dynamic-slice", "slice", "gather"):
        return 2.0 * _shape_bytes(ins.type_str)
    if ins.op in ("dynamic-update-slice", "scatter"):
        upd_idx = 1 if ins.op == "dynamic-update-slice" else 2
        upd = comp.shapes.get(ins.operands[upd_idx], "") \
            if len(ins.operands) > upd_idx else ins.type_str
        return 2.0 * _shape_bytes(upd)
    if ins.op == "fusion":
        return _fusion_bytes(ins, comp, comps)
    b = _shape_bytes(ins.type_str)
    for opnd in ins.operands:
        b += _shape_bytes(comp.shapes.get(opnd, ""))
    return b


def _fusion_bytes(ins: Instr, comp: Computation, comps: dict) -> float:
    """Fusion traffic: slice-only-consumed parameters count at slice size;
    in-place dynamic-update-slice roots count at update size."""
    callee = comps.get(_ref(ins, "calls"))
    if callee is None:
        b = _shape_bytes(ins.type_str)
        for opnd in ins.operands:
            b += _shape_bytes(comp.shapes.get(opnd, ""))
        return b
    # If the fusion's output is produced by a dynamic-update-slice of the
    # same (stacked) shape, the buffer is updated in place: only the update
    # window moves through HBM.
    out_b = float(_shape_bytes(ins.type_str))
    for ci in callee.instrs:
        if ci.op == "dynamic-update-slice" and len(ci.operands) > 1 \
                and _shape_bytes(ci.type_str) == _shape_bytes(ins.type_str):
            out_b = 2.0 * _shape_bytes(callee.shapes.get(ci.operands[1],
                                                         ins.type_str))
            break
    total = out_b
    # Map callee parameters to fusion operands; slice-only uses count small.
    for ci in callee.instrs:
        if ci.op != "parameter":
            continue
        midx = re.match(r"(\d+)\)", ci.rest)
        if not midx:
            continue
        idx = int(midx.group(1))
        if idx >= len(ins.operands):
            continue
        full = _shape_bytes(comp.shapes.get(ins.operands[idx], ""))
        uses = [u for u in callee.instrs if ci.name in u.operands]
        if uses and all(u.op in ("dynamic-slice", "slice", "gather", "bitcast",
                                 "get-tuple-element", "dynamic-update-slice")
                        for u in uses):
            total += sum(float(_shape_bytes(u.type_str))
                         if u.op != "dynamic-update-slice"
                         else float(_shape_bytes(
                             callee.shapes.get(u.operands[1], "")))
                         for u in uses)
        else:
            total += full
    return total


def _ref(ins: Instr, key: str) -> str:
    m = re.search(rf"{key}=%?([\w.\-]+)", ins.rest)
    return m.group(1) if m else ""


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = 1
    for _, dims in _shape_dims(ins.type_str):
        for d in dims:
            out_elems *= d
    lhs = comp.shapes.get(ins.operands[0], "") if ins.operands else ""
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    contract = 1
    if m and lhs:
        dims = _shape_dims(lhs)[0][1]
        for idx in (int(i) for i in m.group(1).split(",") if i):
            contract *= dims[idx]
    return 2.0 * out_elems * contract
