"""Shared benchmark timing: warmup + R repetitions, one way everywhere.

Every benchmark used to hand-roll its own ``time.perf_counter()`` loop;
the ``BENCH_scale.json`` records are now all produced through this module
so warmup handling, repetition reduction (max-of-R for regression-gate
conservatism, mean/min for reporting) and optional ``tracemalloc`` peak
tracking are identical across modules.

``sync=`` accepts a callable applied to the function's return value
before the stop stamp — pass ``jax.block_until_ready`` when timing
dispatched device work so the measurement covers execution, not enqueue.
"""
from __future__ import annotations

import time
import tracemalloc
from dataclasses import dataclass

__all__ = ["Measurement", "measure", "timeit", "stopwatch"]


@dataclass(frozen=True)
class Measurement:
    """Per-repetition wall-clock samples plus the (last) result."""

    times_s: tuple
    result: object = None
    peak_bytes: int = 0

    @property
    def best_s(self) -> float:
        return min(self.times_s)

    @property
    def worst_s(self) -> float:
        return max(self.times_s)

    @property
    def mean_s(self) -> float:
        return sum(self.times_s) / len(self.times_s)

    @property
    def mean_us(self) -> float:
        return self.mean_s * 1e6

    @property
    def worst_us(self) -> float:
        return self.worst_s * 1e6

    def reduced_s(self, reduce: str = "mean") -> float:
        if reduce == "mean":
            return self.mean_s
        if reduce == "max":
            return self.worst_s
        if reduce == "min":
            return self.best_s
        raise ValueError(f"reduce must be mean/max/min, got {reduce!r}")


def measure(fn, *, reps: int = 3, warmup: int = 1, sync=None,
            trace_memory: bool = False) -> Measurement:
    """Call ``fn()`` ``warmup`` + ``reps`` times; time each rep.

    ``trace_memory=True`` wraps the timed reps in ``tracemalloc`` and
    reports the peak allocation across them (``Measurement.peak_bytes``).
    """
    if reps < 1:
        raise ValueError("reps must be >= 1")
    for _ in range(warmup):
        out = fn()
        if sync is not None:
            sync(out)
    peak = 0
    if trace_memory:
        tracemalloc.start()
    try:
        times = []
        result = None
        for _ in range(reps):
            t0 = time.perf_counter()
            result = fn()
            if sync is not None:
                sync(result)
            times.append(time.perf_counter() - t0)
        if trace_memory:
            _, peak = tracemalloc.get_traced_memory()
    finally:
        if trace_memory:
            tracemalloc.stop()
    return Measurement(tuple(times), result, peak)


def timeit(fn, *, reps: int = 3, warmup: int = 1, sync=None,
           reduce: str = "mean") -> float:
    """Microseconds per call of ``fn()`` (reduction over ``reps``)."""
    return measure(fn, reps=reps, warmup=warmup, sync=sync).reduced_s(reduce) * 1e6


class stopwatch:
    """``with stopwatch() as sw: ...`` then read ``sw.s`` / ``sw.us``."""

    __slots__ = ("t0", "s")

    def __enter__(self):
        self.s = 0.0
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.s = time.perf_counter() - self.t0
        return False

    @property
    def us(self) -> float:
        return self.s * 1e6
