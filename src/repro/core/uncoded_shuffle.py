"""Baseline uncoded Shuffle (paper §IV-A 'Uncoded Shuffle').

Every intermediate value v_{i,j} that Reducer-owner k needs but did not Map
locally is unicast by one designated Mapper of j. Achieves the expected load
L^UC = p (1 - r/K) under the ER allocation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import Allocation
from .bitcodec import T_BITS


@dataclasses.dataclass
class ShuffleResult:
    """Delivered values per server plus exact load accounting."""

    delivered: dict[int, dict[tuple[int, int], float]]  # k -> {(i, j): v}
    bits_sent: int
    n: int

    @property
    def normalized_load(self) -> float:
        """Definition 2: total bits / (n^2 T)."""
        return self.bits_sent / (self.n * self.n * T_BITS)


def missing_pairs(adj: np.ndarray, alloc: Allocation, k: int) -> np.ndarray:
    """[(i, j)] rows that Reducer k needs and has not Mapped: i in R_k,
    (i, j) in E, j not in M_k."""
    rk = alloc.reduce_owner == k
    need = adj & rk[:, None] & ~alloc.map_sets[k][None, :]
    return np.argwhere(need)


def missing_triples(adj: np.ndarray,
                    alloc: Allocation) -> tuple[np.ndarray, np.ndarray,
                                                np.ndarray]:
    """All (k, i, j) the Shuffle must move, in one vectorized edge pass.

    Sorted by (k, i, j) - the concatenation of `missing_pairs(k)` over k.
    This is the demand set both the uncoded baseline and the ShufflePlan
    compiler serve; deriving it edge-wise replaces the per-server scans.
    """
    ii, jj = np.nonzero(adj)
    kk = alloc.reduce_owner[ii]
    sel = ~alloc.map_sets[kk, jj]
    kk, ii, jj = kk[sel], ii[sel], jj[sel]
    order = np.lexsort((jj, ii, kk))
    return kk[order], ii[order], jj[order]


def run_uncoded(adj: np.ndarray, values: np.ndarray, alloc: Allocation) -> ShuffleResult:
    """values: [n, n] float32 with V[i, j] = v_{i,j} (valid on edges)."""
    delivered: dict[int, dict[tuple[int, int], float]] = {k: {} for k in range(alloc.K)}
    kk, ii, jj = missing_triples(adj, alloc)
    for k, i, j, v in zip(kk, ii, jj, values[ii, jj]):
        delivered[int(k)][(int(i), int(j))] = float(v)
    return ShuffleResult(delivered, len(kk) * T_BITS, alloc.n)


def uncoded_load(adj: np.ndarray, alloc: Allocation) -> float:
    """Exact normalized uncoded load of a realization (no data movement)."""
    bits = sum(len(missing_pairs(adj, alloc, k)) for k in range(alloc.K)) * T_BITS
    return bits / (alloc.n * alloc.n * T_BITS)
