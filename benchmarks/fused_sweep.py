"""Fused (multi-device shard_map) vs NumPy sparse coded-Shuffle sweep.

Measures the steady-state per-iteration wall-clock of one coded Shuffle on
the sparse path, two ways off the *same* compiled plan:

  * `FusedSparseShuffle` replaying its jitted shard_map exchange on a
    K-device ('servers',) host mesh (per-shard xor_code encode, one packed
    all_gather of uint32 words, per-shard strip/decode);
  * `ShufflePlan.execute_coded_sparse`, the single-host NumPy executor.

Bitwise parity of the delivered uint32 words is asserted on every case -
this is a benchmark of the *same* computation on two substrates, not of
two approximations.

jax pins the process's device count at first init, so the sweep runs in a
subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8 (HOME
and JAX_PLATFORMS=cpu passed through per the ROADMAP note). The smoke row
`scale_fused_pagerank_n280` is committed to BENCH_scale.json and gated by
benchmarks/check_regression.py. Interpreted host-CPU collectives are NOT
the TPU performance story - the record tracks regression of the fused
path's compiled replay, while the numpy column is the reference point.
"""
from __future__ import annotations

import json
import os
import pathlib
import subprocess
import sys

SMOKE_CASES = [(280, 8, 3, 0.10)]          # n=280 (already divisible)
FULL_CASES = [(1000, 8, 3, 0.05), (3000, 8, 3, 0.02)]

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import sys

import numpy as np

from repro import graphs, obs
from repro.core import algorithms as algo
from repro.core.allocation import divisible_n, er_allocation
from repro.core.bitcodec import floats_to_words
from repro.core.fused_shuffle import FusedSparseShuffle
from repro.core.shuffle_plan import compile_plan_csr

cases = json.loads(sys.argv[1])
prog = algo.pagerank()
rows = []
for n_req, K, r, p in cases:
    n = divisible_n(n_req, K, r)
    g = graphs.erdos_renyi(n, p, seed=7)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc)
    tables = plan.edge_tables(g.csr, alloc)
    fx = FusedSparseShuffle(plan, g.csr, alloc)
    ev = prog.map_edge_values(g, prog.init(g)).astype(np.float32)

    ref = plan.execute_coded_sparse(ev, tables)
    res = fx.execute(ev)                       # includes jit compile
    equal = bool(np.array_equal(floats_to_words(ref.values),
                                floats_to_words(res.values)))

    # One warmup + mean-of-5 for both substrates (shared obs helper; the
    # fused warmup rep is the steady-state replay, compile already paid).
    numpy_us = obs.timeit(lambda: plan.execute_coded_sparse(ev, tables),
                          reps=5, warmup=1)
    fused_us = obs.timeit(lambda: fx.execute(ev), reps=5, warmup=1)

    rows.append({"n": n, "K": K, "r": r, "edges": int(g.num_edges),
                 "M": int(plan.all_k.size), "equal": equal,
                 "fused_us": fused_us, "numpy_us": numpy_us})
print(json.dumps(rows))
"""


def run(report, smoke=False):
    cases = SMOKE_CASES if smoke else SMOKE_CASES + FULL_CASES
    # Absolute src path: run.py supports plain-script invocation from any
    # cwd, so the subprocess env must not depend on the caller's cwd.
    src = str(pathlib.Path(__file__).resolve().parents[1] / "src")
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(cases)],
        capture_output=True, text=True, timeout=1200,
        env={"PYTHONPATH": src, "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
             "HOME": os.environ.get("HOME", "/tmp"), "JAX_PLATFORMS": "cpu"})
    if proc.returncode != 0:
        raise RuntimeError(f"fused sweep subprocess failed:\n"
                           f"{proc.stderr[-2000:]}")
    rows = json.loads(proc.stdout.strip().splitlines()[-1])
    for row in rows:
        assert row["equal"], f"fused != numpy words at n={row['n']}"
        report(f"scale_fused_pagerank_n{row['n']}", row["fused_us"],
               f"K={row['K']} r={row['r']} edges={row['edges']} "
               f"M={row['M']} numpy_us={row['numpy_us']:.1f} "
               f"vs_numpy={row['fused_us'] / max(row['numpy_us'], 1e-9):.1f}x "
               f"bitwise_equal={row['equal']}")
    return {"rows": rows}
