"""Admission-batched serving: coalescing, exactness, and amortization.

`GraphService` must (a) return per-query results identical to standalone
engine runs (bitwise for SSSP - min reductions), (b) actually coalesce
concurrent queries into shared batched runs (fewer batches than queries,
shuffle bits = schedule bits x total payload columns), and (c) validate
inputs and refuse work after close.
"""
from concurrent.futures import CancelledError

import numpy as np
import pytest

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.serve import GraphService


def _case(n=48, K=4, r=2, p=0.2, seed=11):
    n = divisible_n(n, K, r)
    return graphs.erdos_renyi(n, p, seed=seed), er_allocation(n, K, r)


def test_sssp_queries_match_standalone_bitwise():
    g, alloc = _case()
    roots = [0, 3, 7, 11, 19, 23]
    with GraphService(g, alloc, max_batch=3, max_wait_s=0.05) as svc:
        futs = [svc.submit("sssp", s, iters=6) for s in roots]
        results = [f.result(timeout=60) for f in futs]
    for s, d in zip(roots, results):
        ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(6)
        assert np.array_equal(d, ref.state), s
    assert svc.stats.queries == len(roots)


def test_ppr_queries_match_standalone():
    g, alloc = _case()
    rng = np.random.default_rng(4)
    prefs = rng.random((3, g.n)).astype(np.float32)
    prefs /= prefs.sum(axis=1, keepdims=True)
    with GraphService(g, alloc, max_batch=3, max_wait_s=0.05) as svc:
        futs = [svc.submit("ppr", p, iters=5) for p in prefs]
        results = [f.result(timeout=60) for f in futs]
    for p, v in zip(prefs, results):
        ref = engine.compile(algo.personalized_pagerank(p),
                             g, alloc, "coded").run(5)
        np.testing.assert_allclose(v, ref.state[:, 0], rtol=1e-6, atol=1e-9)


def test_full_batches_amortize_one_shuffle_run():
    g, alloc = _case()
    B = 4
    # Generous admission window + exactly-full batches => deterministic
    # coalescing: the worker admits each batch the moment it fills.
    with GraphService(g, alloc, max_batch=B, max_wait_s=5.0) as svc:
        futs = [svc.submit("sssp", s, iters=4) for s in range(2 * B)]
        for f in futs:
            f.result(timeout=120)
    assert svc.stats.queries == 2 * B
    assert svc.stats.batches == 2
    assert svc.stats.mean_batch == B
    single = engine.compile(algo.sssp(0), g, alloc, "coded").run(4)
    # Bits scale with payload columns only: schedule paid once per batch.
    assert svc.stats.shuffle_bits == 2 * B * single.shuffle_bits
    assert svc.stats.bits_per_query == single.shuffle_bits


def test_lanes_keep_kinds_and_iter_counts_separate():
    g, alloc = _case()
    with GraphService(g, alloc, max_batch=8, max_wait_s=0.02) as svc:
        f_sssp = svc.submit("sssp", 1, iters=3)
        f_ppr = svc.submit("ppr", algo.uniform_prefs(g.n)[:, 0], iters=3)
        f_long = svc.submit("sssp", 1, iters=5)
        a, b, c = (f.result(timeout=60) for f in (f_sssp, f_ppr, f_long))
    assert np.array_equal(
        a, engine.compile(algo.sssp(1), g, alloc, "coded").run(3).state)
    assert np.array_equal(
        c, engine.compile(algo.sssp(1), g, alloc, "coded").run(5).state)
    assert b.shape == (g.n,)
    assert svc.stats.batches == 3      # three (kind, iters) lanes


def test_validation_and_lifecycle():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=2, max_wait_s=0.01)
    try:
        with pytest.raises(ValueError, match="out of range"):
            svc.submit("sssp", g.n)
        with pytest.raises(ValueError, match=rf"n={g.n}"):
            svc.submit("ppr", np.ones(3, dtype=np.float32))
        with pytest.raises(ValueError, match="unknown query kind"):
            svc.submit("bfs", 0)
        assert set(svc.loads()) == {"uncoded", "coded",
                                    "coded_leftover_unicast", "gain"}
    finally:
        svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sssp", 0)
    with pytest.raises(ValueError, match="max_batch"):
        GraphService(g, alloc, max_batch=0)


def test_close_drains_pending_queries():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=4, max_wait_s=10.0)
    # A partial batch sits in its admission window; close() must flush it
    # rather than drop the futures.
    futs = [svc.submit("sssp", s, iters=3) for s in (0, 1)]
    svc.close()
    for s, f in zip((0, 1), futs):
        ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(3)
        assert np.array_equal(f.result(timeout=5), ref.state)


# ---- PR 7: chaos-hardened serving ----

def test_poison_query_fails_alone_batchmates_resolve():
    """Acceptance gate: one poison query in a full batch fails only its own
    future (after O(log B) bisection retries); every batchmate resolves and
    the failure is recorded in ServeStats."""
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=4, max_wait_s=5.0)
    orig = svc._execute
    poison_root = 2

    def poisoned(kind, args, iters):
        if poison_root in args:
            raise RuntimeError("poison value")
        return orig(kind, args, iters)

    svc._execute = poisoned
    futs = [svc.submit("sssp", s, iters=3) for s in range(4)]
    svc.close()
    for s, f in enumerate(futs):
        if s == poison_root:
            with pytest.raises(RuntimeError, match="poison value"):
                f.result(timeout=5)
        else:
            ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(3)
            assert np.array_equal(f.result(timeout=5), ref.state), s
    assert svc.stats.failed_queries == 1
    assert svc.stats.queries == 3
    assert svc.stats.retries > 0


def test_deadline_expires_queued_queries():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=4, max_wait_s=0.2)
    # An already-lapsed deadline fails at admission; a generous one rides.
    dead = svc.submit("sssp", 0, iters=3, deadline_s=0.0)
    live = svc.submit("sssp", 1, iters=3, deadline_s=60.0)
    svc.close()
    with pytest.raises(TimeoutError, match="deadline"):
        dead.result(timeout=5)
    ref = engine.compile(algo.sssp(1), g, alloc, "coded").run(3)
    assert np.array_equal(live.result(timeout=5), ref.state)
    assert svc.stats.expired_queries == 1
    assert svc.stats.queries == 1


def test_close_nowait_cancels_queued_futures():
    """Satellite fix: close(wait=False) must not strand queued futures."""
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=64, max_wait_s=60.0)
    futs = [svc.submit("sssp", s, iters=3) for s in range(3)]
    svc.close(wait=False)
    for f in futs:
        assert f.cancelled()
        with pytest.raises(CancelledError):
            f.result(timeout=1)          # resolves immediately, no hang
    svc._worker.join(timeout=10)
    assert not svc._worker.is_alive()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sssp", 0)


@pytest.mark.filterwarnings(
    "ignore::pytest.PytestUnhandledThreadExceptionWarning")
def test_worker_death_fans_exception_to_queued_futures():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=2, max_wait_s=60.0)

    def die(lane, batch):                # outside _run_batch's try/except
        raise MemoryError("worker died outside _run_batch")

    svc._run_batch = die
    futs = [svc.submit("sssp", s, iters=2) for s in (0, 1)]
    for f in futs:
        with pytest.raises(MemoryError, match="worker died"):
            f.result(timeout=10)
    svc._worker.join(timeout=10)
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sssp", 0)


def test_fault_schedule_crash_recover_through_service():
    """Chaos gate: a crash at a batch boundary swaps in the repaired coded
    session; results stay bitwise-correct and the events land in stats."""
    from repro.core.faults import FaultSchedule

    g, alloc = _case()
    sched = FaultSchedule([(1, "crash", (1,)), (2, "recover", (1,))])
    with GraphService(g, alloc, max_batch=2, max_wait_s=5.0,
                      fault_schedule=sched) as svc:
        results = []
        for wave in range(3):            # one full batch per boundary
            futs = [svc.submit("sssp", 2 * wave + b, iters=3)
                    for b in range(2)]
            results.extend(f.result(timeout=60) for f in futs)
    for s, d in enumerate(results):
        ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(3)
        assert np.array_equal(d, ref.state), s
    assert svc.stats.crashes == 1
    assert svc.stats.recoveries == 1
    assert svc.stats.queries == 6


# ---- PR 8: metrics-backed ServeStats ----

def test_bisection_bits_accounting_stays_per_query_exact():
    """Regression gate (PR 8): under poison-query bisection retries the
    stats must count each *successful* query's bits exactly once —
    queries/batches/shuffle_bits only grow in `record_success`, so
    `bits_per_query` equals the single-query schedule cost no matter how
    the batch was split."""
    g, alloc = _case()
    bits1 = engine.compile(algo.sssp(0), g, alloc, "coded").run(3).shuffle_bits

    svc = GraphService(g, alloc, max_batch=4, max_wait_s=5.0)
    orig = svc._execute
    poison_root = 2

    def poisoned(kind, args, iters):
        if poison_root in args:
            raise RuntimeError("poison value")
        return orig(kind, args, iters)

    svc._execute = poisoned
    futs = [svc.submit("sssp", s, iters=3) for s in range(4)]
    svc.close()
    for s, f in enumerate(futs):
        if s != poison_root:
            f.result(timeout=5)
    st = svc.stats
    # [0,1,2,3] fails -> [0,1] lands, [2,3] fails -> [2] fails alone,
    # [3] lands: 3 successes over 2 successful sub-batches, 4 retries.
    assert st.queries == 3
    assert st.batches == 2
    assert st.retries == 4
    assert st.failed_queries == 1
    assert st.shuffle_bits == 3 * bits1
    assert st.bits_per_query == bits1
    assert st.mean_batch == pytest.approx(1.5)


def test_servestats_latency_percentiles_and_prometheus_view():
    g, alloc = _case()
    with GraphService(g, alloc, max_batch=4, max_wait_s=0.05) as svc:
        futs = [svc.submit("sssp", s, iters=3) for s in range(8)]
        for f in futs:
            f.result(timeout=60)
    st = svc.stats
    assert st.registry.get("serve_query_latency_seconds").count == 8
    assert 0 < st.latency_p50 <= st.latency_p95 <= st.latency_p99
    assert st.latency_percentiles() == {
        "p50": st.latency_p50, "p95": st.latency_p95, "p99": st.latency_p99}
    text = st.to_prometheus_text()
    assert "serve_queries_total 8" in text
    assert "serve_query_latency_seconds_count 8" in text
    assert f"serve_shuffle_bits_total {st.shuffle_bits}" in text


def test_servestats_shared_registry_injection():
    """A caller-supplied MetricsRegistry sees the service's metrics; two
    default-constructed services never cross-contaminate."""
    from repro.obs import MetricsRegistry

    g, alloc = _case()
    reg = MetricsRegistry()
    with GraphService(g, alloc, max_batch=2, max_wait_s=0.05,
                      registry=reg) as svc:
        svc.submit("sssp", 0, iters=2).result(timeout=60)
    assert reg.get("serve_queries_total").value == 1
    assert svc.stats.registry is reg

    with GraphService(g, alloc, max_batch=2, max_wait_s=0.05) as other:
        other.submit("sssp", 1, iters=2).result(timeout=60)
    assert svc.stats.queries == 1          # untouched by the second service
    assert other.stats.queries == 1
