"""Fault tolerance: node failure, straggler degradation, elastic rebalance."""
import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation


@pytest.fixture
def setup():
    K, r = 5, 2
    n = divisible_n(50, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=8)
    return g, er_allocation(n, K, r), algo.pagerank()


def test_single_failure_is_transparent(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 4)
    for f in range(alloc.K):
        res, stats = faults.run_with_failure(prog, g, alloc, 4, failed=(f,),
                                             fail_at_iter=2)
        np.testing.assert_array_equal(res.state, ref)
        # r=2 replication: nothing needs re-Mapping for a single failure.
        assert stats.remapped_vertices == 0


def test_r_minus_one_failures_need_no_remap(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    res, stats = faults.run_with_failure(prog, g, alloc, 3, failed=(1,),
                                         fail_at_iter=0)
    np.testing.assert_array_equal(res.state, ref)
    assert stats.remapped_vertices == 0


def test_r_failures_trigger_remap_but_still_correct(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    res, stats = faults.run_with_failure(prog, g, alloc, 3, failed=(0, 1),
                                         fail_at_iter=1)
    np.testing.assert_array_equal(res.state, ref)
    # Batch B_{0,1} was only at the failed pair -> must be re-Mapped.
    assert stats.remapped_vertices == alloc.g


def test_rebalance_preserves_results(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    for K_new in (2, 5, 10):
        try:
            alloc2 = faults.rebalance(alloc, K_new)
        except ValueError:
            continue  # n not compatible; rebalance() is explicit about padding
        res = engine.run(prog, g, alloc2, 3, mode="coded")
        np.testing.assert_array_equal(res.state, ref)


def test_degraded_allocation_is_valid(setup):
    g, alloc, prog = setup
    degraded, _ = faults.degrade_allocation(alloc, (3,))
    assert not degraded.map_sets[3].any()
    assert (degraded.reduce_owner != 3).all()
    # Every vertex still Mapped somewhere and Reduced exactly once.
    assert degraded.map_sets.any(axis=0).all()
    assert len(degraded.reduce_owner) == alloc.n


def test_all_failures_rejected(setup):
    g, alloc, _ = setup
    with pytest.raises(ValueError):
        faults.degrade_allocation(alloc, tuple(range(alloc.K)))


def test_straggler_load_degrades_gracefully():
    """Coded shuffle with straggling senders stays well below uncoded."""
    from repro.core.coded_shuffle import coded_load
    from repro.core.uncoded_shuffle import uncoded_load
    import repro.core.graph_models as gm
    from repro.core.allocation import divisible_n, er_allocation

    K, r = 6, 3
    n = divisible_n(120, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=2)
    alloc = er_allocation(n, K, r)
    base = coded_load(g.adj, alloc)
    unc = uncoded_load(g.adj, alloc)
    prev = base
    for s in range(1, r):
        load = faults.straggler_coded_load(g, alloc, tuple(range(s)))
        assert base <= load < unc          # graceful, still beats uncoded
        assert load >= prev
        prev = load


def test_straggler_load_entry_points_agree_and_dense_rejected():
    """Graph / CSR / plan entry points agree exactly (one plan underneath);
    the removed dense-adjacency reference now raises TypeError."""
    from repro import graphs
    from repro.core.shuffle_plan import compile_plan_csr

    for K, r in [(6, 3), (5, 2)]:
        n = divisible_n(120, K, r)
        g = graphs.erdos_renyi(n, 0.15, seed=11)
        alloc = er_allocation(n, K, r)
        plan = compile_plan_csr(g.csr, alloc, validate=False)
        for s in range(1, r):
            strag = tuple(range(s))
            want = faults.straggler_coded_load(g, alloc, strag)
            assert faults.straggler_coded_load(g.csr, alloc, strag) == want
            assert faults.straggler_coded_load(plan, alloc, strag) == want
            assert faults.straggler_coded_load_plan(plan, strag) == want
        with pytest.raises(TypeError, match="dense .* form was removed"):
            faults.straggler_coded_load(g.adj, alloc, (0,))


def test_straggler_plan_rejects_unhealthy_groups_and_no_schedule():
    from repro import graphs
    from repro.core.shuffle_plan import compile_plan_csr

    K, r = 6, 3
    n = divisible_n(120, K, r)
    g = graphs.erdos_renyi(n, 0.15, seed=11)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    with pytest.raises(ValueError, match="lacks healthy senders"):
        faults.straggler_coded_load_plan(plan, (0, 1, 2))
    bare = compile_plan_csr(g.csr, alloc, validate=False, schedule=False)
    with pytest.raises(ValueError, match="schedule=False"):
        faults.straggler_coded_load_plan(bare, (0,))
    # Mismatched (plan, alloc) pairs are an error, not a silent wrong load.
    other = er_allocation(2 * n, K, r)
    with pytest.raises(ValueError, match="compiled for"):
        faults.straggler_coded_load(plan, other, (0,))


# ---- PR 7: coded plan repair + deterministic fault injection ----

def _models(n):
    return [
        ("er", gm.erdos_renyi(n, 0.2, seed=5)),
        ("pl", gm.power_law(n, 2.5, seed=6)),
        ("sbm", gm.stochastic_block(n // 2, n - n // 2, 0.4, 0.08, seed=7)),
    ]


def _delivered(plan, g, alloc, prog):
    from repro.core.shuffle_plan import ShufflePlan  # noqa: F401
    ev = prog.map_edge_values(g, prog.init(g)).astype(np.float32)
    return plan.execute_coded_sparse(ev, plan.edge_tables(g.csr, alloc))


def test_repair_matches_fresh_compile_across_models():
    """Acceptance gate: for |failed| < r the repaired plan is the fresh
    degraded compile - identical arrays except `col_sender` (which fresh
    compilation would still point at dead servers) - and delivers bitwise-
    equal words."""
    import dataclasses

    from repro.core.shuffle_plan import compile_plan_csr

    K, r = 6, 3
    n = divisible_n(120, K, r)
    alloc = er_allocation(n, K, r)
    prog = algo.pagerank()
    for name, g in _models(n):
        plan = compile_plan_csr(g.csr, alloc)
        for failed in [(1,), (0, 4)]:
            rep, degraded, stats = plan.repair(g.csr, alloc, failed)
            fresh = compile_plan_csr(g.csr, degraded)
            for f in dataclasses.fields(type(rep)):
                a, b = getattr(rep, f.name), getattr(fresh, f.name)
                if f.name == "col_sender":
                    # Fresh compile keeps dead multicasters; repair must not.
                    assert np.isin(b, failed).any(), (name, failed)
                    assert not np.isin(a, failed).any(), (name, failed)
                else:
                    assert np.array_equal(a, b), (name, failed, f.name)
            assert rep.coded_bits == fresh.coded_bits
            assert stats.demoted_pairs == 0 and stats.remapped_vertices == 0
            assert stats.handover_bits > 0
            got = _delivered(rep, g, degraded, prog)
            want = _delivered(fresh, g, degraded, prog)
            for fld in ("k", "i", "j", "values", "ptr"):
                assert np.array_equal(getattr(got, fld), getattr(want, fld))
            assert got.bits_sent == want.bits_sent


def test_repair_beyond_r_demotes_and_remaps_but_stays_exact(setup):
    """|failed| >= r: orphaned batches are re-Mapped, unhealthy groups are
    demoted to unicast, and the end state still matches the oracle."""
    g, alloc, prog = setup          # K=5, r=2
    from repro.core.shuffle_plan import compile_plan_csr

    plan = compile_plan_csr(g.csr, alloc)
    rep, degraded, stats = plan.repair(g.csr, alloc, (0, 1))
    assert stats.remapped_vertices == alloc.g
    assert stats.demoted_pairs >= 0
    ref = algo.reference_run(prog, g, 3)
    res, rstats = faults.run_with_failure(prog, g, alloc, 3, (0, 1),
                                          fail_at_iter=1)
    np.testing.assert_array_equal(res.state, ref)
    assert rstats.remapped_vertices == alloc.g


def test_repair_validation(setup):
    g, alloc, _ = setup
    from repro.core.shuffle_plan import compile_plan_csr

    plan = compile_plan_csr(g.csr, alloc)
    with pytest.raises(ValueError, match="out of range"):
        plan.repair(g.csr, alloc, (alloc.K,))
    g2 = gm.erdos_renyi(2 * alloc.n, 0.1, seed=0)
    with pytest.raises(ValueError, match="compiled for"):
        plan.repair(g2.csr, alloc, (0,))
    bare = compile_plan_csr(g.csr, alloc, schedule=False)
    with pytest.raises(ValueError, match="schedule=False"):
        bare.repair(g.csr, alloc, (0,))


def test_post_failure_coded_beats_uncoded_fallback(setup):
    """The tentpole payoff: staying coded after a crash costs measurably
    fewer bits than the legacy uncoded degradation, at identical state."""
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 6)
    res_c, st_c = faults.run_with_failure(prog, g, alloc, 6, (1,),
                                          fail_at_iter=2)
    res_u, st_u = faults.run_with_failure(prog, g, alloc, 6, (1,),
                                          fail_at_iter=2, mode="uncoded")
    np.testing.assert_array_equal(res_c.state, ref)
    np.testing.assert_array_equal(res_u.state, ref)
    assert res_c.shuffle_bits < res_u.shuffle_bits
    assert st_c.recovery_bits < st_u.recovery_bits
    assert st_c.recovery_bits > 0


def test_engine_fail_session_and_recover(setup):
    """CompiledEngine.fail + FaultSchedule crash/recover round-trip: values
    are never perturbed, the degraded epochs pay the hand-over overhead,
    and recovery returns to the original schedule's bits."""
    g, alloc, prog = setup
    eng = engine.compile(prog, g, alloc, "coded")
    clean = eng.run(6)
    sched = faults.FaultSchedule([(2, "crash", (1,)), (4, "recover", (1,))])
    res = eng.run(6, fault_schedule=sched)
    np.testing.assert_array_equal(res.state, clean.state)
    log = res.faults
    assert log.crashes == 1 and log.recoveries == 1
    assert log.handover_bits > 0
    assert log.recovery_bits > 0
    assert res.shuffle_bits > clean.shuffle_bits  # degraded epochs cost more
    # fail() itself returns a session on the degraded allocation.
    deg = eng.fail((1,))
    assert deg.recovery.handover_bits > 0
    assert not deg.alloc.map_sets[1].any()
    np.testing.assert_array_equal(deg.run(3).state,
                                  algo.reference_run(prog, g, 3))


def test_engine_fail_validation(setup):
    g, alloc, prog = setup
    eng = engine.compile(prog, g, alloc, "coded")
    with pytest.raises(ValueError, match="out of range"):
        eng.fail((alloc.K + 3,))
    ref = engine.compile(prog, g, alloc, "coded-ref")
    with pytest.raises(ValueError, match="plan-mode"):
        ref.fail((0,))


def test_straggle_event_reprices_without_touching_values(setup):
    g, alloc, prog = setup
    eng = engine.compile(prog, g, alloc, "coded")
    clean = eng.run(4)
    sched = faults.FaultSchedule([(1, "straggle", (0,)),
                                  (2, "recover", (0,))])
    res = eng.run(4, fault_schedule=sched)
    np.testing.assert_array_equal(res.state, clean.state)
    assert res.faults.straggled_iters == 1
    assert res.shuffle_bits > clean.shuffle_bits


def test_fault_schedule_validation_and_determinism():
    with pytest.raises(ValueError, match="unknown fault kind"):
        faults.FaultSchedule([(0, "explode", (1,))])
    with pytest.raises(ValueError, match=">= 0"):
        faults.FaultSchedule([(-1, "crash", (1,))])
    a = faults.FaultSchedule.random(6, 12, seed=3)
    b = faults.FaultSchedule.random(6, 12, seed=3)
    assert a.events == b.events
    assert a.horizon <= 11
    assert faults.FaultSchedule([]).horizon == -1
    # Events sort by boundary and normalize server tuples.
    s = faults.FaultSchedule([(3, "recover", 2), (1, "crash", (2, 2))])
    assert s.events[0] == faults.FaultEvent(1, "crash", (2,))
    assert s.at(3) == [faults.FaultEvent(3, "recover", (2,))]


def test_rebalance_pad_routes_through_padding():
    K, r = 5, 2
    n = divisible_n(50, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=8)
    alloc = er_allocation(n, K, r)
    K_new = 4
    assert divisible_n(n, K_new, r) != n
    with pytest.raises(ValueError, match="pad=True"):
        faults.rebalance(alloc, K_new)
    alloc2 = faults.rebalance(alloc, K_new, pad=True)
    assert alloc2.n == divisible_n(n, K_new, r)
    g2 = g.padded(alloc2.n)
    res = engine.run(algo.sssp(0), g2, alloc2, 3, mode="coded")
    ref = algo.reference_run(algo.sssp(0), g, 3)
    # SSSP distances ignore the virtual isolated pad vertices entirely.
    np.testing.assert_array_equal(res.state[:n], ref)
    assert np.isinf(res.state[n:]).all()


def test_straggler_dense_form_removed():
    """PR 10 satellite: the dense-adjacency form is gone (TypeError); the
    plan form stays warning-free."""
    from repro.core.shuffle_plan import compile_plan_csr

    K, r = 6, 3
    n = divisible_n(120, K, r)
    g = gm.erdos_renyi(n, 0.15, seed=11)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    with pytest.raises(TypeError, match="dense .* form was removed"):
        faults.straggler_coded_load(g.adj, alloc, (0,))
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")    # the plan form must stay silent
        assert faults.straggler_coded_load(plan, alloc, (0,)) > 0
