"""Vertex programs expressed as MapReduce pairs (paper §II-A, Examples 1-2).

An algorithm supplies two interchangeable forms of the same Map/Reduce pair:

Dense form (the paper-literal oracle, O(n^2) per iteration):
  map_values(graph, state)  -> V [n, n] float32 where V[i, j] = g_{i,j}(w_j)
                               for (i, j) in E (garbage elsewhere; the engine
                               masks with the adjacency),
  reduce(vals, mask, state) -> new state from each vertex's neighbor values,
  identity                  -> the padding value that is absorbing for reduce.

Edge-value form (the O(edges) execution path; all four built-ins supply it):
  map_edge_values(graph, state)        -> [nnz] float32, one value per CSR
                                          entry e = (i, j), equal bitwise to
                                          map_values(...)[i, j],
  reduce_edges(vals, indptr, state, g) -> new state via a segment reduction
                                          over the CSR rows (np.add.reduceat /
                                          np.minimum.reduceat).

Contract: each execution path must match the *same-form* single-machine
oracle (`reference_run(path=...)`) bitwise - the sparse engine accumulates
every row in canonical CSR entry order, so distributed == oracle exactly.
Across forms, min-reductions (sssp, cc) and integer sums (degree) are also
bitwise equal; pagerank's float sum legitimately differs by reduction order
(dense row-sum vs sequential reduceat), within a few ulp.

Programs whose Map value depends only on the source vertex and whose Reduce
is a plain sum (pagerank, degree) additionally expose `map_source` ([n]
per-source values) and `finalize` (elementwise epilogue), which lets the
engine route the blocked row reduction through the kernels/spmv Pallas tiles
(`backend="spmv"`).

The dense-matrix form is the blocked-dense TPU adaptation (DESIGN.md §3): a
PageRank Map over a vertex block is one column-scaled adjacency tile, and the
Reduce is a masked row reduction - both MXU/VPU friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .graph_models import Graph


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    identity: float
    init: Callable[[Graph], np.ndarray]
    map_values: Callable[[Graph, np.ndarray], np.ndarray]
    reduce: Callable[[np.ndarray, np.ndarray, np.ndarray, Graph], np.ndarray]
    # Edge-value (sparse) form; None => program only supports the dense path.
    map_edge_values: Callable[[Graph, np.ndarray], np.ndarray] | None = None
    reduce_edges: Callable[[np.ndarray, np.ndarray, np.ndarray, Graph],
                           np.ndarray] | None = None
    # Linear-program extras for the blocked spmv backend (sum-reduce programs
    # whose v_{i,j} depends only on source j): v_e = map_source(g, state)[j].
    map_source: Callable[[Graph, np.ndarray], np.ndarray] | None = None
    finalize: Callable[[np.ndarray, np.ndarray, Graph], np.ndarray] | None = None

    @property
    def supports_sparse(self) -> bool:
        return (self.map_edge_values is not None
                and self.reduce_edges is not None)


def segment_reduce(ufunc, vals: np.ndarray, indptr: np.ndarray,
                   identity: float) -> np.ndarray:
    """`ufunc.reduceat` over CSR row segments; empty rows -> identity.

    reduceat accumulates sequentially within a segment, so the reduction
    order is the canonical CSR entry order - the bitwise contract shared by
    the single-machine sparse oracle and the distributed sparse engine.
    """
    out = np.full(indptr.size - 1, identity, dtype=np.float32)
    starts = indptr[:-1]
    nonempty = indptr[1:] > starts
    if vals.size:
        out[nonempty] = ufunc.reduceat(vals, starts[nonempty])
    return out


def pagerank(damping: float = 0.15) -> VertexProgram:
    """Example 1. state = rank vector Pi; v_{i,j} = Pi(j) / deg(j)."""

    def init(g: Graph) -> np.ndarray:
        return np.full(g.n, 1.0 / g.n, dtype=np.float32)

    def map_source(g: Graph, state: np.ndarray) -> np.ndarray:
        deg = np.maximum(g.degrees(), 1)
        return (state / deg).astype(np.float32)       # per-source value

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.broadcast_to(map_source(g, state)[None, :], (g.n, g.n))

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return map_source(g, state)[g.csr.indices]

    def finalize(acc: np.ndarray, state: np.ndarray, g: Graph) -> np.ndarray:
        return ((1.0 - damping) * acc + damping / g.n).astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        return finalize(np.where(mask, vals, 0.0).sum(axis=1), state, g)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        return finalize(segment_reduce(np.add, vals, indptr, 0.0), state, g)

    return VertexProgram("pagerank", 0.0, init, map_values, reduce,
                         map_edge_values, reduce_edges, map_source, finalize)


def sssp(source: int = 0) -> VertexProgram:
    """Example 2. state = distance vector D; v_{i,j} = D(j) + t(j, i)."""

    def init(g: Graph) -> np.ndarray:
        d = np.full(g.n, np.inf, dtype=np.float32)
        d[source] = 0.0
        return d

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        w = g.weights()
        return (state[None, :] + w.T).astype(np.float32)   # t(j, i) = w[j, i]

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        # w is symmetric and edge_weights() shares one draw per undirected
        # edge, so state[j] + w_e == the dense (i, j) entry bitwise.
        return (state[g.csr.indices] + g.edge_weights()).astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        m = segment_reduce(np.minimum, vals, indptr, np.inf)
        return np.minimum(state, m).astype(np.float32)

    return VertexProgram("sssp", np.inf, init, map_values, reduce,
                         map_edge_values, reduce_edges)


def connected_components() -> VertexProgram:
    """Min-label propagation; converges to per-component min vertex id."""

    def init(g: Graph) -> np.ndarray:
        return np.arange(g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.broadcast_to(state[None, :], (g.n, g.n)).astype(np.float32)

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return state[g.csr.indices].astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        m = segment_reduce(np.minimum, vals, indptr, np.inf)
        return np.minimum(state, m).astype(np.float32)

    return VertexProgram("cc", np.inf, init, map_values, reduce,
                         map_edge_values, reduce_edges)


def degree_count() -> VertexProgram:
    """Trivial one-shot program: each vertex counts its neighbors."""

    def init(g: Graph) -> np.ndarray:
        return np.zeros(g.n, dtype=np.float32)

    def map_source(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones(g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones((g.n, g.n), dtype=np.float32)

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones(g.csr.nnz, dtype=np.float32)

    def finalize(acc: np.ndarray, state: np.ndarray, g: Graph) -> np.ndarray:
        return acc.astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        return finalize(np.where(mask, vals, 0.0).sum(axis=1), state, g)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        return finalize(segment_reduce(np.add, vals, indptr, 0.0), state, g)

    return VertexProgram("degree", 0.0, init, map_values, reduce,
                         map_edge_values, reduce_edges, map_source, finalize)


def reference_run(program: VertexProgram, g: Graph, iters: int,
                  path: str = "auto") -> np.ndarray:
    """Single-machine oracle: the engine (any mode) must match this exactly.

    path="sparse" (or "auto" when the program has an edge-value form) runs
    the O(edges) form; path="dense" runs the paper-literal [n, n] form. Each
    engine path is bit-exact against the *same-path* oracle; see the module
    docstring for the cross-path contract.
    """
    if path not in ("auto", "sparse", "dense"):
        raise ValueError(f"unknown path {path!r}")
    if path == "sparse" and not program.supports_sparse:
        raise ValueError(f"{program.name} has no edge-value (sparse) form")
    sparse = path != "dense" and program.supports_sparse
    state = program.init(g)
    if sparse:
        indptr = g.csr.indptr
        for _ in range(iters):
            vals = program.map_edge_values(g, state).astype(np.float32)
            state = program.reduce_edges(vals, indptr, state, g)
    else:
        for _ in range(iters):
            vals = program.map_values(g, state)
            state = program.reduce(vals, g.adj, state, g)
    return state
