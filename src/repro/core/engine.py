"""Distributed MapReduce-on-graph engine (paper §II-B execution model).

Simulates K servers bit-faithfully: each server Maps its subgraph M_k, the
Shuffle phase moves exactly the bits the chosen scheme prescribes, and each
server Reduces R_k using *only* locally-Mapped plus delivered values. Any
divergence from the single-machine oracle is therefore a real bug in the
allocation or coding logic, not a modeling artifact.

The multicast schedule depends only on (graph, allocation), so `run` compiles
a `ShufflePlan` once and replays it every iteration (compile-once /
execute-many); the schedule-completeness check that used to run per iteration
now runs once at compile time inside `compile_plan`.

Execution paths (`path=` argument):
  sparse (default when the program has an edge-value form) - one iteration is
      O(edges + plan) in time and memory: Map emits a [nnz] edge-value vector
      in CSR order, the plan's sparse executors move exactly the scheduled
      entries, and the Reduce is one gather (local CSR slice + delivery
      arrays, via the plan's precompiled edge-order gather table) followed by
      a segment reduction. Because the gather lands every row's values in
      canonical CSR entry order, the distributed result is bitwise equal to
      the sparse single-machine oracle (`reference_run(path="sparse")`).
  dense - the paper-literal [n, n] form, kept as the validation oracle (and
      the only path for programs without an edge-value form). Bitwise equal
      to `reference_run(path="dense")`. Cross-path, sum-programs (pagerank)
      may differ by float reduction order within a few ulp; min/integer
      programs are bitwise identical (see algorithms.py).

Backends (sparse path): `backend="numpy"` segment-reduces with reduceat;
`backend="spmv"` routes the row reduction of linear programs (pagerank,
degree) through the kernels/spmv Pallas kernel in [bm, n] blocked strips, so
the TPU path exercises real MXU tiles at O(bm*n) memory; `backend="fused"`
(mode="coded" only) executes each iteration's Shuffle on a multi-device
('servers',) mesh under shard_map - per-shard XOR encode, one packed
all_gather of uint32 coded words, per-shard strip - via
`fused_shuffle.FusedSparseShuffle`, jitted once and replayed, with delivered
words bitwise equal to the NumPy plan executor (the Reduce then rides the
same gather + segment reduction as backend="numpy").

Modes:
  single      - oracle, no distribution.
  uncoded     - baseline unicast shuffle   (load ~ p(1 - r/K)).
  coded       - paper's XOR multicast      (load ~ p(1 - r/K)/r), bit-exact.
  coded-fast  - same schedule/loads via the compiled plan, values moved
                directly (skips the XOR simulation; used for large sweeps).
  coded-ref   - the literal per-group reference (`coded_shuffle.run_coded`),
                dict delivery and dense reduce; kept for A/B validation.

Sessions: `compile(program, g, alloc, mode, path=, backend=, **opts)` returns
a `CompiledEngine` holding every reusable artifact (plan, edge tables, fused
exchange) so repeated `.run(iters)` / `.run_batch(states, iters)` calls never
recompile; `run(...)` remains as a thin one-shot wrapper over it. Batched
states [n, B] (multi-source SSSP, personalized PageRank) ride ONE Shuffle
exchange per iteration on the sparse path - the schedule is value-agnostic,
so `bits_sent` scales as B x the single-query schedule bits.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..launch.mesh import Topology
from ..obs import get_tracer
from .algorithms import VertexProgram
from .allocation import Allocation
from .bitcodec import T_BITS
from .coded_shuffle import run_coded
from .graph_models import Graph
from .shuffle_plan import (HierarchicalPlan, PlanShuffleResult, ShufflePlan,
                           compile_hierarchical, compile_plan_csr)
from .uncoded_shuffle import missing_pairs

PLAN_MODES = ("uncoded", "coded", "coded-fast")

# Per-backend accepted `backend_opts` keys. Validated up front so a typo'd
# option raises instead of being silently dropped (numpy takes none).
_BACKEND_OPTS: dict[str, frozenset] = {
    "numpy": frozenset(),
    "spmv": frozenset({"bm", "interpret"}),
    "fused": frozenset({"mesh", "encode", "interpret"}),
}


def _validate_backend_opts(backend: str, opts: dict) -> None:
    if backend not in _BACKEND_OPTS:
        raise ValueError(f"unknown backend {backend!r}")
    unknown = sorted(set(opts) - _BACKEND_OPTS[backend])
    if unknown:
        accepted = sorted(_BACKEND_OPTS[backend])
        raise ValueError(
            f"backend {backend!r} got unknown option(s) {unknown}; "
            f"accepted: {accepted if accepted else '(none)'}")


@dataclasses.dataclass
class EngineResult:
    state: np.ndarray            # [n] (or [n, B] from a batched run)
    iters: int
    shuffle_bits: int            # total over all iterations
    mode: str
    faults: "object | None" = None   # faults.FaultLog when a schedule ran

    @property
    def batch(self) -> int:
        """Number of query columns carried (1 for unbatched runs)."""
        return 1 if self.state.ndim == 1 else int(self.state.shape[1])

    @property
    def normalized_load(self) -> float:
        """Average per-iteration, per-query Definition-2 load."""
        n = self.state.shape[0]
        return (self.shuffle_bits / max(self.iters, 1)
                / (self.batch * n * n * T_BITS))


def _reduce_distributed(program: VertexProgram, g: Graph, alloc: Allocation,
                        values: np.ndarray,
                        delivered: dict[int, dict[tuple[int, int], float]],
                        state: np.ndarray) -> np.ndarray:
    """Dict-delivery Reduce (reference path; `faults.py` and coded-ref)."""
    new_state = np.empty_like(state)
    for k in range(alloc.K):
        vk = np.full((g.n, g.n), program.identity, dtype=np.float32)
        cols = alloc.map_sets[k]
        vk[:, cols] = values[:, cols]                  # locally Mapped
        for (i, j), v in delivered[k].items():
            vk[i, j] = v
        rk = alloc.reduce_owner == k
        # Verify the server really has everything it needs (catches schedule bugs).
        need = g.adj & rk[:, None]
        have = cols[None, :] | np.zeros((g.n, g.n), dtype=bool)
        for (i, j) in delivered[k]:
            have[i, j] = True
        if (need & ~have).any():
            miss = np.argwhere(need & ~have)[:5]
            raise RuntimeError(f"server {k} missing values, e.g. {miss.tolist()}")
        reduced = program.reduce(vk, g.adj, state, g)
        new_state[rk] = reduced[rk]
    return new_state


def _reduce_plan(program: VertexProgram, g: Graph, alloc: Allocation,
                 values: np.ndarray, res: PlanShuffleResult,
                 state: np.ndarray) -> np.ndarray:
    """Array-delivery dense Reduce: scatter each server's CSR slice.

    O(K n^2) per iteration - the reference the sparse path is validated and
    benchmarked against (`path="dense"`). Schedule completeness was verified
    once at plan-compile time, so the per-iteration missing-value scan of the
    dict path is not repeated here.
    """
    new_state = np.empty_like(state)
    for k in range(alloc.K):
        vk = np.full((g.n, g.n), program.identity, dtype=np.float32)
        cols = alloc.map_sets[k]
        vk[:, cols] = values[:, cols]                  # locally Mapped
        a, b = int(res.ptr[k]), int(res.ptr[k + 1])
        vk[res.i[a:b], res.j[a:b]] = res.values[a:b]   # delivered
        rk = alloc.reduce_owner == k
        reduced = program.reduce(vk, g.adj, state, g)
        new_state[rk] = reduced[rk]
    return new_state


def _reduce_sparse(program: VertexProgram, g: Graph, edge_vals: np.ndarray,
                   res: PlanShuffleResult, gather: np.ndarray,
                   state: np.ndarray) -> np.ndarray:
    """Gather-then-segment-reduce over all servers at once, O(edges).

    Each CSR entry's value comes from its owner's locally-Mapped slice or
    its delivery slot (the precompiled `gather` table encodes which); the
    gathered vector is in canonical CSR entry order, so the segment
    reduction is bitwise identical to the sparse single-machine oracle.
    """
    buf = np.concatenate([edge_vals, res.values])
    return program.reduce_edges(buf[gather], g.csr.indptr, state, g)


def _reduce_spmv(program: VertexProgram, g: Graph, state: np.ndarray, *,
                 bm: int = 128, interpret: bool = True) -> np.ndarray:
    """Blocked row reduction through the kernels/spmv Pallas kernel.

    Valid for linear programs (v_{i,j} = map_source(g, state)[j], Reduce =
    sum + elementwise finalize): acc = adj @ c computed strip-by-strip from
    the CSR view at O(bm * n) memory. Kernel float accumulation order
    differs from reduceat, so this backend is tolerance- (not bit-) exact.
    Batched [n, B] states run the kernel once per query column and finalize
    on the stacked [n, B] accumulator (finalize may close over per-query
    data, e.g. personalized-PageRank preference columns).
    """
    from ..kernels.spmv import ops as spmv_ops

    c = program.map_source(g, state)

    def one(col):
        return spmv_ops.spmv_csr_rows(g.csr.indptr, g.csr.indices, col, g.n,
                                      rows=g.csr.rows, bm=bm,
                                      interpret=interpret)

    acc = (np.stack([one(c[:, b]) for b in range(c.shape[1])], axis=1)
           if c.ndim == 2 else one(c))
    return program.finalize(acc, state, g)


def _use_sparse(program: VertexProgram, mode: str, path: str) -> bool:
    if path not in ("auto", "sparse", "dense"):
        raise ValueError(f"unknown path {path!r}")
    if mode not in PLAN_MODES + ("single", "coded-ref"):
        raise ValueError(f"unknown mode {mode!r}")
    if mode == "coded-ref":
        if path == "sparse":
            raise ValueError("coded-ref is the dense dict-delivery reference")
        return False
    if path == "sparse" and not program.supports_sparse:
        raise ValueError(f"{program.name} has no edge-value (sparse) form")
    return path != "dense" and program.supports_sparse


def _plan_bits(plan: ShufflePlan, mode: str) -> int:
    """Bits-on-the-wire of one Shuffle: schedule-only, data-independent."""
    if mode == "coded":
        return plan.coded_bits + plan.leftover_bits
    if mode == "coded-fast":
        return plan.coded_bits
    return plan.uncoded_bits


class CompiledEngine:
    """Compile-once session bound to (graph, allocation, mode, path, backend).

    Holds every reusable artifact - the `ShufflePlan`, its CSR edge tables,
    and (for backend="fused") the jitted multi-device exchange - so repeated
    `.run` / `.run_batch` calls replay iterations with zero recompilation.
    All of those artifacts are *program-independent* (the schedule is a
    function of (graph, allocation) only), which is why `with_program`
    rebinds the vertex program for free: the serving queue swaps in a fresh
    `multi_sssp` / `personalized_pagerank` per admitted batch on one session
    without ever touching the plan.
    """

    def __init__(self, program: VertexProgram, g: Graph,
                 alloc: Allocation | None, mode: str = "coded", *,
                 path: str = "auto", backend: str = "numpy",
                 plan: ShufflePlan | HierarchicalPlan | None = None,
                 backend_opts: dict | None = None,
                 topology: Topology | None = None):
        backend_opts = dict(backend_opts or {})
        sparse = _use_sparse(program, mode, path)
        _validate_backend_opts(backend, backend_opts)
        self.hplan = None
        if isinstance(plan, HierarchicalPlan):
            if topology is not None and topology != plan.topology:
                raise ValueError(
                    f"topology {topology} disagrees with the plan's "
                    f"{plan.topology}")
            topology = plan.topology
            if topology.is_flat:
                plan = plan.flat              # degenerate: flat session
            else:
                self.hplan = plan
                plan = plan.flat
        hier = topology is not None and not topology.is_flat
        if hier:
            # The hierarchical executor implements the coded sparse Shuffle
            # only; spmv never executes a Shuffle at all.
            if mode != "coded" or not sparse:
                raise ValueError(
                    "a non-flat topology runs the two-level coded Shuffle: "
                    f"mode='coded' on the sparse path required (got "
                    f"mode={mode!r}, path={path!r})")
            if backend == "spmv":
                raise ValueError(
                    "backend='spmv' skips the Shuffle; a non-flat topology "
                    "needs backend 'numpy' or 'fused'")
            if alloc is None:
                raise ValueError("a non-flat topology needs an allocation")
            topology.check_K(alloc.K)
        if backend == "spmv":
            if not sparse:
                raise ValueError("backend='spmv' requires the sparse path")
            if program.map_source is None or program.finalize is None:
                raise ValueError(
                    f"{program.name} is not linear (no map_source/finalize); "
                    "backend='spmv' needs a per-source Map and a sum Reduce")
        if backend == "fused":
            if not sparse:
                raise ValueError("backend='fused' requires the sparse path")
            if mode != "coded":
                raise ValueError(
                    "backend='fused' executes the coded multicast schedule; "
                    f"use mode='coded' (got {mode!r})")
            if alloc is None:
                raise ValueError("backend='fused' needs an allocation")
        self.program = program
        self.g = g
        self.alloc = alloc
        self.mode = mode
        self.path = path                      # as requested ("auto" kept)
        self.backend = backend
        self.backend_opts = backend_opts
        self.topology = topology
        self.sparse = sparse
        self.distributed = mode != "single" and alloc is not None
        if self.distributed and mode in PLAN_MODES and plan is None:
            # Uncoded only consumes the missing set; skip the column tables.
            # CSR entry point: adjacency-free and schedule-identical to the
            # dense compile, so CSR-native graphs never materialize [n, n].
            with get_tracer().span(
                    "engine.compile", mode=mode, backend=backend, n=g.n,
                    K=alloc.K,
                    **({"racks": topology.racks,
                        "servers_per_rack": topology.servers_per_rack}
                       if hier else {})):
                if hier:
                    self.hplan = compile_hierarchical(g.csr, alloc, topology)
                    plan = self.hplan.flat
                else:
                    plan = compile_plan_csr(g.csr, alloc,
                                            schedule=mode != "uncoded")
        self.plan = plan
        self.tables = (plan.edge_tables(g.csr, alloc)
                       if sparse and self.distributed and mode in PLAN_MODES
                       else None)
        self.htables = (self.hplan.edge_tables(g.csr, alloc)
                        if self.hplan is not None and sparse
                        and self.distributed else None)
        self._fused = None
        self.recovery = None                  # faults.RepairStats after fail()
        self.delta_stats = None               # shuffle_plan.DeltaStats after update()

    @property
    def fused(self):
        """The jitted shard_map exchange, built on first use and replayed
        (compile-once / execute-many); value- and program-agnostic."""
        if self.backend == "fused" and self._fused is None:
            from .fused_shuffle import FusedSparseShuffle
            self._fused = FusedSparseShuffle(
                self.hplan if self.hplan is not None else self.plan,
                self.g.csr, self.alloc, **self.backend_opts)
        return self._fused

    def with_program(self, program: VertexProgram) -> "CompiledEngine":
        """Rebind the vertex program on the same compiled artifacts.

        No recompilation: the plan, edge tables, and fused exchange carry
        over verbatim (they never saw the program). This is the serving
        queue's per-batch hook.
        """
        eng = CompiledEngine(
            program, self.g, self.alloc, self.mode, path=self.path,
            backend=self.backend,
            plan=self.hplan if self.hplan is not None else self.plan,
            backend_opts=self.backend_opts)
        eng._fused = self._fused
        return eng

    def fail(self, servers) -> "CompiledEngine":
        """Degrade this session after `servers` crash; returns the survivors'.

        Coded modes repair the compiled schedule in place of recompiling
        (`ShufflePlan.repair`: dead senders' columns handed to healthy group
        members, bitwise-equal delivered words), so post-failure iterations
        keep the coded gain; uncoded recompiles the missing set on the
        degraded allocation. Always call on the *original* session with the
        cumulative failed set - `fail((0,)).fail((0, 1))` is not supported,
        `fail((0, 1))` is. The returned session's `.recovery` holds the
        `RepairStats` (hand-over bits, demotions, re-Mapped vertices); its
        per-iteration `run` bits include the hand-over overhead.
        """
        from .faults import RepairStats, degrade_allocation

        if not self.distributed or self.mode not in PLAN_MODES:
            raise ValueError(
                "fail() needs a distributed plan-mode session "
                f"(uncoded/coded/coded-fast; got mode={self.mode!r})")
        failed = tuple(sorted({int(s) for s in np.atleast_1d(servers)}))
        bad = [s for s in failed if not 0 <= s < self.alloc.K]
        if bad:
            raise ValueError(f"failed servers {bad} out of range 0..{self.alloc.K - 1}")
        if self.mode == "uncoded":
            degraded, dstats = degrade_allocation(self.alloc, failed)
            plan = compile_plan_csr(self.g.csr, degraded, schedule=False)
            rstats = RepairStats(failed, dstats.remapped_vertices, 0, 0)
        else:
            plan, degraded, rstats = self.plan.repair(self.g.csr, self.alloc,
                                                      failed)
            if self.hplan is not None:
                # Repair keeps the rack structure: the survivors stay in
                # their racks, so the two-level session recompiles the
                # hierarchical plan on the degraded allocation (O(edges))
                # while `rstats` keeps the flat repair's hand-over pricing.
                plan = compile_hierarchical(self.g.csr, degraded,
                                            self.topology)
        eng = CompiledEngine(self.program, self.g, degraded, self.mode,
                             path=self.path, backend=self.backend, plan=plan,
                             backend_opts=self.backend_opts)
        eng.recovery = rstats
        return eng

    def update(self, delta) -> "CompiledEngine":
        """Rebind this session to the mutated graph in O(plan + delta).

        `delta` is a `graphs.EdgeDelta`. The returned session is
        array-identical to compiling fresh on the mutated graph - the plan
        is patched by `ShufflePlan.apply_delta` (bitwise-equal schedule),
        the CSR edge tables are carried forward incrementally (no
        re-locate), and for backend="fused" the partition tables are
        rebuilt on the *same* jitted exchange, which re-lowers only if the
        padded partition shapes actually changed. The new session's
        `.delta_stats` holds the `DeltaStats`.

        Composes with `fail` both ways: `update` on a degraded session
        re-patches hand-over senders for the new schedule (its
        `.recovery.handover_bits` is refreshed), and `fail` on an updated
        session repairs the updated plan.
        """
        if not self.distributed or self.mode not in PLAN_MODES:
            raise ValueError(
                "update() needs a distributed plan-mode session "
                f"(uncoded/coded/coded-fast; got mode={self.mode!r})")
        with get_tracer().span("engine.update", mode=self.mode,
                               inserts=delta.num_insert,
                               deletes=delta.num_delete) as sp:
            csr2 = self.g.csr.apply_delta(delta)
            g2 = Graph(model=self.g.model, params=dict(self.g.params),
                       csr=csr2, dense_limit=self.g.dense_limit)
            plan2, dstats = self.plan.apply_delta(
                self.g.csr, self.alloc, delta, csr_new=csr2)
            if self.hplan is not None:
                # The flat patch prices the delta (`dstats`); the rack-level
                # stream can shift arbitrarily under it, so the two-level
                # session recompiles the hierarchy on the new CSR.
                plan2 = compile_hierarchical(csr2, self.alloc, self.topology)
            eng = CompiledEngine(self.program, g2, self.alloc, self.mode,
                                 path=self.path, backend=self.backend,
                                 plan=plan2, backend_opts=self.backend_opts)
            eng.delta_stats = dstats
            if self.recovery is not None:
                eng.recovery = (
                    dataclasses.replace(self.recovery,
                                        handover_bits=dstats.handover_bits)
                    if dstats.schedule_changed else self.recovery)
            if self._fused is not None:
                eng._fused = (self._fused if len(delta) == 0
                              else self._fused.rebind(plan2, csr2,
                                                      self.alloc))
            sp.set(schedule_changed=dstats.schedule_changed,
                   handover_bits=dstats.handover_bits)
        return eng

    def _apply_events(self, cur: "CompiledEngine", events,
                      failed: set, straggling: set, log) -> tuple["CompiledEngine", bool]:
        """Fold one boundary's fault events into the (failed, straggling)
        sets; returns (current session, whether a new crash landed)."""
        crashed = changed = False
        tr = get_tracer()
        for ev in events:
            tr.event(f"fault.{ev.kind}", at=ev.at,
                     servers=",".join(str(s) for s in ev.servers))
            if ev.kind == "crash":
                new = set(ev.servers) - failed
                if new:
                    failed |= new
                    straggling -= new
                    changed = crashed = True
                    log.crashes += 1
            elif ev.kind == "recover":
                if set(ev.servers) & failed:
                    failed.difference_update(ev.servers)
                    changed = True
                    log.recoveries += 1
                straggling.difference_update(ev.servers)
            else:                                       # "straggle"
                straggling |= set(ev.servers) - failed
            log.applied += (ev,)
        if changed:
            cur = self if not failed else self.fail(tuple(sorted(failed)))
            if cur.recovery is not None:
                log.demoted_pairs = cur.recovery.demoted_pairs
                log.remapped_vertices = cur.recovery.remapped_vertices
        return cur, crashed

    def _step(self, state: np.ndarray) -> tuple[np.ndarray, int]:
        """One Map -> Shuffle -> Reduce round; returns (state', bits_sent)."""
        program, g, alloc = self.program, self.g, self.alloc
        tr = get_tracer()
        if self.sparse:
            if self.backend == "spmv":
                # Coverage was verified when `tables` was built, so the
                # blocked kernel reads each owner's full CSR row slice; the
                # shuffled values would be recomputed per-source anyway, so
                # only the (schedule-only) bit accounting is added. Batched
                # states run the kernel per query column.
                B = 1 if state.ndim == 1 else state.shape[1]
                bits = _plan_bits(self.plan, self.mode) * B \
                    if self.distributed else 0
                return _reduce_spmv(program, g, state,
                                    **self.backend_opts), bits
            with tr.span("phase.map", nnz=g.csr.nnz):
                edge_vals = program.map_edge_values(g, state) \
                    .astype(np.float32)
            if not self.distributed:
                with tr.span("phase.reduce"):
                    return program.reduce_edges(edge_vals, g.csr.indptr,
                                                state, g), 0
            # The executor emits phase.encode / phase.exchange /
            # phase.decode spans itself (it knows words and bits).
            if self.backend == "fused":
                res = self.fused.execute(edge_vals)
            elif self.hplan is not None:
                res = self.hplan.execute_coded_sparse(edge_vals, self.htables)
            else:
                res = self.plan.execute_sparse(edge_vals, self.mode,
                                               self.tables)
            with tr.span("phase.reduce", nnz=g.csr.nnz):
                state = _reduce_sparse(program, g, edge_vals, res,
                                       self.tables.gather, state)
            return state, res.bits_sent
        with tr.span("phase.map"):
            values = program.map_values(g, state).astype(np.float32)
        if not self.distributed:
            with tr.span("phase.reduce"):
                return program.reduce(values, g.adj, state, g), 0
        if self.mode in PLAN_MODES:
            res = self.plan.execute(values, self.mode)
            with tr.span("phase.reduce"):
                return _reduce_plan(program, g, alloc, values, res,
                                    state), res.bits_sent
        if self.mode == "coded-ref":
            with tr.span("phase.exchange", mode=self.mode) as sp:
                ref = run_coded(g.adj, values, alloc)
                delivered, bits = ref.delivered, ref.bits_sent
                bits += _unicast_leftovers(g, alloc, values, delivered)
                sp.set(bits=bits)
            with tr.span("phase.reduce"):
                return _reduce_distributed(program, g, alloc, values,
                                           delivered, state), bits
        raise ValueError(f"unknown mode {self.mode!r}")

    def run(self, iters: int, state: np.ndarray | None = None, *,
            start_iter: int = 0, start_bits: int = 0,
            checkpoint=None, checkpoint_every: int = 1,
            fault_schedule=None) -> EngineResult:
        """Execute `iters` rounds from `program.init` (or a given state).

        `start_iter`/`start_bits` resume a checkpointed run: iteration
        indices continue from `start_iter` (fault-schedule boundaries and
        checkpoint epochs line up with the uninterrupted run) and the
        returned `shuffle_bits` is cumulative from `start_bits`.

        `checkpoint` (a `core.checkpoint.SessionCheckpointer`) persists
        (iteration, state, cumulative bits, current allocation) every
        `checkpoint_every` iterations and always after the final one;
        saves are atomic and run on a background thread, so a crash
        mid-save never corrupts the newest complete epoch.

        `fault_schedule` (a `faults.FaultSchedule`) applies crash /
        straggle / recover events at iteration boundaries: crashes swap in
        the repaired coded session (`fail`), recovers swap the original
        back, stragglers re-price the Shuffle per the hand-over rule
        (values are unaffected). The result's `.faults` is the `FaultLog`.
        """
        state = (self.program.init(self.g) if state is None
                 else np.asarray(state, dtype=np.float32))
        total_bits = start_bits
        cur, log = self, None
        failed: set[int] = set()
        straggling: set[int] = set()
        crash_pending = False
        if fault_schedule is not None:
            from .faults import FaultLog
            log = FaultLog()
        tr = get_tracer()
        B0 = 1 if state.ndim == 1 else state.shape[1]
        with tr.span("engine.run", mode=self.mode, backend=self.backend,
                     iters=iters, B=B0) as run_sp:
            for it in range(start_iter, start_iter + iters):
                with tr.span("engine.iteration", iteration=it) as it_sp:
                    if fault_schedule is not None:
                        cur, crashed = self._apply_events(
                            cur, fault_schedule.at(it), failed, straggling,
                            log)
                        crash_pending |= crashed
                    state, bits = cur._step(state)
                    B = 1 if state.ndim == 1 else state.shape[1]
                    if straggling and cur.mode in ("coded", "coded-fast"):
                        from .faults import _straggler_bits_plan
                        bits = _straggler_bits_plan(
                            cur.plan, tuple(sorted(straggling))) * B
                        if cur.mode == "coded":
                            bits += cur.plan.leftover_bits * B
                    if log is not None and straggling:
                        log.straggled_iters += 1
                    if cur.recovery is not None:
                        bits += cur.recovery.handover_bits * B
                        if log is not None:
                            log.handover_bits += \
                                cur.recovery.handover_bits * B
                    if crash_pending:
                        log.recovery_bits += bits
                        crash_pending = False
                    total_bits += bits
                    it_sp.set(bits=bits)
                    if checkpoint is not None and (
                            (it + 1 - start_iter) % max(checkpoint_every, 1)
                            == 0 or it == start_iter + iters - 1):
                        checkpoint.save(it + 1, state, total_bits, cur.alloc)
            run_sp.set(shuffle_bits=total_bits - start_bits)
        return EngineResult(state, start_iter + iters, total_bits, self.mode,
                            faults=log)

    def run_batch(self, states, iters: int) -> EngineResult:
        """Run B queries on ONE Shuffle exchange per iteration.

        `states` is [n, B] (or a sequence of B [n] columns, stacked here).
        The program must be batch-polymorphic (`multi_sssp`,
        `personalized_pagerank`, or any program whose map/reduce broadcast
        over a trailing query axis). Result state is [n, B]; `shuffle_bits`
        is exactly B x the single-query schedule bits.
        """
        if not self.sparse:
            raise ValueError(
                "run_batch needs the sparse path (dense [n, n] value "
                "matrices have no query axis)")
        if isinstance(states, (list, tuple)):
            st = np.stack([np.asarray(s, dtype=np.float32) for s in states],
                          axis=1)
        else:
            st = np.asarray(states, dtype=np.float32)
        if st.ndim != 2 or st.shape[0] != self.g.n:
            raise ValueError(
                f"states must be [n={self.g.n}, B]; got shape {st.shape}")
        return self.run(iters, state=st)

    def loads(self) -> dict[str, float]:
        """Exact Definition-2 loads of this session's schedule (no data
        moves; see `loads.empirical_loads`)."""
        if self.plan is None:
            raise ValueError(
                "loads() needs a compiled plan (a distributed plan mode)")
        from .loads import empirical_loads
        return empirical_loads(
            self.hplan if self.hplan is not None else self.plan, self.alloc,
            topology=self.topology)


def compile(program: VertexProgram, g: Graph, alloc: Allocation | None,
            mode: str = "coded", *, path: str = "auto",
            backend: str = "numpy",
            plan: ShufflePlan | HierarchicalPlan | None = None,
            backend_opts: dict | None = None,
            topology: Topology | None = None, **opts) -> CompiledEngine:
    """Compile a reusable execution session (see `CompiledEngine`).

    Backend options may be passed inline (``compile(..., backend="spmv",
    bm=256)``) or via `backend_opts=`; both are validated against the
    backend's accepted set. Pass a pre-compiled `plan` to share a schedule
    across sessions. A non-flat `topology` compiles the two-level
    hierarchical Shuffle (`shuffle_plan.compile_hierarchical`): coded across
    racks, plain within them, delivered words bitwise equal to the flat
    plan's.
    """
    merged = dict(backend_opts or {})
    merged.update(opts)
    return CompiledEngine(program, g, alloc, mode, path=path,
                          backend=backend, plan=plan, backend_opts=merged,
                          topology=topology)


def run(program: VertexProgram, g: Graph, alloc: Allocation | None,
        iters: int, mode: str = "coded",
        plan: ShufflePlan | HierarchicalPlan | None = None, *,
        path: str = "auto", backend: str = "numpy",
        backend_opts: dict | None = None,
        topology: Topology | None = None) -> EngineResult:
    """One-shot wrapper: `compile(...)` + `.run(iters)` (back-compat form).

    `path` picks the execution form (see module docstring); "auto" resolves
    to sparse whenever the program supplies the edge-value form. `backend`
    ("numpy" | "spmv" | "fused") selects the sparse implementation;
    `backend_opts` is forwarded to it (spmv: `bm`, `interpret` - pass
    ``{"interpret": False}`` on real TPU hardware; fused: `mesh`, `encode`,
    `interpret` - see `fused_shuffle.FusedSparseShuffle`). Unknown option
    keys raise `ValueError` naming the accepted set. Prefer `compile` when
    running the same (graph, allocation) more than once.
    """
    return compile(program, g, alloc, mode, path=path, backend=backend,
                   plan=plan, backend_opts=backend_opts,
                   topology=topology).run(iters)


def restore(directory, program: VertexProgram, g: Graph, *,
            K: int | None = None, mode: str = "coded", path: str = "auto",
            backend: str = "numpy", backend_opts: dict | None = None,
            topology: Topology | None = None, epoch: int | None = None):
    """Rebuild a session from the newest complete checkpoint under
    `directory`; returns `(CompiledEngine, SessionCheckpoint)`.

    The checkpoint carries the exact allocation (fingerprint-verified), so
    the default restore recompiles the *same* schedule and
    ``eng.run(remaining, state=ckpt.state, start_iter=ckpt.iteration,
    start_bits=ckpt.shuffle_bits)`` resumes bitwise-identically to the
    uninterrupted run (the sparse Reduce gathers in canonical CSR entry
    order, so even float-sum programs are insensitive to the allocation).
    Pass `K` != the checkpointed K for an *elastic* restore: the allocation
    is re-derived via `faults.rebalance` and a fresh plan compiled - state
    still resumes bitwise-identically, only the schedule (bits) changes.
    `epoch` pins a specific checkpoint instead of the newest.
    """
    from .checkpoint import load_checkpoint

    ckpt = load_checkpoint(directory, epoch=epoch)
    if ckpt.state.shape[0] != g.n:
        raise ValueError(
            f"checkpoint state has n={ckpt.state.shape[0]} but graph has "
            f"n={g.n}")
    alloc = ckpt.alloc
    if K is not None and alloc is not None and K != alloc.K:
        from .faults import rebalance
        alloc = rebalance(alloc, K)
    eng = compile(program, g, alloc, mode, path=path, backend=backend,
                  backend_opts=backend_opts, topology=topology)
    return eng, ckpt


def _unicast_leftovers(g: Graph, alloc: Allocation, values: np.ndarray,
                       delivered: dict[int, dict[tuple[int, int], float]]) -> int:
    """Unicast whatever the coded groups did not cover (e.g. the phase-III
    spill Reducers of the bi-partite allocation, Appendix A)."""
    bits = 0
    for k in range(alloc.K):
        for i, j in missing_pairs(g.adj, alloc, k):
            if (int(i), int(j)) not in delivered[k]:
                delivered[k][(int(i), int(j))] = float(values[i, j])
                bits += T_BITS
    return bits
