"""Deterministic synthetic data pipeline.

Stateless-by-step: batch(step) is a pure function of (seed, step), so a
restarted job resumes bit-identically from a checkpointed step - the
fault-tolerance contract checkpoint/manager.py relies on. Sharding the batch
across ('pod','data') happens at device_put time via the same logical rules
as activations.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    # Markov-ish synthetic text: makes the LM loss actually decrease.
    ngram_bias: float = 0.8


def batch_for_step(cfg: ModelConfig, shape: ShapeSpec, step: int,
                   data: DataConfig = DataConfig()) -> dict:
    """Pure function of (seed, step) -> one global batch."""
    key = jax.random.fold_in(jax.random.PRNGKey(data.seed), step)
    B, S = shape.global_batch, shape.seq_len
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.frontend == "audio":
        frames = jax.random.normal(k1, (B, S, cfg.d_model), jnp.bfloat16)
        labels = jax.random.randint(k2, (B, S), 0, cfg.vocab)
        return {"frames": frames, "labels": labels}
    if cfg.frontend == "vision":
        st = S - cfg.num_patches
        patches = jax.random.normal(k1, (B, cfg.num_patches, cfg.d_model),
                                    jnp.bfloat16)
        tokens = _tokens(k2, B, st, cfg.vocab, data)
        return {"patches": patches, "tokens": tokens, "labels": tokens}
    tokens = _tokens(k1, B, S, cfg.vocab, data)
    return {"tokens": tokens, "labels": tokens}


def _tokens(key, B, S, vocab, data: DataConfig):
    """Learnable structure: token_{t+1} = token_t + 1 (mod small alphabet)
    with probability ngram_bias, else uniform noise."""
    alpha = min(vocab, 257)
    k1, k2, k3 = jax.random.split(key, 3)
    start = jax.random.randint(k1, (B, 1), 0, alpha)
    drift = jnp.cumsum(jnp.ones((B, S), jnp.int32), axis=1) - 1
    seq = (start + drift) % alpha
    noise = jax.random.randint(k2, (B, S), 0, alpha)
    keep = jax.random.uniform(k3, (B, S)) < data.ngram_bias
    return jnp.where(keep, seq, noise).astype(jnp.int32)
