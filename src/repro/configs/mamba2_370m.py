"""mamba2-370m [ssm] - attention-free SSD [arXiv:2405.21060; unverified]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=0, vocab=50280,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4, chunk=64),
)
