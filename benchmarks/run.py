"""Benchmark driver: one module per paper table/figure (+ kernels).

Prints ``name,us_per_call,derived`` CSV. Roofline terms for the 40
(arch x shape) cells come from the dry-run (launch/dryrun.py --all); this
harness covers the paper-side experiments and kernels, which run at full
fidelity on CPU.
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    from . import (coded_moe_dispatch, fig5_load_curve, kernel_bench,
                   pagerank_phases, straggler_bench, theorem_tradeoffs)
    for mod in (fig5_load_curve, theorem_tradeoffs, pagerank_phases,
                kernel_bench, coded_moe_dispatch, straggler_bench):
        try:
            mod.run(report)
        except Exception as e:  # noqa: BLE001
            report(mod.__name__.split(".")[-1] + "_FAILED", -1.0,
                   f"{type(e).__name__}: {e}")
            raise


if __name__ == "__main__":
    main()
