"""Gradient compression: exactness of the wire primitive on one device and
convergence parity + bandwidth accounting on a real 4-device mesh
(subprocess so the host-device flag stays contained)."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp

from repro.train.compression import dequantize, quantize, wire_bytes


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.standard_normal(1000) * 5, jnp.float32)
    q, s = quantize(x)
    err = np.abs(np.asarray(dequantize(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-7          # half-ULP of the grid
    assert q.dtype == jnp.int8


def test_wire_bytes_accounting():
    params = {"a": jnp.zeros((10, 10)), "b": jnp.zeros(50)}
    assert wire_bytes(params, compressed=False) == 150 * 4
    assert wire_bytes(params, compressed=True) == 150


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.train.compression import compressed_psum_mean, ef_compress_tree, ef_state

from repro.launch.mesh import shard_map_compat

def shard_map(f, **kw):
    return shard_map_compat(f, check=False, **kw)

mesh = jax.make_mesh((4,), ("dp",))

# 1. wire primitive: compressed mean-psum ~= exact mean.
x = jnp.arange(16, dtype=jnp.float32).reshape(4, 4) / 7.0

def f(xs):
    return compressed_psum_mean(xs, "dp")

got = jax.jit(shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P("dp")))(x)
want = jnp.broadcast_to(x.reshape(4, 1, 4).mean(0), (4, 4)).reshape(4,4)
err1 = float(jnp.abs(got - want).max())

# 2. convergence parity: least squares with per-shard data, EF-compressed DP.
rng = np.random.default_rng(1)
A = jnp.array(rng.standard_normal((64, 8)), jnp.float32)
wstar = jnp.array(rng.standard_normal(8), jnp.float32)
y = A @ wstar

def loss(w, a, b):
    r = a @ w - b
    return 0.5 * jnp.mean(r * r)

def train(compressed):
    w = jnp.zeros(8)
    res = ef_state({"w": w})

    def step(w, res, a, b):
        def shard_step(ws, rs, ash, bsh):
            g = jax.grad(loss)(ws, ash, bsh)
            if compressed:
                red, new_r = ef_compress_tree({"w": g}, rs, "dp")
                return red["w"], new_r
            return jax.lax.pmean(g, "dp"), rs
        f = shard_map(shard_step, mesh=mesh,
                      in_specs=(P(), {"w": P()}, P("dp"), P("dp")),
                      out_specs=(P(), {"w": P()}))
        g, new_res = f(w, res, a, b)
        return w - 0.05 * g, new_res

    stepj = jax.jit(step)
    for _ in range(400):
        w, res = stepj(w, res, A, y)
    return float(loss(w, A, y))

l_exact = train(False)
l_comp = train(True)
print(json.dumps({"err1": err1, "l_exact": l_exact, "l_comp": l_comp}))
"""


def test_compressed_dp_converges_on_mesh():
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=420,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["err1"] < 0.02                      # int8 grid error
    assert res["l_exact"] < 1e-3
    # Error feedback keeps compressed training within striking distance.
    assert res["l_comp"] < 5e-2, res