"""gemma2-27b [dense] - local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36864, vocab=256000, act="gelu",
    attn_pattern=("local", "global"), window=4096,
    attn_softcap=50.0, logit_softcap=30.0,
)
