"""Fault tolerance built on the paper's r-fold Map redundancy (DESIGN.md §5).

The coded allocation stores every vertex at r servers, so the loss of up to
r-1 servers destroys no Map shard. On failure of server f:
  * f's Reduce partition R_f is re-assigned round-robin to survivors,
  * the compiled coded schedule is *repaired*, not abandoned:
    `ShufflePlan.repair` splices the surviving deliveries with the orphaned
    rows' recomputed needs and hands dead senders' columns to healthy group
    members (the straggler hand-over rule), so post-failure iterations keep
    the paper's inverse-linear coded gain,
  * if r <= |failed|, batches uniquely Mapped at the dead set are *re-Mapped*
    by survivors (counted as recovery compute, not shuffle bits) and pairs
    whose (r+1)-group keeps < 2 healthy members are demoted to unicast.

`run_with_failure` executes this end-to-end and must match the oracle
exactly; `FaultSchedule` scripts deterministic crash / straggle / recover
events at iteration boundaries for chaos tests (`CompiledEngine.run` and
`serve.GraphService` both drive it).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import VertexProgram
from .allocation import Allocation
from .bitcodec import T_BITS
from .engine import EngineResult
from .graph_models import Graph


@dataclasses.dataclass(frozen=True)
class RecoveryStats:
    failed: tuple[int, ...]
    remapped_vertices: int         # Map work repeated by survivors (r <= |failed| only)
    recovery_bits: int             # extra shuffle bits for recovery


@dataclasses.dataclass(frozen=True)
class RepairStats:
    """What one `ShufflePlan.repair` cost beyond the degraded schedule."""

    failed: tuple[int, ...]
    remapped_vertices: int         # vertices re-Mapped by survivors
    handover_bits: int             # per-Shuffle unicast overhead of stand-ins
    demoted_pairs: int             # coded pairs demoted to unicast leftovers


FAULT_KINDS = ("crash", "straggle", "recover")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scripted event applied at iteration boundary `at` (before the
    iteration with that index runs)."""

    at: int
    kind: str                      # "crash" | "straggle" | "recover"
    servers: tuple[int, ...]


class FaultSchedule:
    """Deterministic fault-injection script for chaos tests.

    Events fire at iteration boundaries (batch boundaries in the serving
    queue): "crash" removes servers permanently until a "recover" names
    them; "straggle" keeps servers alive but hands their coded columns over
    per the straggler rule (bit accounting only - delivered values are
    unchanged); "recover" clears both states for the named servers, after
    which execution returns to the original compiled schedule. The whole
    script is plain data, so a seeded `FaultSchedule.random` run is exactly
    reproducible.
    """

    def __init__(self, events):
        evs = []
        for ev in events:
            if not isinstance(ev, FaultEvent):
                at, kind, servers = ev
                ev = FaultEvent(int(at), str(kind),
                                tuple(int(s) for s in np.atleast_1d(servers)))
            if ev.kind not in FAULT_KINDS:
                raise ValueError(
                    f"unknown fault kind {ev.kind!r}; accepted: {FAULT_KINDS}")
            if ev.at < 0:
                raise ValueError(f"event boundary {ev.at} must be >= 0")
            evs.append(dataclasses.replace(
                ev, servers=tuple(sorted(set(ev.servers)))))
        self.events = tuple(sorted(
            evs, key=lambda e: (e.at, FAULT_KINDS.index(e.kind), e.servers)))

    def at(self, boundary: int) -> list[FaultEvent]:
        return [ev for ev in self.events if ev.at == boundary]

    @property
    def horizon(self) -> int:
        """Last boundary with an event (-1 for an empty schedule)."""
        return max((ev.at for ev in self.events), default=-1)

    def __len__(self) -> int:
        return len(self.events)

    def __repr__(self) -> str:
        return f"FaultSchedule({list(self.events)!r})"

    @classmethod
    def random(cls, K: int, iters: int, seed: int = 0, *,
               max_failed: int = 1, p_crash: float = 0.3,
               p_recover: float = 0.3) -> "FaultSchedule":
        """Seeded chaos: random crash/recover walk bounded by `max_failed`.

        Keep `max_failed < r` to stay inside the repair regime where no
        re-Map is needed and every group keeps >= 2 healthy members.
        """
        rng = np.random.default_rng(seed)
        failed: set[int] = set()
        events: list[FaultEvent] = []
        for it in range(iters):
            if failed and rng.random() < p_recover:
                s = sorted(failed)[int(rng.integers(len(failed)))]
                failed.discard(s)
                events.append(FaultEvent(it, "recover", (s,)))
            if len(failed) < max_failed and rng.random() < p_crash:
                alive = [k for k in range(K) if k not in failed]
                s = alive[int(rng.integers(len(alive)))]
                failed.add(s)
                events.append(FaultEvent(it, "crash", (s,)))
        return cls(events)


@dataclasses.dataclass
class FaultLog:
    """What a fault-injected run actually did (see `EngineResult.faults`)."""

    applied: tuple[FaultEvent, ...] = ()
    crashes: int = 0               # crash events applied
    recoveries: int = 0            # recover events applied
    straggled_iters: int = 0       # iterations run under >= 1 straggler
    handover_bits: int = 0         # cumulative stand-in unicast overhead
    demoted_pairs: int = 0         # pairs demoted at the deepest degradation
    remapped_vertices: int = 0     # vertices re-Mapped at the deepest degradation
    recovery_bits: int = 0         # bits of the first shuffle after each crash


def degrade_allocation(alloc: Allocation, failed: tuple[int, ...]) -> tuple[Allocation, RecoveryStats]:
    """Reassign failed servers' Reduce partitions; re-Map orphaned batches."""
    survivors = [k for k in range(alloc.K) if k not in failed]
    if not survivors:
        raise ValueError("all servers failed")
    reduce_owner = alloc.reduce_owner.copy()
    orphans = np.flatnonzero(np.isin(reduce_owner, failed))
    reduce_owner[orphans] = np.array(survivors)[np.arange(len(orphans)) % len(survivors)]
    map_sets = alloc.map_sets.copy()
    map_sets[list(failed), :] = False
    # Re-Map any vertex no longer Mapped anywhere (possible only if r <= |failed|).
    unmapped = np.flatnonzero(~map_sets.any(axis=0))
    for idx, v in enumerate(unmapped):
        map_sets[survivors[idx % len(survivors)], v] = True
    degraded = Allocation(alloc.n, alloc.K, alloc.r, alloc.subsets,
                          alloc.batch_of, map_sets, reduce_owner)
    stats = RecoveryStats(tuple(failed), int(len(unmapped)), 0)
    return degraded, stats


def run_with_failure(program: VertexProgram, g: Graph, alloc: Allocation,
                     iters: int, failed: tuple[int, ...],
                     fail_at_iter: int = 0,
                     mode: str = "coded") -> tuple[EngineResult, RecoveryStats]:
    """Run iterations; servers in `failed` die at `fail_at_iter` (post-Map).

    Iterations before the failure run the compiled schedule of `mode`;
    at the failure boundary the session repairs itself
    (`CompiledEngine.fail` -> `ShufflePlan.repair`), so post-failure epochs
    *keep the coded gain* instead of degrading to unicast - `mode="uncoded"`
    reproduces the legacy all-unicast fallback for A/B comparison.

    Programs with an edge-value form ride the O(edges) sparse path; others
    fall back to the dense plan executors. Bit accounting is identical
    either way (schedule-only). `stats.recovery_bits` is the first
    post-failure Shuffle's bits.
    """
    from . import engine

    failed = tuple(sorted({int(f) for f in failed}))
    sched = FaultSchedule([FaultEvent(int(fail_at_iter), "crash", failed)])
    res = engine.compile(program, g, alloc, mode).run(
        iters, fault_schedule=sched)
    log = res.faults
    stats = RecoveryStats(failed, log.remapped_vertices, log.recovery_bits)
    result = EngineResult(res.state, iters, res.shuffle_bits,
                          f"failover-{len(failed)}")
    return result, stats


def straggler_coded_load(graph, alloc: Allocation,
                         stragglers: tuple[int, ...]) -> float:
    """Normalized coded load when `stragglers` send nothing.

    When sender s straggles, the lexicographically-first healthy member s' of
    its group takes over s's coded columns. s' holds every row of s's table
    EXCEPT its own (Z^{s'} is exactly what s' is missing), so:
      * s' re-sends s's columns with the s'-row omitted (same bits; the other
        receivers strip one fewer row),
      * s'-s own segments that s owed it are unicast by a third healthy
        member (they all Mapped B_{S\\{s'}}) - that unicast is the overhead.

    `graph` is a `Graph`, a raw `CSR` view, or an already-compiled scheduled
    `ShufflePlan` - all route through `straggler_coded_load_plan`, O(plan)
    after one O(edges) CSR compile, so straggler accounting works past
    `dense_limit`. The legacy dense [n, n] subset-enumeration reference was
    removed (the plan path is exactly equal by construction; it only
    replaced the per-group |Z^k| counts); passing a dense adjacency raises
    `TypeError`.
    """
    from .graph_models import CSR, Graph
    from .shuffle_plan import ShufflePlan, compile_plan_csr

    if isinstance(graph, ShufflePlan):
        graph.check_alloc(alloc)
        return straggler_coded_load_plan(graph, stragglers)
    if isinstance(graph, (Graph, CSR)):
        csr = graph.csr if isinstance(graph, Graph) else graph
        return straggler_coded_load_plan(
            compile_plan_csr(csr, alloc, validate=False), stragglers)
    raise TypeError(
        "straggler_coded_load needs a Graph, CSR, or compiled ShufflePlan; "
        "the dense [n, n] adjacency form was removed - pass the Graph (or "
        "its .csr) so the accounting stays O(edges)")


def _group_straggler_bits(S: tuple[int, ...], sizes: dict[int, int],
                          stragglers: tuple[int, ...], r: int,
                          bounds) -> int:
    """Bits one (r+1)-group sends under stragglers; see
    `straggler_coded_load` for the hand-over accounting."""
    healthy = [x for x in S if x not in stragglers]
    if len(healthy) < 2:
        raise ValueError(f"group {S} lacks healthy senders")
    bits = 0
    for s in S:
        rows = []
        for k in S:
            if k == s:
                continue
            others = tuple(sorted(set(S) - {k}))
            a, b = bounds[others.index(s)]
            rows.append((k, sizes[k], b - a))
        ncols = max((sz for _, sz, _ in rows), default=0)
        bits += sum(max((w for _, sz, w in rows if c < sz), default=0)
                    for c in range(ncols))
        if s in stragglers:
            stand_in = next(x for x in healthy if x != s)
            # Overhead: unicast of the stand-in's own segments from row
            # s' of s's table (it cannot XOR what it does not have).
            others = tuple(sorted(set(S) - {stand_in}))
            a, b = bounds[others.index(s)]
            bits += sizes[stand_in] * (b - a)
    return bits


def _straggler_bits_plan(plan, stragglers: tuple[int, ...]) -> int:
    """Raw group bits of one coded Shuffle under `stragglers`, read off a
    compiled scheduled plan (excludes the unicast leftovers, like the dense
    reference; `straggler_coded_load_plan` normalizes it)."""
    import itertools

    from .bitcodec import segment_bounds
    from .shuffle_plan import ShufflePlan

    assert isinstance(plan, ShufflePlan)
    plan._require_schedule()
    K, r = plan.K, plan.r
    sizes: dict[tuple[int, int], int] = {}
    if plan.pair_k.size:
        gm = plan.col_gm[plan.pair_col[:, 0]]
        order = np.lexsort((plan.pair_k, gm))
        g_s, k_s = gm[order], plan.pair_k[order]
        new = np.ones(g_s.size, dtype=bool)
        new[1:] = (g_s[1:] != g_s[:-1]) | (k_s[1:] != k_s[:-1])
        starts = np.flatnonzero(new)
        counts = np.diff(np.append(starts, g_s.size))
        for gmv, kv, c in zip(g_s[starts], k_s[starts], counts):
            sizes[(int(gmv), int(kv))] = int(c)
    bounds = segment_bounds(r)
    total_bits = 0
    for S in itertools.combinations(range(K), r + 1):
        mask = sum(1 << x for x in S)
        group_sizes = {k: sizes.get((mask, k), 0) for k in S}
        total_bits += _group_straggler_bits(S, group_sizes, stragglers, r,
                                            bounds)
    return total_bits


def straggler_coded_load_plan(plan, stragglers: tuple[int, ...]) -> float:
    """`straggler_coded_load` read off a compiled scheduled `ShufflePlan`.

    The dense reference only consumes the per-(group, receiver) needed-value
    counts |Z^k_{S\\{k}}|; those are run lengths of the plan's covered-pair
    table (each pair's group is the bitmask of its segment-0 column), so the
    whole accounting is one O(P) pass plus the same C(K, r+1) group loop -
    no adjacency, hence no dense_limit ceiling. Exactly equal to the dense
    reference on the same realization.
    """
    return _straggler_bits_plan(plan, stragglers) \
        / (plan.n * plan.n * T_BITS)


def rebalance(alloc: Allocation, K_new: int, *, pad: bool = False) -> Allocation:
    """Elastic re-allocation onto K_new servers (same n, same r if feasible).

    Deterministic: allocation depends only on (n, K, r), so scale-up/down is a
    pure re-partition - checkpointed vertex state carries over unchanged.

    If n is not divisible by the new (K, C(K, r)) the strict default raises;
    `pad=True` routes through `er_allocation(pad=True)` instead (mirroring
    `graphs.allocate`): the returned allocation has
    ``alloc.n == divisible_n(n, K_new, r)`` and the graph must be padded to
    match with virtual isolated vertices (``Graph.padded(alloc.n)``).
    """
    from .allocation import divisible_n, er_allocation

    r = min(alloc.r, K_new)
    n2 = divisible_n(alloc.n, K_new, r)
    if n2 != alloc.n and not pad:
        raise ValueError(
            f"n={alloc.n} not compatible with K={K_new}, r={r}; pad to {n2} "
            f"(or pass pad=True)")
    return er_allocation(alloc.n, K_new, r, pad=pad)
