"""Sparse-path scale sweep: coded vs uncoded PageRank across n.

For each n the sweep reports per-iteration wall-clock and tracemalloc peak
memory of the sparse O(edges) engine (`path="sparse"`), coded vs uncoded,
plus one dense-vs-sparse A/B at the largest size: the dense `_reduce_plan`
path materializes K [n, n] float32 buffers per iteration, the sparse path
none - full (non-smoke) mode asserts the >= 10x acceptance speedup at
n ~ 4096, K = 10, r = 3 and bit-exactness against the sparse oracle.

CSR-native rows (PR 3): `scale_large` runs coded PageRank on a streaming-
sampled ER graph at n ~ 1e5 entirely dense-free (the graph is CSR-native,
the plan is compiled via `compile_plan_csr`, and the dense-materialization
guard makes any [n, n] touch a hard error); `scale_fixture` loads the
committed karate-club dataset, normalizes, pads, and runs coded vs uncoded
against the oracle. Full mode adds the sampler sweep to n = 3e5, asserting
O(edges) peak memory, and checks the n ~ 1e5 run bitwise vs the oracle.

The smoke rows are the committed `BENCH_scale.json` baseline; CI fails if a
smoke row's wall-clock regresses past the `benchmarks/check_regression.py`
tolerance (2x on the reference container; the CI job sets BENCH_TOL=3.0 to
absorb shared-runner hardware spread on top of that budget).
"""
import resource
import tracemalloc

import numpy as np

from repro import graphs, obs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.shuffle_plan import compile_plan, compile_plan_csr

SMOKE_CASES = [(120, 4, 2, 0.08), (360, 4, 2, 0.05)]
FULL_CASES = [(1024, 10, 3, 0.02), (2048, 10, 3, 0.01), (4096, 10, 3, 0.01)]
SAMPLER_SIZES = (100_000, 200_000, 300_000)


def _timed(prog, g, alloc, iters, mode, plan, path):
    m = obs.measure(
        lambda: engine.run(prog, g, alloc, iters, mode=mode, plan=plan,
                           path=path),
        reps=1, warmup=0, trace_memory=True)
    return m.result, m.mean_s, m.peak_bytes


def run(report, smoke=False):
    prog = algo.pagerank()
    iters = 3 if smoke else 10
    rows = []
    for n_req, K, r, p in (SMOKE_CASES if smoke else FULL_CASES):
        n = divisible_n(n_req, K, r)
        g = gm.erdos_renyi(n, p, seed=7)
        alloc = er_allocation(n, K, r)
        plan = compile_plan(g.adj, alloc)
        plan.edge_tables(g.csr, alloc)         # bind CSR once (compile side)
        prog.map_edge_values(g, prog.init(g))  # warm degree/CSR caches
        row = {"n": n, "K": K, "r": r, "edges": g.num_edges}
        for mode in ("uncoded", "coded"):
            res, dt, peak = _timed(prog, g, alloc, iters, mode, plan, "sparse")
            row[mode] = {"s_per_iter": dt / iters, "peak_mb": peak / 1e6,
                         "load": res.normalized_load}
            report(f"scale_pagerank_{mode}_n{n}", dt / iters * 1e6,
                   f"edges={g.num_edges} peak_mb={peak / 1e6:.2f} "
                   f"load={res.normalized_load:.4f}")
        rows.append(row)

    # Dense-vs-sparse A/B at the largest size (the acceptance point when
    # not smoking: n ~ 4096, K = 10, r = 3, 10-iteration coded PageRank).
    # g/alloc/plan are the last row's, reused - same seed, same realization.
    n = rows[-1]["n"]
    sp, t_sparse, peak_sparse = _timed(prog, g, alloc, iters, "coded", plan,
                                       "sparse")
    dn, t_dense, peak_dense = _timed(prog, g, alloc, iters, "coded", plan,
                                     "dense")
    assert sp.shuffle_bits == dn.shuffle_bits, "path load accounting diverged"
    np.testing.assert_allclose(sp.state, dn.state, rtol=1e-6)
    oracle = algo.reference_run(prog, g, iters)
    assert np.array_equal(sp.state, oracle), "sparse != sparse oracle"
    speedup = t_dense / t_sparse
    if not smoke:
        assert speedup >= 10.0, f"acceptance: sparse only {speedup:.1f}x"
        assert peak_sparse < n * n * 4, "sparse peak reached dense-buffer size"
    report(f"scale_dense_vs_sparse_n{n}", t_sparse / iters * 1e6,
           f"dense_s={t_dense:.3f} sparse_s={t_sparse:.3f} "
           f"speedup={speedup:.1f}x peak_dense_mb={peak_dense / 1e6:.1f} "
           f"peak_sparse_mb={peak_sparse / 1e6:.2f}")

    large = _run_large(report, prog, smoke)
    _run_fixture(report, prog)
    if not smoke:
        _sampler_sweep(report)
    return {"rows": rows, "speedup": speedup,
            "peak_sparse_mb": peak_sparse / 1e6,
            "peak_dense_mb": peak_dense / 1e6, "large": large}


def _run_large(report, prog, smoke):
    """CSR-native dense-free path at n ~ 1e5 (smoke: the CI-gated record)."""
    K, r = 4, 2
    n = divisible_n(100_000, K, r)
    iters = 2 if smoke else 10
    with obs.stopwatch() as sw_sample:
        g = graphs.erdos_renyi(n, 10.0 / n, seed=7)
    t_sample = sw_sample.s
    alloc = er_allocation(n, K, r)
    tracemalloc.start()
    with obs.stopwatch() as sw_compile:
        plan = compile_plan_csr(g.csr, alloc)      # adjacency-free compile
    t_compile = sw_compile.s
    plan.edge_tables(g.csr, alloc)                 # bind CSR (compile side)
    prog.map_edge_values(g, prog.init(g))          # warm degree/CSR caches
    _, peak_compile = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    nnz = g.csr.nnz
    assert peak_compile < 500 * nnz, \
        f"compile peak {peak_compile / 1e6:.1f}MB is not O(edges)"
    res, dt, peak = _timed(prog, g, alloc, iters, "coded", plan, "sparse")
    assert peak < 500 * nnz, f"peak {peak / 1e6:.1f}MB is not O(edges)"
    if not smoke:                                  # acceptance: bitwise
        np.testing.assert_array_equal(
            res.state, algo.reference_run(prog, g, iters, path="sparse"))
    report(f"scale_large_coded_n{n}", dt / iters * 1e6,
           f"edges={g.num_edges} p_emp={g.density:.2e} "
           f"sample_s={t_sample:.2f} compile_s={t_compile:.2f} "
           f"compile_peak_mb={peak_compile / 1e6:.1f} "
           f"peak_mb={peak / 1e6:.1f} load={res.normalized_load:.6f}")
    return {"n": n, "edges": g.num_edges, "s_per_iter": dt / iters,
            "peak_mb": peak / 1e6}


def _run_fixture(report, prog):
    """Committed real-world dataset: load, normalize, pad, coded vs uncoded."""
    g, alloc = graphs.allocate(graphs.load_fixture(), 4, 2)
    iters = 10
    ref = algo.reference_run(prog, g, iters, path="sparse")
    res_c, dt, _ = _timed(prog, g, alloc, iters, "coded", None, "sparse")
    res_u = engine.run(prog, g, alloc, iters, mode="uncoded", path="sparse")
    np.testing.assert_array_equal(res_c.state, ref)
    np.testing.assert_array_equal(res_u.state, ref)
    report(f"scale_fixture_karate_n{g.n}", dt / iters * 1e6,
           f"edges={g.num_edges} coded_load={res_c.normalized_load:.4f} "
           f"uncoded_load={res_u.normalized_load:.4f}")


def _sampler_sweep(report):
    """CSR-native sampler wall-clock + memory to n = 3e5: peak stays
    O(edges) (tracemalloc) while RSS never sees an [n, n] buffer."""
    for n in SAMPLER_SIZES:
        m = obs.measure(lambda: graphs.erdos_renyi(n, 12.0 / n, seed=1),
                        reps=1, warmup=0, trace_memory=True)
        g, dt, peak = m.result, m.mean_s, m.peak_bytes
        nnz = g.csr.nnz
        assert peak < 400 * nnz, f"sampler peak {peak / 1e6:.1f}MB not O(edges)"
        assert peak < n * n // 8, "sampler peak reached dense-buffer scale"
        rss_mb = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024
        report(f"sampler_er_n{n}", dt * 1e6,
               f"edges={g.num_edges} p_emp={g.density:.2e} "
               f"peak_mb={peak / 1e6:.1f} rss_mb={rss_mb:.0f} "
               f"bytes_per_edge={peak / max(nnz, 1):.0f}")
    with obs.stopwatch() as sw:
        g = graphs.power_law(100_000, 2.5, seed=1)
    report("sampler_pl_n100000", sw.us, f"edges={g.num_edges}")
