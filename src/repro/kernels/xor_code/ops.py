"""Jitted public wrappers for XOR encode/decode (fused TPU shuffle path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .xor_code import xor_encode_pallas


def xor_encode(rows: jnp.ndarray, valid: jnp.ndarray, *, use_kernel: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    if use_kernel:
        return xor_encode_pallas(rows, valid, interpret=interpret)
    return ref.xor_encode(rows, valid)


def xor_decode(coded: jnp.ndarray, known_rows: jnp.ndarray,
               known_valid: jnp.ndarray, *, use_kernel: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    """coded [C, W]; known_rows [r-1, C, W]; -> missing segments [C, W]."""
    strip = xor_encode(known_rows, known_valid, use_kernel=use_kernel,
                       interpret=interpret)
    return jnp.bitwise_xor(coded, strip)


def xor_encode_columns(slot_words, *, lanes: int = 128,
                       use_kernel: bool = True,
                       interpret: bool = True) -> jnp.ndarray:
    """Batched ShufflePlan route: [C, r] uint32 slot words -> [C] coded columns.

    The plan executor hands over one pre-masked segment word per (column,
    slot); invalid slots are zero, so no validity mask is needed and the
    column axis can be reshaped freely. We fold it into [r, C/lanes, lanes]
    so the Pallas kernel sees VPU-shaped uint32 tiles (lane dim 128) instead
    of W=1 slivers - this is the path that feeds the kernel realistic
    workloads (C ~ thousands of coded columns per Shuffle).

    Batched-payload route: [C, r, B] slot words (B query payloads per slot,
    the multi-query Shuffle) fold the payload axis into the column axis -
    XOR is elementwise, so the C*B fold is free - and return [C, B] coded
    columns; payload column b is bitwise the single-payload encode of its
    slice.
    """
    slot_words = jnp.asarray(slot_words, jnp.uint32)
    if slot_words.ndim == 3:                            # [C, r, B] payloads
        c, r, b = slot_words.shape
        folded = jnp.swapaxes(slot_words, 1, 2).reshape(c * b, r)
        out = xor_encode_columns(folded, lanes=lanes, use_kernel=use_kernel,
                                 interpret=interpret)
        return out.reshape(c, b)
    c, r = slot_words.shape
    if c == 0:                     # empty schedule: nothing to multicast
        return jnp.zeros(0, jnp.uint32)
    pad = (-c) % lanes
    rows = jnp.pad(slot_words, ((0, pad), (0, 0))).T    # [r, C+pad]
    rows = rows.reshape(r, (c + pad) // lanes, lanes)
    valid = jnp.ones(rows.shape[:2], dtype=jnp.bool_)
    out = xor_encode(rows, valid, use_kernel=use_kernel, interpret=interpret)
    return out.reshape(-1)[:c]


def xor_strip_columns(slot_words, *, lanes: int = 128,
                      use_kernel: bool = True,
                      interpret: bool = True) -> jnp.ndarray:
    """Per-slot strip words: [C, r] with strip[:, t] = XOR of the OTHER slots.

    This is the receiver side of the coded Shuffle: the receiver at slot t
    XORs the locally-recomputable slots out of the coded column, leaving its
    own segment (`coded ^ strip[:, t]`). r is small and static, so the
    per-slot loop unrolls into r batched kernel calls. Batched payloads
    [C, r, B] -> [C, r, B] strips via the same per-slot loop (the slot axis
    is axis 1 in both layouts).
    """
    slot_words = jnp.asarray(slot_words, jnp.uint32)
    _, r = slot_words.shape
    cols = []
    for t in range(r):
        others = slot_words.at[:, t].set(jnp.uint32(0))
        cols.append(xor_encode_columns(others, lanes=lanes,
                                       use_kernel=use_kernel,
                                       interpret=interpret))
    return jnp.stack(cols, axis=1)


def xor_encode_slots(loc: jnp.ndarray, idx: jnp.ndarray, shift: jnp.ndarray,
                     mask: jnp.ndarray, *, lanes: int = 128,
                     use_kernel: bool = True,
                     interpret: bool = True) -> jnp.ndarray:
    """Per-shard fused-path encode: one server's packed coded buffer.

    Gathers the server's slot words from its local value vector, aligns each
    segment (left-shift + keep-mask, zero for sentinel slots), then XOR-folds
    the r slots through the batched column route above - so the multi-device
    shard_map path and the single-host ShufflePlan executor share one kernel.

    loc [L+1] uint32 local words (last entry 0 = sentinel); idx [W, r] int
    into loc; shift/mask [W, r] uint32 -> [W] uint32 coded columns.
    Batched loc [L+1, B] (B payload words per local value) gathers to
    [W, r, B], the shift/mask tables broadcast behind the payload axis, and
    the batched-column route returns [W, B] coded columns.
    """
    gathered = loc[idx]
    if gathered.ndim == 3:
        shift, mask = shift[..., None], mask[..., None]
    slotw = (gathered << shift) & mask
    return xor_encode_columns(slotw, lanes=lanes, use_kernel=use_kernel,
                              interpret=interpret)


def floats_as_words(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-preserving float32 -> uint32 view (lane codec for the fused path)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def words_as_floats(w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(w.astype(jnp.uint32), jnp.float32)
