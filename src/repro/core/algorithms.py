"""Vertex programs expressed as MapReduce pairs (paper §II-A, Examples 1-2).

An algorithm supplies:
  map_values(graph, state)  -> V [n, n] float32 where V[i, j] = g_{i,j}(w_j)
                               for (i, j) in E (garbage elsewhere; the engine
                               masks with the adjacency),
  reduce(vals, mask, state) -> new state from each vertex's neighbor values,
  identity                  -> the padding value that is absorbing for reduce.

The dense-matrix form is the blocked-dense TPU adaptation (DESIGN.md §3): a
PageRank Map over a vertex block is one column-scaled adjacency tile, and the
Reduce is a masked row reduction - both MXU/VPU friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .graph_models import Graph


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    identity: float
    init: Callable[[Graph], np.ndarray]
    map_values: Callable[[Graph, np.ndarray], np.ndarray]
    reduce: Callable[[np.ndarray, np.ndarray, np.ndarray, Graph], np.ndarray]


def pagerank(damping: float = 0.15) -> VertexProgram:
    """Example 1. state = rank vector Pi; v_{i,j} = Pi(j) / deg(j)."""

    def init(g: Graph) -> np.ndarray:
        return np.full(g.n, 1.0 / g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        deg = np.maximum(g.degrees(), 1)
        contrib = (state / deg).astype(np.float32)     # per-source value
        return np.broadcast_to(contrib[None, :], (g.n, g.n))

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        acc = np.where(mask, vals, 0.0).sum(axis=1)
        return ((1.0 - damping) * acc + damping / g.n).astype(np.float32)

    return VertexProgram("pagerank", 0.0, init, map_values, reduce)


def sssp(source: int = 0) -> VertexProgram:
    """Example 2. state = distance vector D; v_{i,j} = D(j) + t(j, i)."""

    def init(g: Graph) -> np.ndarray:
        d = np.full(g.n, np.inf, dtype=np.float32)
        d[source] = 0.0
        return d

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        w = g.weights()
        return (state[None, :] + w.T).astype(np.float32)   # t(j, i) = w[j, i]

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    return VertexProgram("sssp", np.inf, init, map_values, reduce)


def connected_components() -> VertexProgram:
    """Min-label propagation; converges to per-component min vertex id."""

    def init(g: Graph) -> np.ndarray:
        return np.arange(g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.broadcast_to(state[None, :], (g.n, g.n)).astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    return VertexProgram("cc", np.inf, init, map_values, reduce)


def degree_count() -> VertexProgram:
    """Trivial one-shot program: each vertex counts its neighbors."""

    def init(g: Graph) -> np.ndarray:
        return np.zeros(g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones((g.n, g.n), dtype=np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        return np.where(mask, vals, 0.0).sum(axis=1).astype(np.float32)

    return VertexProgram("degree", 0.0, init, map_values, reduce)


def reference_run(program: VertexProgram, g: Graph, iters: int) -> np.ndarray:
    """Single-machine oracle: the engine (any mode) must match this exactly."""
    state = program.init(g)
    for _ in range(iters):
        vals = program.map_values(g, state)
        state = program.reduce(vals, g.adj, state, g)
    return state
