"""Table II experiment harness smoke/full driver (`repro.experiments`).

Smoke (CI): the committed karate fixture through the full
registry -> fetch -> ingest -> pad/allocate -> CSR-plan -> loads path, no
network - the wall-clock is the CI-gated `scale_table2_karate_n34` record.

Full: a >= 76k-vertex dataset. Uses cached soc-Epinions1 when present in
the dataset cache ($REPRO_DATA_DIR), downloading only when the operator
opted in via $REPRO_DOWNLOAD=1; otherwise the deterministic `er-76k`
synthetic stand-in (sampled/cached offline). For the ER stand-in the
measured gains are asserted against the Theorem-1 closed forms - the
acceptance contract of the Table II reproduction.
"""
import tracemalloc

from repro import obs
from repro.experiments import DatasetUnavailable, run_table2


def _full_dataset() -> str:
    try:
        from repro.experiments import fetch
        fetch("soc-Epinions1")          # cached, or $REPRO_DOWNLOAD=1
        return "soc-Epinions1"
    except DatasetUnavailable:
        return "er-76k"


def run(report, smoke=False):
    if smoke:
        with obs.stopwatch() as sw:
            result = run_table2(("karate",), K=4, r_grid=(1, 2), report=report)
        dt = sw.s
        row = result["rows"][-1]
        report(f"scale_table2_karate_n{row['n']}", dt * 1e6,
               f"offline registry->harness path, gain_r2={row['gain']:.2f}")
        return result

    name = _full_dataset()
    tracemalloc.start()
    with obs.stopwatch() as sw:
        result = run_table2((name,), K=6, r_grid=(1, 2, 3),
                            download=None,    # registry defers to the env
                            report=report)
    dt = sw.s
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    for row in result["rows"]:
        edges = row["edges"] * 2                      # directed CSR entries
        assert peak < 600 * edges, \
            f"table2 peak {peak / 1e6:.0f}MB is not O(edges)"
        if name == "er-76k":                          # ER closed-form gate
            assert row["coded"] <= row["coded_er_finite"] * 1.02, row
            assert row["coded"] >= row["lower_bound_er"] * 0.97, row
            assert 0.85 <= row["gain"] / row["r"] <= 1.02, row
    report(f"table2_{name}_total", dt * 1e6,
           f"n={result['rows'][0]['n']} edges={result['rows'][0]['edges']} "
           f"peak_mb={peak / 1e6:.0f} "
           f"gains={[round(r['gain'], 2) for r in result['rows']]}")
    return result
