"""Load accounting vs the paper's theory (Theorems 1-4, Lemma 3, Remark 10)."""
import math

import numpy as np
import pytest

from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.coded_shuffle import coded_load
from repro.core.uncoded_shuffle import uncoded_load


def _avg_loads(n, p, K, r, samples=4):
    lu, lc = [], []
    alloc = er_allocation(n, K, r)
    for s in range(samples):
        g = gm.erdos_renyi(n, p, seed=100 + s)
        lu.append(uncoded_load(g.adj, alloc))
        lc.append(coded_load(g.adj, alloc))
    return float(np.mean(lu)), float(np.mean(lc))


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_er_loads_match_theory(r):
    K, p = 5, 0.1
    n = divisible_n(300, K, r)
    lu, lc = _avg_loads(n, p, K, r)
    assert lu == pytest.approx(loads.uncoded_load_er(p, r, K), rel=0.05)
    # Coded load sits between the converse and the finite-n achievable bound.
    assert lc >= loads.lower_bound_er(p, r, K) * 0.97
    assert lc <= loads.coded_load_er_finite(n, p, r, K) * 1.02


def test_lemma3_lower_bound_is_below_measured():
    K, r, p = 5, 2, 0.1
    n = divisible_n(300, K, r)
    alloc = er_allocation(n, K, r)
    g = gm.erdos_renyi(n, p, seed=0)
    # For the proposed allocation every vertex is Mapped at exactly r servers.
    a_j = np.zeros(K)
    a_j[r - 1] = n
    lb = loads.lower_bound_lemma3(p, a_j, n, K)
    assert lb == pytest.approx(loads.lower_bound_er(p, r, K))
    assert coded_load(g.adj, alloc) >= lb * 0.97


def test_converse_convexity_argument():
    """Mixing multiplicities can't beat the uniform-r bound (eq. 65-67)."""
    K, p, r = 6, 0.2, 3
    uniform = loads.lower_bound_er(p, r, K)
    for split in [(2, 4), (1, 5), (2, 5)]:
        j1, j2 = split
        w = (j2 - r) / (j2 - j1)          # fraction at j1 so the mean is r
        a_j = np.zeros(K)
        a_j[j1 - 1] = w * 100
        a_j[j2 - 1] = (1 - w) * 100
        mixed = loads.lower_bound_lemma3(p, a_j, 100, K)
        assert mixed >= uniform - 1e-12


def test_rb_load_within_theorem2_bounds():
    n1 = n2 = 36
    K, r, q = 6, 2, 0.3
    alloc = bipartite_allocation(n1, n2, K, r)
    lcs, lus = [], []
    for s in range(4):
        g = gm.random_bipartite(n1, n2, q, seed=s)
        lcs.append(coded_load(g.adj, alloc))
        lus.append(uncoded_load(g.adj, alloc))
    lo, hi = loads.bounds_rb(q, r, K)
    # Upper bound is asymptotic; allow finite-n slack. With the balanced
    # clusters there is no phase-III spill, but phase-II coding still has to
    # cover the leftovers uncoded when K2 < r+1.
    assert np.mean(lcs) <= np.mean(lus)
    assert np.mean(lcs) / q >= lo * 0.9


def test_sbm_achievability_and_converse():
    """Theorem 3: the plain ER allocation over the union of clusters attains
    (1/r) p_eff (1 - r/K) - coding correctness never needed homogeneous edge
    probabilities. (The two-cluster Appendix-A allocation is for RB graphs,
    where it exploits the known absence of intra-cluster edges.)"""
    n1 = n2 = 45
    K, r, p, q = 6, 2, 0.3, 0.1
    n = divisible_n(n1 + n2, K, r)
    assert n == n1 + n2
    alloc = er_allocation(n, K, r, interleave=True)
    vals, uvals = [], []
    for s in range(4):
        g = gm.stochastic_block(n1, n2, p, q, seed=s)
        vals.append(coded_load(g.adj, alloc))
        uvals.append(uncoded_load(g.adj, alloc))
    ach = loads.achievable_sbm(n1, n2, p, q, r, K)
    assert loads.lower_bound_sbm(q, r, K) <= ach
    # Finite-n: measured coded load near the Theorem-3 bound, gain near r.
    assert np.mean(vals) == pytest.approx(ach, rel=0.25)
    assert np.mean(uvals) / np.mean(vals) > 0.8 * r


def test_remark10_time_model():
    t_map, t_shuffle, t_reduce = 1.649, 43.78, 0.5
    r_star = loads.optimal_r(t_map, t_shuffle)
    assert r_star == pytest.approx(5.15, abs=0.02)   # paper's Scenario-2 number
    ts = [loads.total_time_model(r, t_map, t_shuffle, t_reduce)
          for r in range(1, 11)]
    assert min(range(1, 11), key=lambda r: ts[r - 1]) == 5


def test_power_law_theorem4_bound_monotone_in_r():
    vals = [loads.achievable_pl(2.5, r, 10) for r in range(1, 10)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_uncoded_load_decreases_linearly_in_r():
    K, p = 5, 0.1
    measured = []
    for r in range(1, 5):
        n = divisible_n(300, K, r)
        lu, _ = _avg_loads(n, p, K, r, samples=2)
        measured.append(lu)
    # L^UC(r) = p(1 - r/K): successive differences constant ~ -p/K.
    diffs = np.diff(measured)
    assert np.allclose(diffs, -p / K, atol=0.004)
