"""Sparse-path scale sweep: coded vs uncoded PageRank across n.

For each n the sweep reports per-iteration wall-clock and tracemalloc peak
memory of the sparse O(edges) engine (`path="sparse"`), coded vs uncoded,
plus one dense-vs-sparse A/B at the largest size: the dense `_reduce_plan`
path materializes K [n, n] float32 buffers per iteration, the sparse path
none - full (non-smoke) mode asserts the >= 10x acceptance speedup at
n ~ 4096, K = 10, r = 3 and bit-exactness against the sparse oracle.

The smoke rows are the committed `BENCH_scale.json` baseline; CI fails if a
smoke row's wall-clock regresses by more than 2x (benchmarks/
check_regression.py).
"""
import time
import tracemalloc

import numpy as np

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.shuffle_plan import compile_plan

SMOKE_CASES = [(120, 4, 2, 0.08), (360, 4, 2, 0.05)]
FULL_CASES = [(1024, 10, 3, 0.02), (2048, 10, 3, 0.01), (4096, 10, 3, 0.01)]


def _timed(prog, g, alloc, iters, mode, plan, path):
    tracemalloc.start()
    t0 = time.perf_counter()
    res = engine.run(prog, g, alloc, iters, mode=mode, plan=plan, path=path)
    dt = time.perf_counter() - t0
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    return res, dt, peak


def run(report, smoke=False):
    prog = algo.pagerank()
    iters = 3 if smoke else 10
    rows = []
    for n_req, K, r, p in (SMOKE_CASES if smoke else FULL_CASES):
        n = divisible_n(n_req, K, r)
        g = gm.erdos_renyi(n, p, seed=7)
        alloc = er_allocation(n, K, r)
        plan = compile_plan(g.adj, alloc)
        plan.edge_tables(g.csr, alloc)         # bind CSR once (compile side)
        prog.map_edge_values(g, prog.init(g))  # warm degree/CSR caches
        row = {"n": n, "K": K, "r": r, "edges": g.num_edges}
        for mode in ("uncoded", "coded"):
            res, dt, peak = _timed(prog, g, alloc, iters, mode, plan, "sparse")
            row[mode] = {"s_per_iter": dt / iters, "peak_mb": peak / 1e6,
                         "load": res.normalized_load}
            report(f"scale_pagerank_{mode}_n{n}", dt / iters * 1e6,
                   f"edges={g.num_edges} peak_mb={peak / 1e6:.2f} "
                   f"load={res.normalized_load:.4f}")
        rows.append(row)

    # Dense-vs-sparse A/B at the largest size (the acceptance point when
    # not smoking: n ~ 4096, K = 10, r = 3, 10-iteration coded PageRank).
    # g/alloc/plan are the last row's, reused - same seed, same realization.
    n = rows[-1]["n"]
    sp, t_sparse, peak_sparse = _timed(prog, g, alloc, iters, "coded", plan,
                                       "sparse")
    dn, t_dense, peak_dense = _timed(prog, g, alloc, iters, "coded", plan,
                                     "dense")
    assert sp.shuffle_bits == dn.shuffle_bits, "path load accounting diverged"
    np.testing.assert_allclose(sp.state, dn.state, rtol=1e-6)
    oracle = algo.reference_run(prog, g, iters)
    assert np.array_equal(sp.state, oracle), "sparse != sparse oracle"
    speedup = t_dense / t_sparse
    if not smoke:
        assert speedup >= 10.0, f"acceptance: sparse only {speedup:.1f}x"
        assert peak_sparse < n * n * 4, "sparse peak reached dense-buffer size"
    report(f"scale_dense_vs_sparse_n{n}", t_sparse / iters * 1e6,
           f"dense_s={t_dense:.3f} sparse_s={t_sparse:.3f} "
           f"speedup={speedup:.1f}x peak_dense_mb={peak_dense / 1e6:.1f} "
           f"peak_sparse_mb={peak_sparse / 1e6:.2f}")
    return {"rows": rows, "speedup": speedup,
            "peak_sparse_mb": peak_sparse / 1e6,
            "peak_dense_mb": peak_dense / 1e6}
