"""Distributed-runtime substrate tests: optimizer, data, checkpoint, sharding
rules, end-to-end training with restart."""
import os
import tempfile

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, batch_for_step
from repro.launch.train import train
from repro.models import transformer as tfm
from repro.models.layers import init_params
from repro.train.optimizer import (AdamWConfig, apply_updates, global_norm,
                                   init_state, schedule)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(0))) < 2e-4
    peak = float(schedule(cfg, jnp.int32(10)))
    assert peak == pytest.approx(1e-3, rel=0.05)
    assert float(schedule(cfg, jnp.int32(99))) < peak * 0.2


def test_adamw_step_moves_toward_minimum():
    params = {"w": jnp.array([4.0, -2.0])}
    state = init_state(params)
    opt = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}          # d/dw |w|^2
        params, state = apply_updates(opt, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert int(state["step"]) == 200


def test_gradient_clipping_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = init_state(params)
    opt = AdamWConfig(lr=1.0, warmup_steps=1, clip_norm=1.0, weight_decay=0.0)
    new, _ = apply_updates(opt, params, {"w": jnp.full(3, 1e6)}, state)
    assert float(jnp.abs(new["w"]).max()) < 10.0


def test_data_pipeline_deterministic_and_step_dependent():
    cfg = configs.get("gemma-7b").reduced()
    shape = ShapeSpec("t", 32, 4, "train")
    b1 = batch_for_step(cfg, shape, 7)
    b2 = batch_for_step(cfg, shape, 7)
    b3 = batch_for_step(cfg, shape, 8)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_data_pipeline_has_learnable_structure():
    cfg = configs.get("gemma-7b").reduced()
    shape = ShapeSpec("t", 256, 8, "train")
    toks = np.asarray(batch_for_step(cfg, shape, 0)["tokens"])
    succ = (np.diff(toks, axis=1) % min(cfg.vocab, 257) == 1).mean()
    assert succ > 0.5          # ngram_bias makes most transitions +1


def test_checkpoint_roundtrip_and_gc():
    params = {"a": jnp.arange(6.0).reshape(2, 3), "n": {"b": jnp.ones(4)}}
    opt = init_state(params)
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d, keep=2)
        for s in (10, 20, 30):
            mgr.save(s, params, opt, blocking=True)
        assert mgr.steps() == [20, 30]          # keep=2 gc'd step 10
        step, p2, o2, _ = mgr.restore(params, opt)
        assert step == 30
        np.testing.assert_array_equal(p2["a"], params["a"])
        np.testing.assert_array_equal(o2["m"]["n"]["b"], opt["m"]["n"]["b"])


def test_checkpoint_atomicity_tmpdir_never_published():
    params = {"a": jnp.ones(2)}
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(5, params, blocking=True)
        assert not [f for f in os.listdir(d) if f.startswith(".tmp")]


def test_train_restart_continues_identically():
    """The fault-tolerance contract: train(2n) == train(n) + restore + train."""
    cfg = configs.get("mamba2-370m").reduced()
    shape = ShapeSpec("t", 32, 4, "train")
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
    with tempfile.TemporaryDirectory() as d:
        r_full = train(cfg, shape, 8, opt=opt, chunk=8, verbose=False,
                       log_every=1)
        train(cfg, shape, 4, opt=opt, ckpt_dir=d, ckpt_every=4, chunk=8,
              verbose=False, log_every=1)
        r_resumed = train(cfg, shape, 8, opt=opt, ckpt_dir=d, ckpt_every=100,
                          chunk=8, verbose=False, log_every=1)
        assert r_resumed.restored_from == 4
        full = dict(r_full.losses)
        resumed = dict(r_resumed.losses)
        for step in range(5, 8):
            assert full[step] == pytest.approx(resumed[step], rel=1e-4)


def test_training_reduces_loss():
    cfg = configs.get("gemma-7b").reduced()
    shape = ShapeSpec("t", 64, 8, "train")
    opt = AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=80)
    res = train(cfg, shape, 80, opt=opt, chunk=64, verbose=False, log_every=5)
    first, last = res.losses[0][1], res.losses[-1][1]
    # Clear learning signal: below uniform-over-alphabet entropy (ln 257=5.55)
    # takes longer; require a solid monotone drop in 80 steps.
    assert last < first - 0.5, (first, last)


# ---- sharding rules ----

def test_rules_divisibility_fallback():
    from repro.launch.mesh import make_mesh_auto
    from repro.sharding.rules import spec_for
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    # All dims divisible by 1: everything resolves to the first candidate.
    spec = spec_for(mesh, ("embed", "heads"), (64, 14))
    assert spec == jax.sharding.PartitionSpec("data", "model")


def test_rules_no_axis_used_twice():
    from repro.launch.mesh import make_mesh_auto
    from repro.sharding.rules import spec_for
    mesh = make_mesh_auto((1, 1), ("data", "model"))
    spec = spec_for(mesh, ("heads", "mlp"), (16, 64))   # both want 'model'
    got = [s for s in spec if s is not None]
    assert got.count("model") <= 1


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)
