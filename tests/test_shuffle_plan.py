"""ShufflePlan compile-once/execute-many vs the literal references.

The compiled plan must be *bit-exact* against `run_coded` / `run_uncoded`
(delivered values AND bits on the wire), and its compile-time load accounting
must equal the legacy subset-enumeration value.
"""
import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation, random_allocation)
from repro.core.bitcodec import (floats_to_bits, floats_to_words,
                                 words_to_floats)
from repro.core.coded_shuffle import (coded_load, coded_load_reference,
                                      run_coded)
from repro.core.loads import empirical_loads
from repro.core.shuffle_plan import compile_plan
from repro.core.uncoded_shuffle import run_uncoded, uncoded_load


def _values(g, seed=7):
    rng = np.random.default_rng(seed)
    v = rng.standard_normal((g.n, g.n)).astype(np.float32)
    return np.where(g.adj, v, 0.0).astype(np.float32)


def _er_case(K, r, n0=50, p=0.25):
    n = divisible_n(n0, K, r)
    g = gm.erdos_renyi(n, p, seed=K * 10 + r)
    return g, er_allocation(n, K, r)


def _sbm_case(K, r):
    g = gm.stochastic_block(48, 24, 0.25, 0.1, seed=K + r)
    return g, bipartite_allocation(48, 24, K, r)


def _assert_same_delivery(res, ref):
    """Delivered sets identical and every value equal at the bit level."""
    got, want = res.delivered, ref.delivered
    assert got.keys() == want.keys()
    for k in want:
        assert got[k].keys() == want[k].keys()
        for key in want[k]:
            assert (np.float32(got[k][key]).view(np.uint32)
                    == np.float32(want[k][key]).view(np.uint32)), (k, key)


@pytest.mark.parametrize("K,r", [(4, 1), (4, 2), (4, 3), (5, 2), (5, 3),
                                 (5, 4), (6, 2)])
def test_plan_coded_bit_exact_vs_reference_er(K, r):
    g, alloc = _er_case(K, r)
    vals = _values(g)
    ref = run_coded(g.adj, vals, alloc)
    plan = compile_plan(g.adj, alloc)
    res = plan.execute_coded(vals)
    assert plan.left_k.size == 0          # ER allocation: full group coverage
    assert res.bits_sent == ref.bits_sent
    _assert_same_delivery(res, ref)


@pytest.mark.parametrize("K,r", [(6, 2), (6, 3)])
def test_plan_coded_bit_exact_vs_reference_sbm(K, r):
    g, alloc = _sbm_case(K, r)
    vals = _values(g)
    ref = run_coded(g.adj, vals, alloc)
    plan = compile_plan(g.adj, alloc)
    res = plan.execute_coded(vals)
    # The reference covers only the multicast groups; the plan also carries
    # the unicast leftovers (Appendix-A spill), exactly T bits each.
    assert res.bits_sent == ref.bits_sent + plan.leftover_bits
    got = res.delivered
    for k in ref.delivered:
        for key, v in ref.delivered[k].items():
            assert (np.float32(got[k][key]).view(np.uint32)
                    == np.float32(v).view(np.uint32))


@pytest.mark.parametrize("K,r", [(4, 2), (5, 3), (6, 2)])
def test_plan_uncoded_matches_reference(K, r):
    g, alloc = _er_case(K, r, p=0.3)
    vals = _values(g)
    ref = run_uncoded(g.adj, vals, alloc)
    res = compile_plan(g.adj, alloc).execute_uncoded(vals)
    assert res.bits_sent == ref.bits_sent
    _assert_same_delivery(res, ref)


@pytest.mark.parametrize("K,r", [(4, 1), (4, 2), (5, 2), (5, 3), (5, 4),
                                 (6, 3)])
def test_plan_coded_load_matches_legacy_enumeration_er(K, r):
    g, alloc = _er_case(K, r, n0=40, p=0.3)
    assert coded_load(g.adj, alloc) == coded_load_reference(g.adj, alloc)
    measured = empirical_loads(g, alloc)
    assert measured["coded"] == coded_load_reference(g.adj, alloc)
    assert measured["uncoded"] == uncoded_load(g.adj, alloc)


@pytest.mark.parametrize("K,r", [(6, 2), (6, 3)])
def test_plan_coded_load_matches_legacy_enumeration_sbm(K, r):
    g, alloc = _sbm_case(K, r)
    assert coded_load(g.adj, alloc) == coded_load_reference(g.adj, alloc)


def test_plan_covers_random_allocation():
    """The edge-driven compiler must reproduce the subset-enumeration
    schedule on an unstructured (random) allocation too."""
    n, K, r = 60, 5, 2
    alloc = random_allocation(n, K, r, seed=3)
    g = gm.erdos_renyi(n, 0.25, seed=9)
    vals = _values(g)
    ref = run_coded(g.adj, vals, alloc)
    plan = compile_plan(g.adj, alloc)
    res = plan.execute_coded(vals)
    assert res.bits_sent == ref.bits_sent + plan.leftover_bits
    got = res.delivered
    for k in ref.delivered:
        for key, v in ref.delivered[k].items():
            assert (np.float32(got[k][key]).view(np.uint32)
                    == np.float32(v).view(np.uint32))


def test_plan_engine_modes_match_oracle_with_spill():
    """bipartite r > K2 forces unicast leftovers (phase-III spill); the plan
    engine must still match the oracle and the legacy reference bits.

    Each engine path is compared against its *same-path* oracle (the coded
    plan runs sparse by default, coded-ref is the dense dict reference);
    cross-path float sums differ only by reduction order (see algorithms.py).
    """
    g = gm.stochastic_block(48, 24, 0.25, 0.1, seed=5)
    alloc = bipartite_allocation(48, 24, 6, 3)
    plan = compile_plan(g.adj, alloc)
    assert plan.left_k.size > 0
    prog = algo.pagerank()
    res = engine.run(prog, g, alloc, 3, mode="coded")
    legacy = engine.run(prog, g, alloc, 3, mode="coded-ref")
    np.testing.assert_array_equal(res.state, algo.reference_run(prog, g, 3))
    np.testing.assert_array_equal(
        legacy.state, algo.reference_run(prog, g, 3, path="dense"))
    assert res.shuffle_bits == legacy.shuffle_bits


def test_plan_engine_bits_match_legacy_reference():
    g, alloc = _er_case(5, 3, n0=40, p=0.2)
    prog = algo.pagerank()
    legacy = engine.run(prog, g, alloc, 2, mode="coded-ref")
    # Same dense Reduce => bitwise state equality with the dict reference.
    res_dense = engine.run(prog, g, alloc, 2, mode="coded", path="dense")
    np.testing.assert_array_equal(res_dense.state, legacy.state)
    assert res_dense.shuffle_bits == legacy.shuffle_bits
    # The sparse path moves the same bits (state compared to its own oracle
    # elsewhere; float sums cross paths differ by reduction order only).
    res_sparse = engine.run(prog, g, alloc, 2, mode="coded")
    assert res_sparse.shuffle_bits == legacy.shuffle_bits


@pytest.mark.parametrize("backend", ["xor-ref", "xor-kernel"])
def test_plan_xor_code_backends_bit_exact(backend):
    """The batched route through kernels/xor_code (Pallas + jnp oracle)."""
    g, alloc = _er_case(4, 2, n0=24, p=0.3)
    vals = _values(g)
    plan = compile_plan(g.adj, alloc)
    a = plan.execute_coded(vals)
    b = plan.execute_coded(vals, backend=backend)
    assert a.bits_sent == b.bits_sent
    np.testing.assert_array_equal(a.values.view(np.uint32),
                                  b.values.view(np.uint32))


def test_plan_schedule_is_data_independent():
    """Same plan replayed over different value matrices stays bit-exact."""
    g, alloc = _er_case(5, 2)
    plan = compile_plan(g.adj, alloc)
    for seed in (1, 2, 3):
        vals = _values(g, seed=seed)
        ref = run_coded(g.adj, vals, alloc)
        res = plan.execute_coded(vals)
        assert res.bits_sent == ref.bits_sent
        _assert_same_delivery(res, ref)


def test_words_codec_consistent_with_bit_codec():
    """codec-order words: bit w of the bit-stream == bit (31-w) of the word."""
    x = np.array([0.0, -0.0, 1.5, -3.25e-12, np.inf, 7e37], dtype=np.float32)
    bits = floats_to_bits(x)
    words = floats_to_words(x)
    w = np.arange(32)
    expanded = (words[:, None] >> np.uint32(31 - w)[None, :]) & np.uint32(1)
    np.testing.assert_array_equal(expanded.astype(np.uint8), bits)
    np.testing.assert_array_equal(words_to_floats(words).view(np.uint32),
                                  x.view(np.uint32))


def test_r_equals_K_compiles_to_empty_plan():
    K = 4
    n = divisible_n(24, K, K)
    g = gm.erdos_renyi(n, 0.5, seed=0)
    plan = compile_plan(g.adj, er_allocation(n, K, K))
    assert plan.coded_bits == 0 and plan.uncoded_bits == 0
    for backend in ("numpy", "xor-ref", "xor-kernel"):
        res = plan.execute_coded(_values(g), backend=backend)
        assert res.bits_sent == 0 and res.values.size == 0


def test_missing_set_only_plan_serves_uncoded_and_guards_coded():
    g, alloc = _er_case(5, 2)
    vals = _values(g)
    lean = compile_plan(g.adj, alloc, schedule=False)
    full = compile_plan(g.adj, alloc)
    assert not lean.has_schedule and full.has_schedule
    a, b = lean.execute_uncoded(vals), full.execute_uncoded(vals)
    assert a.bits_sent == b.bits_sent
    np.testing.assert_array_equal(a.values.view(np.uint32),
                                  b.values.view(np.uint32))
    with pytest.raises(ValueError, match="schedule=False"):
        lean.execute_coded(vals)
    with pytest.raises(ValueError, match="schedule=False"):
        lean.execute_fast(vals)
    with pytest.raises(ValueError, match="schedule=False"):
        _ = lean.coded_bits
