"""Pure-jnp oracle for the Mamba2 SSD recurrence (arXiv:2405.21060).

Sequential (definitionally correct) state-space scan:
    h_t = exp(dt_t * A) * h_{t-1} + dt_t * B_t x_t^T        h in [N, P]
    y_t = C_t^T h_t + D * x_t
Per head: A, D scalars; x [L, P]; B, C [L, N].
"""
import jax
import jax.numpy as jnp


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
             C: jnp.ndarray, D: jnp.ndarray,
             h0: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [L, P], dt [L], A scalar, B/C [L, N], D scalar -> (y [L, P], h [N, P])."""
    L, P = x.shape
    N = B.shape[1]
    h0 = jnp.zeros((N, P), jnp.float32) if h0 is None else h0

    def step(h, inp):
        xt, dtt, bt, ct = inp
        a = jnp.exp(dtt * A)
        h = a * h + dtt * jnp.outer(bt, xt)
        y = ct @ h + D * xt
        return h, y

    hT, ys = jax.lax.scan(step, h0, (x.astype(jnp.float32), dt.astype(jnp.float32),
                                     B.astype(jnp.float32), C.astype(jnp.float32)))
    return ys, hT


def ssd_scan_batched(x, dt, A, B, C, D, h0=None):
    """vmapped over a leading batch*heads axis. x [G, L, P], dt [G, L],
    A [G], B/C [G, L, N], D [G]."""
    f = jax.vmap(ssd_scan, in_axes=(0, 0, 0, 0, 0, 0, 0 if h0 is not None else None))
    return f(x, dt, A, B, C, D, h0)
