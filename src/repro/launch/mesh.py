"""Production mesh definitions.

Defined as functions (never module-level constants) so importing this module
never touches jax device state - jax locks the device count on first init,
and only dryrun.py sets the 512-placeholder XLA flag.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
