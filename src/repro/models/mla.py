"""DeepSeek-V2 Multi-head Latent Attention (arXiv:2405.04434).

Queries and KV are projected through low-rank latents; only the kv_lora_rank
latent (+ the shared rope key) is cached at decode time - the paper's KV-cache
compression. Shapes follow the paper: per head qk = nope + rope dims, v has
its own head dim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import MLAConfig, ModelConfig
from .layers import ParamSpec, attend, chunked_attend, rms_norm, rope


def mla_spec(cfg: ModelConfig) -> dict:
    m: MLAConfig = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "q_a": ParamSpec((d, m.q_lora_rank), ("embed", "lora")),
        "q_a_norm": ParamSpec((m.q_lora_rank,), ("lora",), "zeros"),
        "q_b": ParamSpec((m.q_lora_rank, H, qk), ("lora", "heads", None)),
        "kv_a": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                          ("embed", "lora")),
        "kv_a_norm": ParamSpec((m.kv_lora_rank,), ("lora",), "zeros"),
        "kv_b": ParamSpec((m.kv_lora_rank, H, m.qk_nope_head_dim + m.v_head_dim),
                          ("lora", "heads", None)),
        "out": ParamSpec((H, m.v_head_dim, d), ("heads", None, "embed")),
    }


def _project(p, cfg: ModelConfig, x, positions):
    m = cfg.mla
    q_lat = rms_norm(jnp.einsum("btd,dr->btr", x, p["q_a"]), p["q_a_norm"],
                     cfg.norm_eps)
    q = jnp.einsum("btr,rhk->bthk", q_lat, p["q_b"])
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)

    kv = jnp.einsum("btd,dr->btr", x, p["kv_a"])
    kv_lat = rms_norm(kv[..., :m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps)
    k_rope = rope(kv[..., None, m.kv_lora_rank:], positions, cfg.rope_theta)
    return q_nope, q_rope, kv_lat, k_rope


def _expand_kv(p, cfg: ModelConfig, kv_lat):
    m = cfg.mla
    kvb = jnp.einsum("btr,rhk->bthk", kv_lat, p["kv_b"])
    return kvb[..., :m.qk_nope_head_dim], kvb[..., m.qk_nope_head_dim:]


def mla_attention(p, cfg: ModelConfig, x, positions, *, chunk=1024):
    """Full-sequence (train/prefill) MLA. x [B, S, d]."""
    m = cfg.mla
    q_nope, q_rope, kv_lat, k_rope = _project(p, cfg, x, positions)
    k_nope, v = _expand_kv(p, cfg, kv_lat)
    B, S, H, _ = q_nope.shape
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, k_rope.shape[:2] + (H, m.qk_rope_head_dim))], -1)
    out = chunked_attend(q, k, v, positions, positions, chunk=chunk,
                         causal=True, window=None, softcap=cfg.attn_softcap)
    return jnp.einsum("bthv,hvd->btd", out, p["out"]), (kv_lat, k_rope[:, :, 0])


def mla_decode(p, cfg: ModelConfig, x, pos, cache_lat, cache_rope, kv_valid):
    """Single-token decode against the compressed latent cache.

    cache_lat [B, S, r]; cache_rope [B, S, rope_dim]; x [B, 1, d].
    """
    m = cfg.mla
    q_nope, q_rope, kv_lat, k_rope = _project(p, cfg, x, pos)
    cache_lat = jax.lax.dynamic_update_slice_in_dim(
        cache_lat, kv_lat.astype(cache_lat.dtype), pos[0, 0], axis=1)
    cache_rope = jax.lax.dynamic_update_slice_in_dim(
        cache_rope, k_rope[:, :, 0].astype(cache_rope.dtype), pos[0, 0], axis=1)
    k_nope, v = _expand_kv(p, cfg, cache_lat)
    H = cfg.n_heads
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([
        k_nope,
        jnp.broadcast_to(cache_rope[:, :, None],
                         cache_rope.shape[:2] + (H, m.qk_rope_head_dim))], -1)
    kpos = jnp.arange(k.shape[1])[None]
    out = attend(q, k, v, pos, kpos, causal=True, window=None,
                 softcap=cfg.attn_softcap, kv_valid=kv_valid)
    return jnp.einsum("bthv,hvd->btd", out, p["out"]), cache_lat, cache_rope
