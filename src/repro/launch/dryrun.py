import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent on the
production mesh (16x16 single pod / 2x16x16 multi-pod): sharding rules apply,
the collective schedule exists, and memory_analysis shows the step fits.
cost_analysis + the optimized-HLO collective parse feed EXPERIMENTS.md
SS Dry-run / SS Roofline.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k
  python -m repro.launch.dryrun --all --json results.json
  python -m repro.launch.dryrun --arch ... --shape ... --multi-pod
"""
import argparse        # noqa: E402
import json            # noqa: E402
import sys             # noqa: E402
import time            # noqa: E402

import jax             # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from .. import configs                                      # noqa: E402
from ..configs.base import (SHAPES, ModelConfig, ShapeSpec,  # noqa: E402
                            cell_supported, input_specs)
from ..models import decode as dec                          # noqa: E402
from ..models import transformer as tfm                     # noqa: E402
from ..models.layers import abstract_params, axes_tree      # noqa: E402
from ..sharding import rules                                # noqa: E402
from ..train.optimizer import AdamWConfig                   # noqa: E402
from .mesh import make_production_mesh                      # noqa: E402
from .roofline import from_compiled                         # noqa: E402

BATCH_AXES = {
    "tokens": ("batch", None), "labels": ("batch", None),
    "frames": ("batch", None, None), "patches": ("batch", None, None),
}


def _shardings_for_batch(mesh, specs: dict):
    return {k: NamedSharding(mesh, rules.spec_for(mesh, BATCH_AXES[k], v.shape))
            for k, v in specs.items()}


def _param_trees(cfg: ModelConfig, mesh):
    spec = tfm.model_spec(cfg)
    params = abstract_params(spec)
    axes = axes_tree(spec)
    shardings = jax.tree.map(
        lambda ax, s: NamedSharding(mesh, rules.spec_for(mesh, ax, s.shape)),
        axes, params,
        is_leaf=lambda x: isinstance(x, tuple) and not isinstance(x, dict))
    return params, shardings


def _opt_trees(params, shardings, opt_dtype=jnp.float32):
    # PERF (SSPerf, llama4/train_4k iter 3): 400B-param archs cannot hold
    # fp32 m+v on 16GB/chip even at 512 chips; bf16 second/first moments
    # (stochastic-rounding-friendly) halve optimizer bytes.
    f = lambda s: jax.ShapeDtypeStruct(s.shape, opt_dtype)
    state = {"m": jax.tree.map(f, params), "v": jax.tree.map(f, params),
             "step": jax.ShapeDtypeStruct((), jnp.int32)}
    shard = {"m": shardings, "v": shardings,
             "step": NamedSharding(shardings_mesh(shardings), P())}
    return state, shard


def shardings_mesh(shardings):
    return jax.tree.leaves(shardings)[0].mesh


def _cache_trees(cfg: ModelConfig, shape: ShapeSpec, mesh,
                 cache_dtype=jnp.float32):
    # PERF (SSPerf, internlm2/decode_32k iteration 2): a bf16 cache on the
    # CPU-lowered artifact forces a full-stack bf16<->f32 convert sandwich
    # around every per-layer cache update (f32 dots). f32 storage removes it
    # here; on real TPU the native bf16 MXU dot removes it with bf16 storage.
    specs = dec.cache_specs(cfg, shape, dtype=cache_dtype)
    struct = dec.cache_struct(cfg, shape)
    shardings = {}
    for name, s in specs.items():
        if name == "pos":
            shardings[name] = NamedSharding(mesh, P())
        else:
            axes = struct[name][1]
            shardings[name] = NamedSharding(
                mesh, rules.spec_for(mesh, axes, s.shape))
    return specs, shardings


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               accum: int = 8, chunk: int = 1024, verbose: bool = True,
               opt_dtype=jnp.float32, moe_ep: bool = False):
    import dataclasses
    cfg = configs.get(arch)
    if moe_ep and cfg.moe:
        # shard_map expert parallelism: experts shard over 'data', so the
        # param rule chain must lead with 'data' for this lowering.
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, ep=True))
        rules.LOGICAL_RULES["expert"] = ("data", "model", None)
    shape = SHAPES[shape_name]
    ok, why = cell_supported(cfg, shape)
    if not ok:
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "multi" if multi_pod else "single",
                  "status": "skip", "reason": why}
        if verbose:
            print(json.dumps(result), flush=True)
        return result
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules.set_mesh(mesh)
    t0 = time.time()
    try:
        batch_specs = input_specs(cfg, shape)
        batch_shard = _shardings_for_batch(mesh, batch_specs)
        params, pshard = _param_trees(cfg, mesh)

        if shape.kind == "train":
            opt_state, oshard = _opt_trees(params, pshard, opt_dtype)
            opt = AdamWConfig()
            a = accum if shape.global_batch % accum == 0 else 1

            def train_fn(p, s, b):
                from ..train.step import train_step
                return train_step(p, s, b, cfg=cfg, opt=opt, accum=a,
                                  chunk=chunk)

            fn = jax.jit(train_fn,
                         in_shardings=(pshard, oshard, batch_shard),
                         out_shardings=(pshard, oshard, NamedSharding(mesh, P())),
                         donate_argnums=(0, 1))
            args = (params, opt_state, batch_specs)
        elif shape.kind == "prefill":
            def prefill_fn(p, b):
                return dec.prefill(p, cfg, b, chunk=chunk)

            fn = jax.jit(prefill_fn, in_shardings=(pshard, batch_shard))
            args = (params, batch_specs)
        else:  # decode
            cache_specs_, cshard = _cache_trees(cfg, shape, mesh)

            def serve_fn(p, c, b):
                return dec.decode_step(p, cfg, c, b)

            fn = jax.jit(serve_fn,
                         in_shardings=(pshard, cshard, batch_shard),
                         out_shardings=(NamedSharding(
                             mesh, rules.spec_for(mesh, ("batch", "vocab"),
                                                  (shape.global_batch, cfg.vocab))),
                             cshard),
                         donate_argnums=(1,))
            args = (params, cache_specs_, batch_specs)

        with mesh:
            lowered = fn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            roof = from_compiled(compiled, chips)

        tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
        if shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
        mult = 6 if shape.kind == "train" else 2
        model_flops = mult * cfg.active_param_count() * tokens / chips
        result = {
            "arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "status": "ok", "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "bytes_per_device": int(mem.argument_size_in_bytes
                                    + mem.output_size_in_bytes
                                    + mem.temp_size_in_bytes
                                    - mem.alias_size_in_bytes),
            "arg_bytes": int(mem.argument_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "model_flops_per_device": model_flops,
            **roof.as_dict(),
            "useful_flops_ratio": model_flops / max(roof.flops_per_device, 1.0),
            "roofline_fraction": roof.compute_fraction(model_flops),
        }
    except Exception as e:  # noqa: BLE001 - dry-run failures are findings
        result = {"arch": arch, "shape": shape_name,
                  "mesh": "multi" if multi_pod else "single",
                  "status": "fail", "error": f"{type(e).__name__}: {e}"}
    finally:
        rules.set_mesh(None)
        if moe_ep:
            rules.LOGICAL_RULES["expert"] = ("model", None)
    if verbose:
        print(json.dumps(result), flush=True)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--accum", type=int, default=8)
    ap.add_argument("--chunk", type=int, default=1024)
    ap.add_argument("--json", default=None)
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in configs.ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape, False))
                cells.append((arch, shape, True))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        meshes = [args.multi_pod] if not args.both_meshes else [False, True]
        cells = [(args.arch, args.shape, m) for m in meshes]

    results = [lower_cell(a, s, multi_pod=m, accum=args.accum,
                          chunk=args.chunk) for a, s, m in cells]
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    bad = [r for r in results if r["status"] == "fail"]
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
