"""Edge mutation batches for dynamic graphs (`EdgeDelta`).

A delta is a batch of undirected edge insertions and deletions against one
graph realization. It is the unit the incremental-maintenance path consumes:
`CSR.apply_delta` mutates the canonical CSR without re-sorting untouched
rows, `ShufflePlan.apply_delta` patches the compiled coded-Shuffle schedule
in O(plan + delta) with no sorting pass, and `CompiledEngine.update` /
`GraphService.update` carry the mutation through the session and serving
layers.

Validation happens HERE, at construction, not at apply time: every endpoint
must name a real vertex. In particular ids in the virtual padded range of
`Graph.padded` are rejected - padding works precisely because virtual
vertices are isolated by construction (no edges, no Map values, no Shuffle
traffic), and an edge silently landing there would mis-bind the plan's edge
tables against that invariant. Rows are canonicalized to (min, max) and
sorted, so a delta is a *set* of undirected edges per side.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.graph_models import Graph

__all__ = ["EdgeDelta"]


def _as_pairs(edges, what: str) -> np.ndarray:
    """[D, 2] int64 canonical (min, max) rows, sorted lexicographically."""
    arr = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges)
    if arr.size == 0:
        return np.zeros((0, 2), dtype=np.int64)
    if arr.ndim != 2 or arr.shape[1] != 2:
        raise ValueError(
            f"{what} edges must be pairs (shape [D, 2]); got shape "
            f"{arr.shape}")
    if not np.issubdtype(arr.dtype, np.integer):
        raise ValueError(
            f"{what} edge endpoints must be integer vertex ids; got dtype "
            f"{arr.dtype}")
    arr = arr.astype(np.int64)
    lo = np.minimum(arr[:, 0], arr[:, 1])
    hi = np.maximum(arr[:, 0], arr[:, 1])
    order = np.lexsort((hi, lo))
    return np.column_stack([lo[order], hi[order]])


@dataclasses.dataclass(frozen=True)
class EdgeDelta:
    """One batch of undirected edge mutations against an n-vertex graph.

    `insert` / `delete` are [D, 2] arrays of undirected endpoint pairs
    (any iterable of pairs is accepted; rows are canonicalized to
    (min, max) and sorted). `n` is the graph size the delta binds to and
    `real_n` the bound of *mutable* vertices: for a graph padded with
    virtual isolated vertices (`Graph.padded`), ``real_n < n`` and any
    endpoint in ``[real_n, n)`` raises - virtual vertices must stay
    isolated or the padding contract (and every edge-table binding built
    on it) breaks. Use `EdgeDelta.for_graph` to derive both bounds from a
    `Graph` (it reads ``params["padded_from"]``).

    Whether an inserted edge already exists (or a deleted one does not)
    is a property of the *graph*, not the batch - `CSR.apply_delta`
    raises there.
    """

    insert: np.ndarray
    delete: np.ndarray
    n: int
    real_n: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "insert", _as_pairs(self.insert, "insert"))
        object.__setattr__(self, "delete", _as_pairs(self.delete, "delete"))
        object.__setattr__(self, "n", int(self.n))
        real_n = self.n if self.real_n is None else int(self.real_n)
        object.__setattr__(self, "real_n", real_n)
        if not 0 <= real_n <= self.n:
            raise ValueError(
                f"real_n={real_n} must lie in [0, n={self.n}]")
        for what, arr in (("insert", self.insert), ("delete", self.delete)):
            if arr.size == 0:
                continue
            u, v = arr[:, 0], arr[:, 1]
            bad = (u < 0) | (v >= self.n)
            if bad.any():
                raise ValueError(
                    f"{what} edge {tuple(arr[np.flatnonzero(bad)[0]])} is "
                    f"out of range for an n={self.n} graph")
            if (u == v).any():
                loop = arr[np.flatnonzero(u == v)[0], 0]
                raise ValueError(
                    f"{what} edge ({loop}, {loop}) is a self-loop; graphs "
                    f"are simple")
            pad = v >= real_n
            if pad.any():
                e = tuple(arr[np.flatnonzero(pad)[0]])
                raise ValueError(
                    f"{what} edge {e} touches the virtual padded range "
                    f"[{real_n}, {self.n}): padded vertices are isolated "
                    f"by construction and must stay that way (mutate the "
                    f"unpadded graph instead)")
            if arr.shape[0] > 1 and (np.diff(arr[:, 0]) == 0)[
                    np.diff(arr[:, 1]) == 0].any():
                dup = arr[1:][(arr[1:] == arr[:-1]).all(axis=1)]
                if dup.size:
                    raise ValueError(
                        f"{what} lists edge {tuple(dup[0])} more than once")
        if self.insert.size and self.delete.size:
            ik = self.insert[:, 0] * self.n + self.insert[:, 1]
            dk = self.delete[:, 0] * self.n + self.delete[:, 1]
            both = np.intersect1d(ik, dk)
            if both.size:
                e = (int(both[0]) // self.n, int(both[0]) % self.n)
                raise ValueError(
                    f"edge {e} appears in both insert and delete; a delta "
                    f"is unordered, split it into two batches")

    @classmethod
    def for_graph(cls, g: Graph, insert=(), delete=()) -> "EdgeDelta":
        """Delta bound to `g`'s vertex set, honoring its padding: for a
        `Graph.padded` result the mutable bound is the pre-padding n
        (``params["padded_from"]``)."""
        return cls(insert=insert, delete=delete, n=g.n,
                   real_n=g.params.get("padded_from", g.n))

    @property
    def num_insert(self) -> int:
        return int(self.insert.shape[0])

    @property
    def num_delete(self) -> int:
        return int(self.delete.shape[0])

    def __len__(self) -> int:
        return self.num_insert + self.num_delete

    def __repr__(self) -> str:
        return (f"EdgeDelta(+{self.num_insert}, -{self.num_delete}, "
                f"n={self.n})")
