"""Two-level (racks x servers) fused Shuffle parity (8 forced host devices).

Acceptance criterion of the topology refactor: the hierarchical fused
exchange - coded XOR all_gather on the 'racks' mesh axis, plain
gather/scatter on 'servers' - must deliver *bitwise-identical* uint32 words
to the flat NumPy plan executor across er/pl/sbm x {pagerank, sssp}, both
rack shapes (R=4,S=2 and R=2,S=4), the unicast-leftover spill, and batched
[.., B] payloads, and `engine.run(..., topology=, backend="fused")` must
reproduce the flat engine state bitwise.

Runs in subprocesses so the 8-device host-platform flag never leaks into
other tests (HOME + JAX_PLATFORMS=cpu passed through per the ROADMAP note).
"""
import json
import os
import subprocess
import sys

PREAMBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.bitcodec import floats_to_words
from repro.core.fused_shuffle import FusedSparseShuffle
from repro.core.shuffle_plan import compile_hierarchical, compile_plan_csr
from repro.launch.mesh import Topology

out = {}


def case(model, K=8, r=2):
    if model == "er":
        n = divisible_n(96, K, r)
        return (graphs.erdos_renyi(n, 0.15, seed=11),
                er_allocation(n, K, r, interleave=True))
    if model == "pl":
        n = divisible_n(96, K, r)
        return (graphs.power_law(n, 2.5, seed=9),
                er_allocation(n, K, r, interleave=True))
    if model == "sbm":
        n = divisible_n(112, K, r)
        return (graphs.stochastic_block(n // 2, n // 2, 0.25, 0.05, seed=5),
                er_allocation(n, K, r, interleave=True))
    raise ValueError(model)


def parity(g, alloc, topo, prog, iters=2, B=0, **kw):
    # The flat NumPy executor is the oracle: the hierarchical fused words
    # must match it bitwise, round after round on the same jitted exchange.
    hplan = compile_hierarchical(g.csr, alloc, topo)
    tables = hplan.flat.edge_tables(g.csr, alloc)
    fx = FusedSparseShuffle(hplan, g.csr, alloc, **kw)
    state = prog.init(g)
    if B:
        assert state.ndim == 2 and state.shape[1] == B  # batch-native program
    ok = True
    for _ in range(iters):
        ev = prog.map_edge_values(g, state).astype(np.float32)
        ref = hplan.flat.execute_coded_sparse(ev, tables)
        res = fx.execute(ev)
        ok = ok and np.array_equal(floats_to_words(ref.values),
                                   floats_to_words(res.values))
        buf = np.concatenate([ev, ref.values])
        state = prog.reduce_edges(buf[tables.gather], g.csr.indptr, state, g)
    return bool(ok)
"""

SCRIPT_PARITY = PREAMBLE + r"""
for model in ("er", "sbm", "pl"):
    g, alloc = case(model)
    for topo in (Topology(4, 2), Topology(2, 4)):
        for prog in (algo.pagerank(), algo.sssp(0)):
            key = f"{model}_{prog.name}_{topo.racks}x{topo.servers_per_rack}"
            out[key] = parity(g, alloc, topo, prog)

# Batched [.., B] payloads ride the same two-level exchange.
g, alloc = case("er")
out["batched_B3"] = parity(g, alloc, Topology(4, 2),
                           algo.multi_sssp([0, 3, 11]), B=3)

# Unicast-leftover spill (bipartite r > K2) + non-trivial rack leftovers.
g, alloc = (graphs.random_bipartite(32, 18, 0.3, seed=5),
            bipartite_allocation(32, 18, 6, 3))
out["spill_has_leftovers"] = bool(
    compile_plan_csr(g.csr, alloc).left_k.size > 0)
for topo in (Topology(3, 2), Topology(2, 3)):
    key = f"spill_{topo.racks}x{topo.servers_per_rack}"
    out[key] = parity(g, alloc, topo, algo.pagerank())

# jnp encode route (no Pallas) on the two-level mesh.
g, alloc = case("er")
out["encode_jnp"] = parity(g, alloc, Topology(2, 4), algo.pagerank(),
                           iters=1, encode="jnp")
print(json.dumps(out))
"""

SCRIPT_ENGINE = PREAMBLE + r"""
# engine.run(topology=, backend="fused") == flat numpy engine, bitwise.
g, alloc = case("sbm")
prog = algo.pagerank()
rn = engine.run(prog, g, alloc, 6, mode="coded", path="sparse")
for topo in (Topology(4, 2), Topology(2, 4)):
    rf = engine.run(prog, g, alloc, 6, mode="coded", path="sparse",
                    backend="fused", topology=topo)
    key = f"engine_fused_{topo.racks}x{topo.servers_per_rack}"
    out[key] = bool(np.array_equal(floats_to_words(rn.state),
                                   floats_to_words(rf.state)))
    rh = engine.run(prog, g, alloc, 6, mode="coded", path="sparse",
                    topology=topo)
    out[key + "_numpy"] = bool(np.array_equal(floats_to_words(rn.state),
                                              floats_to_words(rh.state)))
    # numpy and fused hierarchical sessions price the Shuffle identically.
    out[key + "_bits"] = bool(rf.shuffle_bits == rh.shuffle_bits)

# The flat-topology front door degenerates to the flat fused session.
eng = engine.compile(prog, g, alloc, "coded", backend="fused",
                     topology=Topology.flat(alloc.K))
out["flat_degenerate"] = bool(eng.hplan is None
                              and eng.fused._hier is False)
rd = eng.run(6)
out["flat_degenerate_bitwise"] = bool(np.array_equal(
    floats_to_words(rn.state), floats_to_words(rd.state)))

# fail() keeps the rack structure on the fused two-level session.
ef = engine.compile(prog, g, alloc, "coded", backend="fused",
                    topology=Topology(4, 2)).fail((3,))
out["fail_keeps_racks"] = bool(ef.hplan is not None
                               and ef.hplan.topology == Topology(4, 2))
rfail = ef.run(4)
rref = engine.compile(prog, g, alloc, "coded").fail((3,)).run(4)
out["fail_bitwise"] = bool(np.array_equal(floats_to_words(rref.state),
                                          floats_to_words(rfail.state)))
print(json.dumps(out))
"""


def _run(script, timeout=900):
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_hierarchical_fused_word_parity_models_programs_spill_batched():
    res = _run(SCRIPT_PARITY)
    for model in ("er", "sbm", "pl"):
        for prog in ("pagerank", "sssp"):
            for shape in ("4x2", "2x4"):
                assert res[f"{model}_{prog}_{shape}"], (model, prog, shape)
    assert res["batched_B3"]
    assert res["spill_has_leftovers"]
    assert res["spill_3x2"] and res["spill_2x3"]
    assert res["encode_jnp"]


def test_hierarchical_engine_fused_and_fault_composition():
    res = _run(SCRIPT_ENGINE)
    for shape in ("4x2", "2x4"):
        assert res[f"engine_fused_{shape}"], shape
        assert res[f"engine_fused_{shape}_numpy"], shape
        assert res[f"engine_fused_{shape}_bits"], shape
    assert res["flat_degenerate"]
    assert res["flat_degenerate_bitwise"]
    assert res["fail_keeps_racks"]
    assert res["fail_bitwise"]
