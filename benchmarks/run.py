"""Benchmark driver: one module per paper table/figure (+ kernels).

Prints ``name,us_per_call,derived`` CSV. Roofline terms for the 40
(arch x shape) cells come from the dry-run (launch/dryrun.py --all); this
harness covers the paper-side experiments and kernels, which run at full
fidelity on CPU.

``--smoke`` shrinks every module that supports it to CI-sized problems;
``--json PATH`` additionally writes the records as JSON (the CI benchmark
job uploads that file as an artifact).
"""
from __future__ import annotations

import argparse
import inspect
import json
import pathlib
import sys


def _modules():
    """Benchmark modules, importable both via -m and as a plain script."""
    try:
        from . import (batched_sweep, coded_moe_dispatch, delta_sweep,
                       fig5_load_curve, fused_sweep, hierarchy_sweep,
                       kernel_bench, pagerank_phases, phase_profile,
                       recovery_bench, scale_sweep, straggler_bench,
                       table2_snap, theorem_tradeoffs)
    except ImportError:
        root = pathlib.Path(__file__).resolve().parents[1]
        sys.path[:0] = [str(root), str(root / "src")]
        from benchmarks import (batched_sweep, coded_moe_dispatch,
                                delta_sweep, fig5_load_curve, fused_sweep,
                                hierarchy_sweep, kernel_bench,
                                pagerank_phases, phase_profile,
                                recovery_bench, scale_sweep, straggler_bench,
                                table2_snap, theorem_tradeoffs)
    return (fig5_load_curve, theorem_tradeoffs, pagerank_phases, scale_sweep,
            batched_sweep, fused_sweep, kernel_bench, coded_moe_dispatch,
            straggler_bench, table2_snap, recovery_bench, phase_profile,
            delta_sweep, hierarchy_sweep)


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem sizes (CI benchmark gate)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write records as JSON to PATH")
    args = ap.parse_args(argv)

    records: list[dict] = []
    if args.json:                  # fail fast on an unwritable path
        with open(args.json, "w") as f:
            json.dump({"smoke": args.smoke, "records": records}, f)

    print("name,us_per_call,derived")

    def report(name: str, us: float, derived: str = ""):
        print(f"{name},{us:.1f},{derived}", flush=True)
        records.append({"name": name, "us_per_call": us, "derived": derived})

    for mod in _modules():
        kwargs = {}
        if args.smoke and "smoke" in inspect.signature(mod.run).parameters:
            kwargs["smoke"] = True
        try:
            mod.run(report, **kwargs)
        except Exception as e:  # noqa: BLE001
            report(mod.__name__.split(".")[-1] + "_FAILED", -1.0,
                   f"{type(e).__name__}: {e}")
            raise
        finally:
            if args.json:
                with open(args.json, "w") as f:
                    json.dump({"smoke": args.smoke, "records": records}, f,
                              indent=2)


if __name__ == "__main__":
    main()
