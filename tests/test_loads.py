"""Load accounting vs the paper's theory (Theorems 1-4, Lemma 3, Remark 10)."""
import math

import numpy as np
import pytest

from repro import graphs
from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.coded_shuffle import coded_load
from repro.core.shuffle_plan import compile_plan_csr
from repro.core.uncoded_shuffle import uncoded_load


def _avg_loads(n, p, K, r, samples=4):
    lu, lc = [], []
    alloc = er_allocation(n, K, r)
    for s in range(samples):
        g = gm.erdos_renyi(n, p, seed=100 + s)
        lu.append(uncoded_load(g.adj, alloc))
        lc.append(coded_load(g.adj, alloc))
    return float(np.mean(lu)), float(np.mean(lc))


@pytest.mark.parametrize("r", [1, 2, 3, 4])
def test_er_loads_match_theory(r):
    K, p = 5, 0.1
    n = divisible_n(300, K, r)
    lu, lc = _avg_loads(n, p, K, r)
    assert lu == pytest.approx(loads.uncoded_load_er(p, r, K), rel=0.05)
    # Coded load sits between the converse and the finite-n achievable bound.
    assert lc >= loads.lower_bound_er(p, r, K) * 0.97
    assert lc <= loads.coded_load_er_finite(n, p, r, K) * 1.02


def test_lemma3_lower_bound_is_below_measured():
    K, r, p = 5, 2, 0.1
    n = divisible_n(300, K, r)
    alloc = er_allocation(n, K, r)
    g = gm.erdos_renyi(n, p, seed=0)
    # For the proposed allocation every vertex is Mapped at exactly r servers.
    a_j = np.zeros(K)
    a_j[r - 1] = n
    lb = loads.lower_bound_lemma3(p, a_j, n, K)
    assert lb == pytest.approx(loads.lower_bound_er(p, r, K))
    assert coded_load(g.adj, alloc) >= lb * 0.97


def test_converse_convexity_argument():
    """Mixing multiplicities can't beat the uniform-r bound (eq. 65-67)."""
    K, p, r = 6, 0.2, 3
    uniform = loads.lower_bound_er(p, r, K)
    for split in [(2, 4), (1, 5), (2, 5)]:
        j1, j2 = split
        w = (j2 - r) / (j2 - j1)          # fraction at j1 so the mean is r
        a_j = np.zeros(K)
        a_j[j1 - 1] = w * 100
        a_j[j2 - 1] = (1 - w) * 100
        mixed = loads.lower_bound_lemma3(p, a_j, 100, K)
        assert mixed >= uniform - 1e-12


def test_rb_load_within_theorem2_bounds():
    n1 = n2 = 36
    K, r, q = 6, 2, 0.3
    alloc = bipartite_allocation(n1, n2, K, r)
    lcs, lus = [], []
    for s in range(4):
        g = gm.random_bipartite(n1, n2, q, seed=s)
        lcs.append(coded_load(g.adj, alloc))
        lus.append(uncoded_load(g.adj, alloc))
    lo, hi = loads.bounds_rb(q, r, K)
    # Upper bound is asymptotic; allow finite-n slack. With the balanced
    # clusters there is no phase-III spill, but phase-II coding still has to
    # cover the leftovers uncoded when K2 < r+1.
    assert np.mean(lcs) <= np.mean(lus)
    assert np.mean(lcs) / q >= lo * 0.9


def test_sbm_achievability_and_converse():
    """Theorem 3: the plain ER allocation over the union of clusters attains
    (1/r) p_eff (1 - r/K) - coding correctness never needed homogeneous edge
    probabilities. (The two-cluster Appendix-A allocation is for RB graphs,
    where it exploits the known absence of intra-cluster edges.)"""
    n1 = n2 = 45
    K, r, p, q = 6, 2, 0.3, 0.1
    n = divisible_n(n1 + n2, K, r)
    assert n == n1 + n2
    alloc = er_allocation(n, K, r, interleave=True)
    vals, uvals = [], []
    for s in range(4):
        g = gm.stochastic_block(n1, n2, p, q, seed=s)
        vals.append(coded_load(g.adj, alloc))
        uvals.append(uncoded_load(g.adj, alloc))
    ach = loads.achievable_sbm(n1, n2, p, q, r, K)
    assert loads.lower_bound_sbm(q, r, K) <= ach
    # Finite-n: measured coded load near the Theorem-3 bound, gain near r.
    assert np.mean(vals) == pytest.approx(ach, rel=0.25)
    assert np.mean(uvals) / np.mean(vals) > 0.8 * r


@pytest.mark.parametrize("model,kw,mk_alloc", [
    ("er", dict(n=60, p=0.15), lambda: er_allocation(60, 5, 2)),
    ("rb", dict(n1=36, n2=36, q=0.2), lambda: bipartite_allocation(36, 36, 6, 2)),
    ("sbm", dict(n1=30, n2=30, p=0.25, q=0.08),
     lambda: er_allocation(60, 5, 2, interleave=True)),
    ("pl", dict(n=60, gamma=2.5),
     lambda: er_allocation(60, 5, 2, interleave=True)),
])
def test_empirical_loads_forms_agree_and_dense_rejected(model, kw, mk_alloc):
    """`empirical_loads` accepts Graph / CSR / compiled plan - every form is
    bitwise equal on all 4 models (one schedule underneath) - and the
    removed dense-adjacency form now raises TypeError."""
    g = graphs.sample(model, seed=3, **kw)
    alloc = mk_alloc()
    want = loads.empirical_loads(g, alloc)
    assert loads.empirical_loads(g.csr, alloc) == want
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    assert loads.empirical_loads(plan, alloc) == want
    with pytest.raises(TypeError, match="dense .* form was removed"):
        loads.empirical_loads(g.adj, alloc)


def test_empirical_loads_plan_alloc_mismatch_raises():
    alloc = er_allocation(60, 5, 2)
    g = graphs.erdos_renyi(60, 0.15, seed=0)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    with pytest.raises(ValueError, match="compiled for \\(n=60"):
        loads.empirical_loads(plan, er_allocation(80, 5, 2, pad=True))
    # Same n but different r (the stale-plan-in-an-r-sweep mistake).
    with pytest.raises(ValueError, match="r=2.*expects.*r=3"):
        loads.empirical_loads(plan, er_allocation(60, 5, 3, pad=True))


def test_empirical_loads_runs_past_dense_limit():
    """The regression that motivated PR 5: measuring loads used to require
    the dense [n, n] view, which hard-crashes above `dense_limit`."""
    n = divisible_n(21_000, 4, 2)                    # > DENSE_LIMIT = 20_000
    g = graphs.erdos_renyi(n, 30.0 / n, seed=1)
    assert g.n > gm.DENSE_LIMIT
    measured = loads.empirical_loads(g, er_allocation(n, 4, 2))
    assert 0 < measured["coded"] < measured["uncoded"]
    assert measured["gain"] > 1.5


def test_remark10_time_model():
    t_map, t_shuffle, t_reduce = 1.649, 43.78, 0.5
    r_star = loads.optimal_r(t_map, t_shuffle)
    assert r_star == pytest.approx(5.15, abs=0.02)   # paper's Scenario-2 number
    ts = [loads.total_time_model(r, t_map, t_shuffle, t_reduce)
          for r in range(1, 11)]
    assert min(range(1, 11), key=lambda r: ts[r - 1]) == 5


def test_power_law_theorem4_bound_monotone_in_r():
    vals = [loads.achievable_pl(2.5, r, 10) for r in range(1, 10)]
    assert all(a > b for a, b in zip(vals, vals[1:]))


def test_uncoded_load_decreases_linearly_in_r():
    K, p = 5, 0.1
    measured = []
    for r in range(1, 5):
        n = divisible_n(300, K, r)
        lu, _ = _avg_loads(n, p, K, r, samples=2)
        measured.append(lu)
    # L^UC(r) = p(1 - r/K): successive differences constant ~ -p/K.
    diffs = np.diff(measured)
    assert np.allclose(diffs, -p / K, atol=0.004)
