"""Admission-batching request queue over one compiled coded-Shuffle session.

Serving shape: queries arrive one at a time, but the exchange is cheapest
per query when B of them ride one Shuffle (schedule bits are paid once per
payload column, never per compile). The queue therefore trades a bounded
admission delay (`max_wait_s`) for batch width (`max_batch`), exactly the
admission-batching pattern of inference servers.

Batches must share a program family and an iteration count to fuse into one
run, so the queue keeps one lane per (kind, iters) pair and admits from the
fullest lane first. Per admitted batch it builds the batched program
(`multi_sssp` over the collected roots, `personalized_pagerank` over the
stacked preference columns) and rebinds it on the session via
`CompiledEngine.with_program` - no plan recompile, no re-jit of the fused
exchange - then fans `state[:, b]` back to each caller's future.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
import time
from concurrent.futures import Future

import numpy as np

from ..core import algorithms, engine
from ..core.allocation import Allocation
from ..core.graph_models import Graph
from ..core.shuffle_plan import ShufflePlan

QUERY_KINDS = ("sssp", "ppr")


@dataclasses.dataclass
class ServeStats:
    """Counters over the service's lifetime (read them after `close`)."""
    queries: int = 0
    batches: int = 0
    shuffle_bits: int = 0        # total over all batched runs

    @property
    def mean_batch(self) -> float:
        """Realized amortization: queries served per Shuffle-sharing run."""
        return self.queries / self.batches if self.batches else 0.0

    @property
    def bits_per_query(self) -> float:
        return self.shuffle_bits / self.queries if self.queries else 0.0


class GraphService:
    """Batched query server on one graph + allocation.

    Usage::

        with GraphService(g, alloc, max_batch=8, max_wait_s=0.005) as svc:
            futs = [svc.submit("sssp", root, iters=10) for root in roots]
            dists = [f.result() for f in futs]

    One background worker admits batches; `submit` is thread-safe and
    returns a `concurrent.futures.Future` resolving to that query's [n]
    result column. Query kinds: "sssp" (arg = root vertex id) and "ppr"
    (arg = [n] preference vector).
    """

    def __init__(self, g: Graph, alloc: Allocation, mode: str = "coded", *,
                 backend: str = "numpy", max_batch: int = 8,
                 max_wait_s: float = 0.005, plan: ShufflePlan | None = None,
                 backend_opts: dict | None = None, **opts):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1 (got {max_batch})")
        merged = dict(backend_opts or {})
        merged.update(opts)
        # The session is compiled once against a placeholder program; every
        # admitted batch swaps its own program in via `with_program` (the
        # plan/tables/fused exchange never depend on it).
        self.session = engine.compile(
            algorithms.multi_sssp([0]), g, alloc, mode, path="sparse",
            backend=backend, plan=plan, backend_opts=merged)
        self.max_batch = int(max_batch)
        self.max_wait_s = float(max_wait_s)
        self.stats = ServeStats()
        self._lanes: dict[tuple, collections.deque] = collections.defaultdict(
            collections.deque)
        self._cv = threading.Condition()
        self._closed = False
        self._worker = threading.Thread(
            target=self._loop, name="graph-serve", daemon=True)
        self._worker.start()

    # -- client side -------------------------------------------------------

    def submit(self, kind: str, arg, iters: int = 10) -> Future:
        """Enqueue one query; returns a Future of its [n] result column."""
        n = self.session.g.n
        if kind == "sssp":
            arg = int(arg)
            if not 0 <= arg < n:
                raise ValueError(f"sssp root {arg} out of range [0, {n})")
        elif kind == "ppr":
            arg = np.asarray(arg, dtype=np.float32)
            if arg.shape != (n,):
                raise ValueError(
                    f"ppr preference vector must be [n={n}]; got {arg.shape}")
        else:
            raise ValueError(
                f"unknown query kind {kind!r}; accepted: {QUERY_KINDS}")
        fut: Future = Future()
        with self._cv:
            if self._closed:
                raise RuntimeError("service is closed")
            self._lanes[(kind, int(iters))].append((arg, fut))
            self._cv.notify_all()
        return fut

    def loads(self) -> dict[str, float]:
        """Schedule loads of the underlying session (per payload column)."""
        return self.session.loads()

    def close(self, *, wait: bool = True) -> None:
        """Stop admitting; drain already-queued queries, then stop."""
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        if wait:
            self._worker.join()

    def __enter__(self) -> "GraphService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- worker side -------------------------------------------------------

    def _loop(self) -> None:
        while True:
            with self._cv:
                while not self._closed and not any(self._lanes.values()):
                    self._cv.wait()
                if self._closed and not any(self._lanes.values()):
                    return
                lane = max(self._lanes, key=lambda k: len(self._lanes[k]))
                # Admission window: hold the batch open until it is full,
                # the timeout lapses, or the service is draining.
                deadline = time.monotonic() + self.max_wait_s
                while (not self._closed
                       and len(self._lanes[lane]) < self.max_batch):
                    left = deadline - time.monotonic()
                    if left <= 0:
                        break
                    self._cv.wait(timeout=left)
                q = self._lanes[lane]
                batch = [q.popleft()
                         for _ in range(min(self.max_batch, len(q)))]
                if not q:
                    del self._lanes[lane]
            if batch:
                self._run_batch(lane, batch)

    def _run_batch(self, lane: tuple, batch: list) -> None:
        kind, iters = lane
        args = [a for a, _ in batch]
        futs = [f for _, f in batch]
        try:
            if kind == "sssp":
                prog = algorithms.multi_sssp(args)
            else:
                prog = algorithms.personalized_pagerank(
                    np.stack(args, axis=1))
            res = self.session.with_program(prog).run(iters)
        except Exception as e:                 # fan the failure out too
            for f in futs:
                f.set_exception(e)
            return
        with self._cv:
            self.stats.queries += len(batch)
            self.stats.batches += 1
            self.stats.shuffle_bits += res.shuffle_bits
        for b, f in enumerate(futs):
            f.set_result(res.state[:, b])
