"""Theory curves and bounds for the computation-communication trade-off.

Everything here is closed-form from the paper except `empirical_loads`,
which reads the exact realized loads of a (graph, allocation) pair off one
compiled ShufflePlan; the benchmarks overlay the closed forms on these.
"""
from __future__ import annotations

import math

import numpy as np


def _rack_split_flat(plan, alloc, topology) -> tuple[int, int]:
    """(inter, intra) rack bits of the FLAT schedule laid on `topology`.

    A multicast column crosses the rack fabric iff any of its receivers
    lives outside the sender's rack (the word then traverses at least one
    inter-rack link); a unicast leftover crosses iff its designated sender
    (the lowest-index mapper of the column vertex) is in a different rack
    than the receiver. On `Topology.flat(K)` every transfer is inter-rack,
    matching the degenerate hierarchical accounting.
    """
    from .bitcodec import T_BITS

    plan._require_schedule()
    rack_of = topology.rack_of()
    inter = 0
    P = plan.pair_k.size
    if plan.col_width.size and P:
        sp = plan.slot_pair                              # [C, r], P sentinel
        occupied = sp < P
        recv_rack = rack_of[plan.pair_k[np.where(occupied, sp, 0)]]
        send_rack = rack_of[plan.col_sender][:, None]
        crosses = (occupied & (recv_rack != send_rack)).any(axis=1)
        inter += int(plan.col_width[crosses].sum())
    if plan.left_k.size:
        send = np.argmax(alloc.map_sets[:, plan.left_j], axis=0)
        inter += int((rack_of[send] != rack_of[plan.left_k]).sum()) * T_BITS
    total = plan.coded_bits + plan.leftover_bits
    return inter, total - inter


def empirical_loads(graph, alloc, *, topology=None) -> dict[str, float]:
    """Exact uncoded/coded Definition-2 loads of one realization.

    `graph` is a `Graph`, a raw `CSR` view, or an already-compiled
    `ShufflePlan` / `HierarchicalPlan` - all of which stay O(edges) end to
    end (plans compile via `compile_plan_csr`), so measuring loads works at
    any n the sparse engine runs at. The legacy dense [n, n] adjacency form
    was removed (it could not exist past `dense_limit` and the CSR route is
    schedule-identical); passing one raises `TypeError`.

    With a `Topology`, the result additionally splits the coded Shuffle's
    bits per fabric level: ``inter_rack_bits`` / ``intra_rack_bits`` (plus
    the normalized ``inter_rack_load``). A `HierarchicalPlan` (or a
    Graph/CSR with a non-flat topology, which compiles one) reports the
    two-level scheme's split; a flat `ShufflePlan` with a topology reports
    what the *flat* schedule costs on that fabric - the baseline the
    hierarchical scheme's win is measured against.

    Both headline numbers come from a single plan compile (the schedule
    fixes the bit volume; no data moves).
    """
    from .bitcodec import T_BITS
    from .graph_models import CSR, Graph
    from .shuffle_plan import (HierarchicalPlan, ShufflePlan,
                               compile_hierarchical, compile_plan_csr)

    hplan = None
    if isinstance(graph, HierarchicalPlan):
        hplan = graph
        if topology is not None and topology != hplan.topology:
            raise ValueError(
                f"topology {topology} disagrees with the plan's "
                f"{hplan.topology}")
        topology = hplan.topology
        hplan.check_alloc(alloc)
        plan = hplan.flat
    elif isinstance(graph, ShufflePlan):
        plan = graph
        plan.check_alloc(alloc)
    elif isinstance(graph, (Graph, CSR)):
        csr = graph.csr if isinstance(graph, Graph) else graph
        if topology is not None and not topology.is_flat:
            topology.check_K(alloc.K)
            hplan = compile_hierarchical(csr, alloc, topology, validate=False)
            plan = hplan.flat
        else:
            plan = compile_plan_csr(csr, alloc, validate=False)
    else:
        raise TypeError(
            "empirical_loads needs a Graph, CSR, ShufflePlan, or "
            "HierarchicalPlan; the dense [n, n] adjacency form was removed "
            "- pass the Graph (or its .csr) so the measurement stays "
            "O(edges)")
    out = {
        "uncoded": plan.uncoded_load(),
        "coded": plan.coded_load(),
        "coded_leftover_unicast": plan.leftover_bits
        / (alloc.n * alloc.n * T_BITS),
        "gain": plan.uncoded_load() / plan.coded_load()
        if plan.coded_bits else float("nan"),
    }
    if topology is not None:
        if hplan is not None and not topology.is_flat:
            inter = hplan.inter_rack_bits
            intra = hplan.intra_rack_bits
        else:
            topology.check_K(alloc.K)
            inter, intra = _rack_split_flat(plan, alloc, topology)
        out["inter_rack_bits"] = float(inter)
        out["intra_rack_bits"] = float(intra)
        out["inter_rack_load"] = inter / (alloc.n * alloc.n * T_BITS)
    return out


def uncoded_load_er(p: float, r: float, K: int) -> float:
    """L^UC(r) = p (1 - r/K)   (paper §IV-A)."""
    return p * (1.0 - r / K)


def coded_load_er_asymptotic(p: float, r: int, K: int) -> float:
    """L^C(r) -> (1/r) p (1 - r/K)   (Theorem 1 achievability)."""
    return p * (1.0 - r / K) / r


def coded_load_er_finite(n: int, p: float, r: int, K: int) -> float:
    """Finite-n upper bound via Lemma 1 / eq. (41):
    L <= K C(K-1, r) E[Q] / (r n^2),  E[Q] <= g~ p + 2 sqrt(g~ p p~ log r).
    """
    g_tilde = n * n / (K * math.comb(K, r))
    eq = g_tilde * p
    if r > 1:
        eq += 2.0 * math.sqrt(g_tilde * p * (1 - p) * math.log(r))
    return K * math.comb(K - 1, r) * eq / (r * n * n)


def lower_bound_er(p: float, r: float, K: int) -> float:
    """Converse (Theorem 1 / Lemma 3 with the convexity step):
    L*(r) >= (1/r) p (1 - r/K), valid for any real 1 <= r <= K."""
    return p * (1.0 - r / K) / r


def lower_bound_lemma3(p: float, a_j: np.ndarray, n: int, K: int) -> float:
    """Exact Lemma-3 bound for a given Map-multiplicity histogram a^j
    (a_j[j-1] = #vertices Mapped at exactly j servers)."""
    j = np.arange(1, K + 1)
    return float(p * np.sum(a_j / n * (K - j) / (K * j)))


def bounds_rb(q: float, r: int, K: int) -> tuple[float, float]:
    """Theorem 2: (1/(8r))(1-2r/K) <= lim L*/q <= (1/(2r))(1-2r/K)."""
    lo = (1.0 / (8 * r)) * max(0.0, 1.0 - 2 * r / K)
    hi = (1.0 / (2 * r)) * max(0.0, 1.0 - 2 * r / K)
    return lo, hi


def achievable_sbm(n1: int, n2: int, p: float, q: float, r: int, K: int) -> float:
    """Theorem 3 achievability: (pn1^2 + pn2^2 + 2qn1n2)/(n^2 r) (1 - r/K)."""
    n = n1 + n2
    eff = (p * n1 * n1 + p * n2 * n2 + 2 * q * n1 * n2) / (n * n)
    return eff / r * (1.0 - r / K)


def lower_bound_sbm(q: float, r: int, K: int) -> float:
    """Theorem 3 converse: L*/q >= (1/r)(1 - r/K)."""
    return q / r * (1.0 - r / K)


def achievable_pl(gamma: float, r: int, K: int) -> float:
    """Theorem 4: lim n L*(r) / ((g-1)/(g-2)) <= (1/r)(1 - r/K);
    returns the bound on n*L."""
    assert gamma > 2
    return (gamma - 1) / (gamma - 2) / r * (1.0 - r / K)


def total_time_model(r: float, t_map: float, t_shuffle: float,
                     t_reduce: float) -> float:
    """Remark 10: T(r) ~ r T_map + T_shuffle / r + T_reduce."""
    return r * t_map + t_shuffle / r + t_reduce


def optimal_r(t_map: float, t_shuffle: float) -> float:
    """Remark 10 heuristic: r* = sqrt(T_shuffle / T_map)."""
    return math.sqrt(t_shuffle / t_map)
