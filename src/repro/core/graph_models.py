"""Random graph samplers for the four models studied in the paper.

All samplers return a dense symmetric boolean adjacency matrix (no self loops),
which is the representation the validation-scale dense oracle and the
blocked-dense TPU kernels consume (see DESIGN.md §7.1). Every `Graph` also
carries a cached CSR view (`csr`, `degrees()`, `edge_weights()`): the sparse
O(edges) engine path works exclusively off that view, so per-iteration cost
and memory never touch O(n^2) buffers (the dense `adj`/`weights()` matrices
are only materialized by the dense reference path).
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed-sparse-row view of a symmetric adjacency.

    One entry per *directed* edge (i, j), in `np.nonzero(adj)` order: row
    major, ascending column within each row. That canonical entry order is
    the bitwise contract of the sparse path - every segment reduction
    (single-machine oracle or distributed engine) accumulates each row's
    values in exactly this order.
    """

    indptr: np.ndarray       # [n+1] int64 row offsets
    indices: np.ndarray      # [nnz] int32 column (source vertex j) per entry
    rows: np.ndarray         # [nnz] int32 row (destination vertex i) per entry

    @property
    def n(self) -> int:
        return self.indptr.size - 1

    @property
    def nnz(self) -> int:
        return int(self.indices.size)


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph realization plus the model metadata."""

    adj: np.ndarray          # [n, n] bool, symmetric, zero diagonal
    model: str               # 'er' | 'rb' | 'sbm' | 'pl'
    params: dict

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    @property
    def num_edges(self) -> int:
        return self.csr.nnz // 2

    @functools.cached_property
    def csr(self) -> CSR:
        """Cached CSR view of `adj` (built once per instance)."""
        rows, cols = np.nonzero(self.adj)
        counts = np.bincount(rows, minlength=self.n)
        indptr = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(indptr, cols.astype(np.int32), rows.astype(np.int32))

    def degrees(self) -> np.ndarray:
        """[n] int64 vertex degrees (cached; one CSR diff, not an O(n^2)
        row-sum per call as before)."""
        d = self.__dict__.get("_degrees")
        if d is None:
            d = np.diff(self.csr.indptr)
            self.__dict__["_degrees"] = d
        return d

    def edge_weights(self, low: float = 0.5, high: float = 1.5) -> np.ndarray:
        """[nnz] float64 positive edge weights in CSR entry order (for SSSP).

        One uniform draw per *undirected* edge, in canonical upper-triangle
        CSR order, shared bit-for-bit by both directed entries - so
        ``weights()[i, j] == edge_weights()[e]`` exactly for the CSR entry
        e = (i, j), and the sparse SSSP path is bitwise consistent with the
        dense oracle. O(edges) time and memory; cached per (low, high).
        """
        key = ("_edge_weights", float(low), float(high))
        w = self.__dict__.get(key)
        if w is None:
            csr = self.csr
            i64 = csr.rows.astype(np.int64)
            j64 = csr.indices.astype(np.int64)
            ukey = np.minimum(i64, j64) * self.n + np.maximum(i64, j64)
            upper = i64 < j64         # upper-tri entries: ukey already sorted
            rng = np.random.default_rng(0)
            w_upper = rng.uniform(low, high, size=int(np.count_nonzero(upper)))
            w = w_upper[np.searchsorted(ukey[upper], ukey)]
            self.__dict__[key] = w
        return w

    def weights(self, low: float = 0.5, high: float = 1.5) -> np.ndarray:
        """Dense [n, n] scatter of `edge_weights()`; +inf on non-edges.

        Cached per (low, high): SSSP's dense map used to regenerate this
        O(n^2) matrix every iteration. Only the dense reference path calls
        it - the sparse path consumes `edge_weights()` directly.
        """
        key = ("_weights", float(low), float(high))
        w = self.__dict__.get(key)
        if w is None:
            w = np.full((self.n, self.n), np.inf)
            w[self.csr.rows, self.csr.indices] = self.edge_weights(low, high)
            self.__dict__[key] = w
        return w


def _symmetrize(upper: np.ndarray) -> np.ndarray:
    upper = np.triu(upper, 1)
    return upper | upper.T


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """ER(n, p): every edge present independently w.p. p."""
    rng = np.random.default_rng(seed)
    adj = _symmetrize(rng.random((n, n)) < p)
    return Graph(adj, "er", {"n": n, "p": p, "seed": seed})


def random_bipartite(n1: int, n2: int, q: float, seed: int = 0) -> Graph:
    """RB(n1, n2, q): only cross-cluster edges, each present w.p. q.

    Vertices [0, n1) form cluster 1 and [n1, n1+n2) cluster 2.
    """
    rng = np.random.default_rng(seed)
    n = n1 + n2
    adj = np.zeros((n, n), dtype=bool)
    cross = rng.random((n1, n2)) < q
    adj[:n1, n1:] = cross
    adj[n1:, :n1] = cross.T
    return Graph(adj, "rb", {"n1": n1, "n2": n2, "q": q, "seed": seed})


def stochastic_block(n1: int, n2: int, p: float, q: float, seed: int = 0) -> Graph:
    """SBM(n1, n2, p, q): intra-cluster w.p. p, cross-cluster w.p. q (q < p)."""
    rng = np.random.default_rng(seed)
    n = n1 + n2
    probs = np.full((n, n), q)
    probs[:n1, :n1] = p
    probs[n1:, n1:] = p
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "sbm", {"n1": n1, "n2": n2, "p": p, "q": q, "seed": seed})


def power_law(n: int, gamma: float, rho: float | None = None, seed: int = 0,
              d_min: float = 1.0) -> Graph:
    """PL(n, gamma, rho): expected degrees are iid power-law(gamma) samples and
    P[(i,j) in E] = min(1, rho * d_i * d_j) (Chung-Lu style, paper Appendix E).

    If rho is None it is set to 1 / vol so that expected degrees are honored.
    """
    rng = np.random.default_rng(seed)
    # Inverse-CDF sampling of a Pareto-like pmf P[d] ~ d^-gamma, d >= d_min.
    u = rng.random(n)
    degrees = d_min * (1.0 - u) ** (-1.0 / (gamma - 1.0))
    if rho is None:
        rho = 1.0 / degrees.sum()
    probs = np.minimum(1.0, rho * np.outer(degrees, degrees))
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "pl", {"n": n, "gamma": gamma, "rho": rho, "seed": seed})


def sample(model: str, seed: int = 0, **kw) -> Graph:
    return {
        "er": erdos_renyi,
        "rb": random_bipartite,
        "sbm": stochastic_block,
        "pl": power_law,
    }[model](seed=seed, **kw)
