"""Subgraph (Map) and Reduce-computation allocation (paper §IV-A, Appendix A).

The ER allocation partitions the n vertices into C(K, r) batches, one per
r-subset T of the K servers; server k Maps batch B_T iff k in T.  Reduce
functions are partitioned uniformly: server k Reduces R_k (n/K vertices).

The bi-partite / SBM allocation (Appendix A) splits servers proportionally to
the cluster sizes and applies the ER allocation per cluster, spilling the
surplus Reducers of the larger cluster onto the first server group.
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math

import numpy as np


def batch_subsets(K: int, r: int) -> list[tuple[int, ...]]:
    """All r-subsets of [K] in deterministic lexicographic order."""
    return list(itertools.combinations(range(K), r))


def divisible_n(n: int, K: int, r: int) -> int:
    """Smallest n' >= n divisible by both K and C(K, r)."""
    c = math.comb(K, r)
    lcm = math.lcm(K, c)
    return ((n + lcm - 1) // lcm) * lcm


@dataclasses.dataclass(frozen=True)
class Allocation:
    """A subgraph + computation allocation A = (M, R)."""

    n: int
    K: int
    r: int
    subsets: tuple[tuple[int, ...], ...]   # C(K, r) batch index -> server subset
    batch_of: np.ndarray                   # [n] int, vertex -> batch index
    map_sets: np.ndarray                   # [K, n] bool, M_k as indicator rows
    reduce_owner: np.ndarray               # [n] int, vertex -> Reducing server

    @property
    def g(self) -> int:
        """Batch size n / C(K, r)."""
        return self.n // len(self.subsets)

    def M(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.map_sets[k])

    def R(self, k: int) -> np.ndarray:
        return np.flatnonzero(self.reduce_owner == k)

    def computation_load(self) -> float:
        """Definition 1: sum_k |M_k| / n."""
        return float(self.map_sets.sum()) / self.n

    @functools.cached_property
    def _subset_index(self) -> dict[tuple[int, ...], int]:
        """subset -> batch index, built once (replaces O(C(K, r)) tuple
        scans in `batch_vertices`)."""
        return {s: b for b, s in enumerate(self.subsets)}

    def batch_vertices(self, subset: tuple[int, ...]) -> np.ndarray:
        b = self._subset_index.get(tuple(sorted(subset)))
        if b is None:
            raise ValueError(f"{subset} is not a batch subset")
        return np.flatnonzero(self.batch_of == b)


def er_allocation(n: int, K: int, r: int, interleave: bool = False,
                  pad: bool = False) -> Allocation:
    """The paper's §IV-A allocation for the ER model.

    Requires n divisible by C(K, r) and by K (paper Remark 1); use
    divisible_n() to round up first, or pass pad=True to round up here -
    the returned allocation then has `alloc.n = divisible_n(n, K, r)` and
    the graph must be padded to match with virtual isolated vertices
    (`Graph.padded(alloc.n)`), so arbitrary real-graph n is accepted.

    interleave=True assigns vertices to batches round-robin instead of in
    contiguous blocks - a beyond-paper refinement that homogenizes per-group
    row sizes when the graph is *not* edge-homogeneous (SBM, power-law), so
    the per-column max over table rows wastes less (see EXPERIMENTS.md).
    For ER graphs the two are statistically identical.
    """
    if not 1 <= r <= K:
        raise ValueError(f"need 1 <= r <= K, got r={r}, K={K}")
    subsets = batch_subsets(K, r)
    c = len(subsets)
    if n % c or n % K:
        if pad:
            n = divisible_n(n, K, r)
        else:
            raise ValueError(
                f"n={n} must be divisible by C({K},{r})={c} and K={K}; "
                f"use divisible_n -> {divisible_n(n, K, r)} (or pad=True)")
    g = n // c
    if interleave:
        batch_of = np.arange(n) % c
    else:
        batch_of = np.repeat(np.arange(c), g)
    map_sets = np.zeros((K, n), dtype=bool)
    for b, subset in enumerate(subsets):
        members = batch_of == b
        for k in subset:
            map_sets[k, members] = True
    reduce_owner = np.arange(n) % K if interleave else np.repeat(np.arange(K), n // K)
    return Allocation(n, K, r, tuple(subsets), batch_of, map_sets, reduce_owner)


def bipartite_allocation(n1: int, n2: int, K: int, r: int) -> Allocation:
    """Appendix A allocation for RB(n1, n2, q) (also used for SBM).

    Servers are split into K1 = n1/n*K and K2 = n2/n*K groups. Mappers of
    cluster 1 and Reducers of cluster 2 go to group 1 (phase I); Mappers of
    cluster 2 and n2 Reducers of cluster 1 to group 2 (phase II); the surplus
    n1-n2 cluster-1 Reducers spill back to group 1 (phase III).
    """
    if n1 < n2:
        raise ValueError("convention: n1 >= n2 (swap clusters)")
    n = n1 + n2
    K1 = round(K * n1 / n)
    K1 = min(max(K1, 1), K - 1)
    K2 = K - K1
    a1 = er_allocation(divisible_n(n1, K1, min(r, K1)), K1, min(r, K1))
    a2 = er_allocation(divisible_n(n2, K2, min(r, K2)), K2, min(r, K2))
    if a1.n != n1 or a2.n != n2:
        raise ValueError(
            f"cluster sizes must divide evenly: need n1={a1.n}, n2={a2.n}")
    map_sets = np.zeros((K, n), dtype=bool)
    map_sets[:K1, :n1] = a1.map_sets                 # phase I mappers
    map_sets[K1:, n1:] = a2.map_sets                 # phase II mappers
    reduce_owner = np.empty(n, dtype=int)
    # Phase I: cluster-2 Reducers spread over group 1.
    reduce_owner[n1:] = np.arange(n2) % K1
    # Phase II: first n2 cluster-1 Reducers on group 2; phase III: rest on group 1.
    reduce_owner[:n2] = K1 + (np.arange(n2) % K2)
    reduce_owner[n2:n1] = np.arange(n1 - n2) % K1
    # Batches only meaningful per cluster; store cluster-1 batches shifted.
    subsets = tuple(a1.subsets) + tuple(
        tuple(K1 + s for s in ss) for ss in a2.subsets)
    batch_of = np.concatenate([a1.batch_of, len(a1.subsets) + a2.batch_of])
    return Allocation(n, K, r, subsets, batch_of, map_sets, reduce_owner)


def random_allocation(n: int, K: int, r: int, seed: int = 0) -> Allocation:
    """A sanity-check baseline: random r-replicated Map placement (still a
    valid allocation, but with no coded-multicast structure by design)."""
    rng = np.random.default_rng(seed)
    subsets = batch_subsets(K, r)
    batch_of = rng.integers(0, len(subsets), size=n)
    map_sets = np.zeros((K, n), dtype=bool)
    # One scatter instead of the n x r Python loop: vertex v is Mapped at
    # every member of its batch's subset (all subsets have size r here).
    members = np.asarray(subsets, dtype=np.int64)[batch_of]      # [n, r]
    map_sets[members.ravel(), np.repeat(np.arange(n), r)] = True
    reduce_owner = rng.integers(0, K, size=n)
    return Allocation(n, K, r, tuple(subsets), batch_of, map_sets, reduce_owner)
