"""Incremental O(delta) plan maintenance: `apply_delta` == fresh compile.

The locked contract (PR 9): for any `EdgeDelta`, `ShufflePlan.apply_delta`
returns a plan *array-identical* to `compile_plan_csr` on the mutated graph
- every field bitwise equal (dtype, shape, values), edge tables included -
across all three graph models, insert/delete/mixed batches, scheduled and
missing-set-only plans, and the unicast-leftover spill. The only documented
exception: on a *degraded* allocation `col_sender` is re-patched to healthy
stand-ins (a fresh compile would still point at dead servers), exactly the
`repair` rule. Delivered words are bitwise equal either way.

Also locks the session layers: `CompiledEngine.update` (bitwise run states,
stale-cache regressions, composition with `fail` in both orders, fused
exchange rebind) and `GraphService.update` (mutations admitted between
batches, poison deltas isolated).
"""
import dataclasses

import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.graph_models import (Graph, csr_from_undirected,
                                     random_bipartite)
from repro.core.shuffle_plan import compile_plan_csr
from repro.graphs import EdgeDelta, erdos_renyi, power_law, stochastic_block

PLAN_FIELDS = ["pair_k", "pair_i", "pair_j", "col_width", "col_sender",
               "col_gm", "col_rank", "slot_pair", "slot_shift", "slot_mask",
               "pair_col", "pair_slot", "seg_shift", "left_k", "left_i",
               "left_j", "all_k", "all_i", "all_j", "pos_covered",
               "pos_left", "ptr"]

K, R = 5, 2
N = divisible_n(50, K, R)


def assert_plans_equal(a, b, skip=(), ctx=""):
    for f in PLAN_FIELDS:
        if f in skip:
            continue
        x, y = getattr(a, f), getattr(b, f)
        if x is None or y is None:
            assert x is None and y is None, (ctx, f)
            continue
        assert x.dtype == y.dtype, (ctx, f, x.dtype, y.dtype)
        assert x.shape == y.shape, (ctx, f, x.shape, y.shape)
        assert np.array_equal(x, y), (ctx, f)


def mk_delta(g, rng, nins, ndel):
    """Deterministic mixed batch: existing edges to delete, fresh to insert."""
    csr = g.csr
    have = set(zip(csr.rows.tolist(), csr.indices.tolist()))
    dels = []
    if ndel and csr.nnz:
        idx = rng.choice(csr.nnz, size=min(4 * ndel, csr.nnz), replace=False)
        seen = set()
        for e in idx:
            u, v = int(csr.rows[e]), int(csr.indices[e])
            key = (min(u, v), max(u, v))
            if key not in seen:
                seen.add(key)
                dels.append(key)
            if len(dels) == ndel:
                break
    inss = []
    seen = set()
    real_n = g.params.get("padded_from", g.n)
    while len(inss) < nins:
        u, v = int(rng.integers(real_n)), int(rng.integers(real_n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen or (u, v) in have or (v, u) in have:
            continue
        seen.add(key)
        inss.append(key)
    return EdgeDelta.for_graph(g, insert=inss, delete=dels)


def check_delta_vs_fresh(g, alloc, delta, schedule=True, ctx=""):
    csr = g.csr
    plan = compile_plan_csr(csr, alloc, schedule=schedule)
    plan.edge_tables(csr, alloc)
    csr2 = csr.apply_delta(delta)
    plan2, stats = plan.apply_delta(csr, alloc, delta, csr_new=csr2)
    fresh = compile_plan_csr(csr2, alloc, schedule=schedule)
    assert_plans_equal(plan2, fresh, ctx=ctx)
    # Edge tables were carried incrementally AND re-keyed to the new CSR.
    t2 = plan2.__dict__["_edge_tables"]
    assert t2[0] is csr2 and t2[1] is alloc
    ft = fresh.edge_tables(csr2, alloc)
    for f in ["pair_e", "left_e", "all_e", "gather"]:
        assert np.array_equal(getattr(t2[2], f), getattr(ft, f)), (ctx, f)
    return plan2, stats


def _models():
    return [("er", erdos_renyi(N, 0.15, seed=1)),
            ("pl", power_law(N, 2.5, seed=2)),
            ("sbm", stochastic_block(N // 2, N - N // 2, 0.3, 0.02, seed=3))]


# ---------------------------------------------------------------------------
# The contract: apply_delta == fresh compile, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("model", ["er", "pl", "sbm"])
@pytest.mark.parametrize("kind,nins,ndel",
                         [("ins", 8, 0), ("del", 0, 8), ("mix", 6, 6)])
@pytest.mark.parametrize("sched", [True, False])
def test_apply_delta_matches_fresh_compile(model, kind, nins, ndel, sched):
    rng = np.random.default_rng(hash((model, kind, sched)) % 2**32)
    g = dict(_models())[model]
    alloc = er_allocation(N, K, R)
    delta = mk_delta(g, rng, nins, ndel)
    check_delta_vs_fresh(g, alloc, delta, schedule=sched,
                         ctx=f"{model}/{kind}/sched={sched}")


@pytest.mark.parametrize("model", ["er", "pl", "sbm"])
def test_noop_delta_is_identity(model):
    g = dict(_models())[model]
    alloc = er_allocation(N, K, R)
    d0 = EdgeDelta.for_graph(g)
    plan = compile_plan_csr(g.csr, alloc)
    plan2, st = plan.apply_delta(g.csr, alloc, d0,
                                 csr_new=g.csr.apply_delta(d0))
    assert not st.schedule_changed
    assert_plans_equal(plan2, plan, ctx=f"{model}/noop")


def test_apply_delta_segment_fast_path():
    """K=4 keeps the pair stream in a handful of huge (group, receiver)
    runs, which flips `_schedule_from_pairs` onto its segment/slice fast
    path (no index arrays); the bitwise contract must hold there too."""
    rng = np.random.default_rng(21)
    n = divisible_n(1000, 4, 2)
    g = erdos_renyi(n, 10 / n, seed=6)
    alloc = er_allocation(n, 4, 2)
    delta = mk_delta(g, rng, 20, 20)
    p2, _ = check_delta_vs_fresh(g, alloc, delta, ctx="segment-path")
    assert p2.pair_k.size > 16 * 12 * 6     # big enough to take the path


def test_apply_delta_spill_bipartite():
    """Unicast-leftover spill (0 covered pairs on one side, Appendix A)."""
    rng = np.random.default_rng(7)
    gb = random_bipartite(32, 18, 0.3, seed=3)
    ab = bipartite_allocation(32, 18, 6, 4)
    db = mk_delta(gb, rng, 4, 4)
    check_delta_vs_fresh(gb, ab, db, ctx="spill")


def test_apply_delta_sequence_matches_fresh():
    """Successive deltas chain through the plan-level key caches; the end
    of an update *sequence* must still equal one fresh compile."""
    rng = np.random.default_rng(11)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    csr = g.csr
    plan = compile_plan_csr(csr, alloc)
    plan.edge_tables(csr, alloc)
    for step in range(4):
        gv = Graph(model=g.model, params=dict(g.params), csr=csr)
        delta = mk_delta(gv, rng, 5, 5)
        csr2 = csr.apply_delta(delta)
        plan, _ = plan.apply_delta(csr, alloc, delta, csr_new=csr2)
        csr = csr2
        assert_plans_equal(plan, compile_plan_csr(csr, alloc),
                           ctx=f"seq/{step}")


def test_delivered_words_bitwise_equal():
    rng = np.random.default_rng(5)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    d0 = mk_delta(g, rng, 5, 5)
    c2 = g.csr.apply_delta(d0)
    p0 = compile_plan_csr(g.csr, alloc)
    pa, _ = p0.apply_delta(g.csr, alloc, d0)
    pf = compile_plan_csr(c2, alloc)
    vals = ((np.arange(N * N, dtype=np.int64) * 2654435761) % 2**32) \
        .astype(np.uint32).reshape(N, N)
    ra, rf = pa.execute_coded(vals), pf.execute_coded(vals)
    for f in dataclasses.fields(ra):
        x, y = getattr(ra, f.name), getattr(rf, f.name)
        if isinstance(x, np.ndarray):
            assert np.array_equal(x, y), f.name
        else:
            assert x == y, f.name


# ---------------------------------------------------------------------------
# Composition with repair, both orders
# ---------------------------------------------------------------------------


def test_delta_composes_with_repair_both_ways():
    rng = np.random.default_rng(3)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    delta = mk_delta(g, rng, 6, 6)
    csr, csr2 = g.csr, g.csr.apply_delta(delta)
    plan = compile_plan_csr(csr, alloc)
    plan.edge_tables(csr, alloc)
    failed = (1,)
    rep, degraded, _ = plan.repair(csr, alloc, failed)

    # delta after repair: == fresh on the degraded allocation, except the
    # re-patched col_sender, which must point only at survivors
    p_dr, st_dr = rep.apply_delta(csr, degraded, delta, csr_new=csr2)
    fresh_deg = compile_plan_csr(csr2, degraded)
    assert_plans_equal(p_dr, fresh_deg, skip=("col_sender",),
                       ctx="delta-after-repair")
    surv = np.flatnonzero(degraded.map_sets.any(axis=1))
    assert np.isin(p_dr.col_sender, surv).all()

    # repair after delta: identical plan, identical hand-over pricing
    plan2 = compile_plan_csr(csr2, alloc)
    p_rd, _, st_rd = plan2.repair(csr2, alloc, failed)
    assert_plans_equal(p_rd, p_dr, ctx="orders-agree")
    assert st_rd.handover_bits == st_dr.handover_bits


# ---------------------------------------------------------------------------
# CSR.apply_delta and EdgeDelta validation (construction-time errors)
# ---------------------------------------------------------------------------


def test_csr_apply_delta_matches_rebuild():
    rng = np.random.default_rng(9)
    g = _models()[1][1]
    delta = mk_delta(g, rng, 7, 7)
    csr2 = g.csr.apply_delta(delta)
    keep = set(zip(g.csr.rows.tolist(), g.csr.indices.tolist()))
    keep -= {(int(u), int(v)) for u, v in delta.delete}
    keep -= {(int(v), int(u)) for u, v in delta.delete}
    keep |= {(int(u), int(v)) for u, v in delta.insert}
    u = np.array(sorted({(min(a, b), max(a, b)) for a, b in keep}))
    want = csr_from_undirected(u[:, 0], u[:, 1], g.n)
    for f in ("indptr", "indices", "rows"):
        got, exp = getattr(csr2, f), getattr(want, f)
        assert got.dtype == exp.dtype and np.array_equal(got, exp), f


def test_csr_apply_delta_rejects_absent_and_present_edges():
    g = _models()[0][1]
    u, v = int(g.csr.rows[0]), int(g.csr.indices[0])
    with pytest.raises(ValueError, match="already in the graph"):
        g.csr.apply_delta(EdgeDelta.for_graph(g, insert=[(u, v)]))
    absent = None
    have = set(zip(g.csr.rows.tolist(), g.csr.indices.tolist()))
    for a in range(g.n):
        for b in range(a + 1, g.n):
            if (a, b) not in have:
                absent = (a, b)
                break
        if absent:
            break
    with pytest.raises(ValueError, match="not in the graph"):
        g.csr.apply_delta(EdgeDelta.for_graph(g, delete=[absent]))


def test_edge_delta_validation_errors():
    g = _models()[0][1]
    n = g.n
    with pytest.raises(ValueError, match="out of range"):
        EdgeDelta.for_graph(g, insert=[(0, n)])
    with pytest.raises(ValueError, match="out of range"):
        EdgeDelta.for_graph(g, delete=[(-1, 3)])
    with pytest.raises(ValueError, match="self-loop"):
        EdgeDelta.for_graph(g, insert=[(4, 4)])
    with pytest.raises(ValueError, match="more than once"):
        EdgeDelta.for_graph(g, insert=[(1, 2), (2, 1)])
    with pytest.raises(ValueError, match="both insert and delete"):
        EdgeDelta(insert=[(1, 2)], delete=[(2, 1)], n=n)
    with pytest.raises(ValueError, match="pairs"):
        EdgeDelta(insert=[(1, 2, 3)], delete=[], n=n)
    with pytest.raises(ValueError, match="integer"):
        EdgeDelta(insert=[(1.5, 2.5)], delete=[], n=n)


def test_edge_delta_rejects_virtual_padded_range():
    """Padding works because virtual vertices stay isolated; a delta must
    not be able to break that invariant (satellite: clear error, not a
    mis-bound plan)."""
    g = _models()[0][1]
    alloc6 = er_allocation(g.n, 6, 2, pad=True)
    gp = g.padded(alloc6.n)
    assert gp.params["padded_from"] == g.n
    with pytest.raises(ValueError, match="virtual padded range"):
        EdgeDelta.for_graph(gp, insert=[(0, gp.n - 1)])
    # real-range mutations on the padded graph still work end to end
    rng = np.random.default_rng(1)
    delta = mk_delta(gp, rng, 3, 3)
    check_delta_vs_fresh(gp, alloc6, delta, ctx="padded")


# ---------------------------------------------------------------------------
# CompiledEngine.update: session-level bitwise + stale-cache regressions
# ---------------------------------------------------------------------------


def _fresh_graph(g, delta):
    return Graph(model=g.model, params=dict(g.params),
                 csr=g.csr.apply_delta(delta))


@pytest.mark.parametrize("prog_name", ["pagerank", "sssp"])
@pytest.mark.parametrize("mode", ["coded", "uncoded"])
def test_engine_update_matches_fresh_session(prog_name, mode):
    rng = np.random.default_rng(13)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    delta = mk_delta(g, rng, 6, 6)
    prog = algo.pagerank() if prog_name == "pagerank" else algo.sssp(0)
    eng = engine.compile(prog, g, alloc, mode, path="sparse")
    eng2 = eng.update(delta)
    fresh = engine.compile(prog, _fresh_graph(g, delta), alloc, mode,
                           path="sparse")
    r_upd, r_fresh = eng2.run(8), fresh.run(8)
    assert np.array_equal(r_upd.state, r_fresh.state)
    assert r_upd.shuffle_bits == r_fresh.shuffle_bits
    assert eng2.delta_stats is not None


def test_engine_update_requires_plan_mode():
    g = _models()[0][1]
    eng = engine.compile(algo.pagerank(), g, None, "single", path="sparse")
    with pytest.raises(ValueError, match="plan-mode"):
        eng.update(EdgeDelta.for_graph(g))


def test_engine_update_leaves_old_session_usable():
    """Stale-cache regression: the pre-update session keeps its own plan,
    tables, and graph binding - updating must not mutate it."""
    rng = np.random.default_rng(17)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    prog = algo.pagerank()
    eng = engine.compile(prog, g, alloc, "coded", path="sparse")
    before = eng.run(6).state
    old_plan, old_tables, old_gather = \
        eng.plan, eng.tables, eng.tables.gather.copy()
    eng2 = eng.update(mk_delta(g, rng, 6, 6))
    # new session got NEW artifacts...
    assert eng2.plan is not old_plan
    assert eng2.tables is not old_tables
    assert eng2.g is not eng.g
    # ...and the old session's are untouched and still run identically
    assert eng.plan is old_plan and eng.tables is old_tables
    assert np.array_equal(eng.tables.gather, old_gather)
    assert np.array_equal(eng.run(6).state, before)


def test_engine_update_rebinds_tables_without_relocate():
    """The updated session's edge tables must be keyed to the *new* CSR
    (identity, not equality - the stale-cache failure mode is a table
    silently bound to the old CSR)."""
    rng = np.random.default_rng(19)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    eng = engine.compile(algo.pagerank(), g, alloc, "coded", path="sparse")
    eng2 = eng.update(mk_delta(g, rng, 5, 5))
    cached = eng2.plan.__dict__["_edge_tables"]
    assert cached[0] is eng2.g.csr and cached[1] is alloc
    assert cached[2] is eng2.tables


def test_service_update_applies_between_batches():
    """`GraphService.update`: mutation futures resolve with DeltaStats at
    the next batch boundary, post-mutation queries answer on the mutated
    graph (bitwise vs a fresh session), and a poison delta fails only its
    own future."""
    from repro.serve import GraphService

    rng = np.random.default_rng(29)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    delta = mk_delta(g, rng, 5, 5)
    g2 = _fresh_graph(g, delta)
    want_before = engine.compile(algo.sssp(0), g, alloc, "coded",
                                 path="sparse").run(6).state
    want_after = engine.compile(algo.sssp(0), g2, alloc, "coded",
                                path="sparse").run(6).state
    with GraphService(g, alloc, max_batch=2, max_wait_s=0.02) as svc:
        assert np.array_equal(
            svc.submit("sssp", 0, iters=6).result(timeout=60), want_before)
        stats = svc.update(delta).result(timeout=60)
        assert stats.schedule_changed
        # a poison delta (re-deleting an already-deleted edge) fails alone
        with pytest.raises(ValueError, match="not in the graph"):
            svc.update(EdgeDelta.for_graph(
                g2, delete=[delta.delete[0]])).result(timeout=60)
        assert np.array_equal(
            svc.submit("sssp", 0, iters=6).result(timeout=60), want_after)
        assert svc.stats.mutations == 1


def test_engine_update_then_fail_equals_fail_then_update():
    rng = np.random.default_rng(23)
    g = _models()[0][1]
    alloc = er_allocation(N, K, R)
    delta = mk_delta(g, rng, 6, 6)
    prog = algo.pagerank()
    eng = engine.compile(prog, g, alloc, "coded", path="sparse")
    e_uf = eng.update(delta).fail((1,))
    e_fu = eng.fail((1,)).update(delta)
    assert_plans_equal(e_uf.plan, e_fu.plan, ctx="update/fail-orders")
    assert e_uf.recovery.handover_bits == e_fu.recovery.handover_bits
    s_uf, s_fu = e_uf.run(5), e_fu.run(5)
    assert np.array_equal(s_uf.state, s_fu.state)
    assert s_uf.shuffle_bits == s_fu.shuffle_bits
