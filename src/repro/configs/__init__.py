"""Architecture registry: --arch <id> -> ModelConfig."""
from . import (deepseek_v2_236b, gemma2_27b, gemma3_27b, gemma_7b,
               hubert_xlarge, internlm2_20b, internvl2_1b,
               llama4_maverick_400b_a17b, mamba2_370m, zamba2_1_2b)
from .base import (SHAPES, ModelConfig, ShapeSpec, cell_supported,  # noqa: F401
                   input_specs)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG for m in (
        llama4_maverick_400b_a17b, deepseek_v2_236b, internlm2_20b,
        gemma2_27b, gemma3_27b, gemma_7b, zamba2_1_2b, mamba2_370m,
        hubert_xlarge, internvl2_1b)
}


def get(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
