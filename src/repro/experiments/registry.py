"""SNAP dataset registry: name -> source + checksum, download-once cache.

The paper validates its trade-off by running coded PageRank over real
datasets on EC2 (Table II). This registry is the data side of that
reproduction:

  * **snap** entries name a SNAP edge-list URL. The file is downloaded at
    most once into the cache directory (``$REPRO_DATA_DIR``, default
    ``~/.cache/repro-graphs``), gunzipped, and sha256-recorded - a pinned
    ``sha256`` verifies the payload, an unpinned one is computed and stored
    as a ``<name>.sha256`` sidecar on first download so later fetches can
    detect corruption. Network access is strictly opt-in: ``download=True``
    or ``$REPRO_DOWNLOAD=1``; otherwise a missing file raises
    `DatasetUnavailable` with manual-download instructions, so CI and tests
    stay fully offline.
  * **fixture** entries resolve to the committed `repro.graphs` fixtures
    (karate club) - always available, no cache, no network.
  * **synthetic** entries are deterministic streaming-sampler stand-ins
    (e.g. an ER graph at soc-Epinions1 scale) that are sampled once,
    written to the cache as a real edge-list file, and re-ingested through
    the same loader path as a downloaded dataset - so the full
    parse -> normalize -> allocate pipeline is exercised offline at
    n >= 76k.

Every entry loads through `graphs.io.load_graph` into a CSR-native `Graph`;
nothing here ever materializes a dense [n, n] view.
"""
from __future__ import annotations

import dataclasses
import gzip
import hashlib
import os
import pathlib
import shutil
import tempfile

from .. import graphs
from ..core.graph_models import Graph

__all__ = ["Dataset", "DatasetUnavailable", "DATASETS", "PaperCell",
           "register", "data_dir", "fetch", "load"]

_ENV_DIR = "REPRO_DATA_DIR"
_ENV_DOWNLOAD = "REPRO_DOWNLOAD"


class DatasetUnavailable(RuntimeError):
    """A network dataset is not cached and downloading was not opted into."""


@dataclasses.dataclass(frozen=True)
class PaperCell:
    """One literal Table II cell of the paper (arXiv 1801.05522).

    The paper's EC2 experiments report, per real-world dataset and
    computation load r, the running-time gains of coded PageRank over the
    conventional (uncoded) implementation: the Shuffle-phase speedup and
    the overall-execution speedup. Transcribed here so `table2.run_table2`
    can print the paper's own numbers beside this repo's measured load
    columns. Provenance: hand-transcribed from the published Table II;
    this environment is offline, so re-verify the decimals against the PDF
    before citing them - the repo's quantitative gates are the *measured*
    columns and the closed-form overlays, never these cells.
    """

    r: int
    shuffle_speedup: float   # uncoded / coded average per-iter Shuffle time
    overall_speedup: float   # uncoded / coded overall execution time


@dataclasses.dataclass(frozen=True)
class Dataset:
    """One registry entry; see the module docstring for the three kinds."""

    name: str
    kind: str = "snap"              # "snap" | "fixture" | "synthetic"
    url: str | None = None
    sha256: str | None = None       # of the *decompressed* edge-list file
    largest_cc: bool = True
    # Published stats (SNAP page, directed counts) - reporting only, the
    # loader's normalized counts are the ground truth.
    vertices: int | None = None
    edges: int | None = None
    spec: tuple[tuple[str, object], ...] = ()   # synthetic sampler spec
    note: str = ""
    paper_table2: tuple[PaperCell, ...] = ()    # literal paper cells, if any

    def paper_cell(self, r: int) -> PaperCell | None:
        """The paper's Table II cell at computation load r, if reported."""
        for cell in self.paper_table2:
            if cell.r == r:
                return cell
        return None


DATASETS: dict[str, Dataset] = {}


def register(ds: Dataset) -> Dataset:
    DATASETS[ds.name] = ds
    return ds


register(Dataset(
    name="soc-Epinions1",
    url="https://snap.stanford.edu/data/soc-Epinions1.txt.gz",
    vertices=75_879, edges=508_837,
    note="Epinions who-trusts-whom network; the ~76k-vertex real dataset "
         "named by the paper's Table II methodology and ROADMAP.md.",
    paper_table2=(PaperCell(r=2, shuffle_speedup=1.81, overall_speedup=1.42),
                  PaperCell(r=3, shuffle_speedup=2.48,
                            overall_speedup=1.65))))
register(Dataset(
    name="soc-Slashdot0811",
    url="https://snap.stanford.edu/data/soc-Slashdot0811.txt.gz",
    vertices=77_360, edges=905_468,
    note="Slashdot Zoo signed social network, Nov 2008 crawl.",
    paper_table2=(PaperCell(r=2, shuffle_speedup=1.76, overall_speedup=1.39),
                  PaperCell(r=3, shuffle_speedup=2.39,
                            overall_speedup=1.61))))
register(Dataset(
    name="wiki-Vote",
    url="https://snap.stanford.edu/data/wiki-Vote.txt.gz",
    vertices=7_115, edges=103_689,
    note="Wikipedia adminship votes; small enough for quick full runs."))
register(Dataset(
    name="karate",
    kind="fixture",
    vertices=34, edges=78,
    note="Committed Zachary karate-club fixture (graphs/data/karate.edges); "
         "the offline CI smoke path."))
register(Dataset(
    name="er-76k",
    kind="synthetic",
    spec=(("model", "er"), ("n", 80_000), ("avg_degree", 8.0), ("seed", 76)),
    note="Deterministic ER stand-in at soc-Epinions1 scale (>= 76k vertices "
         "after largest-CC extraction) for offline/CI runs of the Table II "
         "harness; its measured loads must match the ER closed forms."))


def data_dir(override: str | os.PathLike | None = None) -> pathlib.Path:
    """Cache directory: explicit override > $REPRO_DATA_DIR > ~/.cache."""
    if override is not None:
        return pathlib.Path(override)
    env = os.environ.get(_ENV_DIR)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro-graphs"


def _sha256(path: pathlib.Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


def _verify(ds: Dataset, dest: pathlib.Path) -> None:
    """Check a cached file against the registry pin or its sidecar digest.

    The sidecar is written when this process downloads or synthesizes the
    file, so a truncated manual fetch or a corrupted cache fails loudly on
    the next use instead of producing silently wrong loads. A cached file
    with neither pin nor sidecar (e.g. hand-placed) is trusted.
    """
    expected = ds.sha256
    sidecar = dest.with_suffix(dest.suffix + ".sha256")
    if expected is None and sidecar.exists():
        expected = sidecar.read_text().strip()
    if expected is not None and _sha256(dest) != expected:
        raise RuntimeError(
            f"{ds.name}: cached file {dest} sha256 mismatch (expected "
            f"{expected}); delete it (and {sidecar.name}) to re-fetch")


def _download(ds: Dataset, dest: pathlib.Path) -> None:
    """URL -> decompressed edge list at `dest`, checksum-verified/recorded."""
    import urllib.request

    dest.parent.mkdir(parents=True, exist_ok=True)
    with tempfile.NamedTemporaryFile(dir=dest.parent, delete=False) as tmp:
        tmp_path = pathlib.Path(tmp.name)
        try:
            with urllib.request.urlopen(ds.url, timeout=60) as resp:
                if ds.url.endswith(".gz"):
                    with gzip.GzipFile(fileobj=resp) as gz:
                        shutil.copyfileobj(gz, tmp)
                else:
                    shutil.copyfileobj(resp, tmp)
        except BaseException:
            tmp_path.unlink(missing_ok=True)
            raise
    digest = _sha256(tmp_path)
    if ds.sha256 is not None and digest != ds.sha256:
        tmp_path.unlink()
        raise RuntimeError(
            f"{ds.name}: downloaded file sha256 {digest} does not match the "
            f"registry pin {ds.sha256}")
    tmp_path.replace(dest)
    dest.with_suffix(dest.suffix + ".sha256").write_text(digest + "\n")


def _synthesize(ds: Dataset, dest: pathlib.Path) -> None:
    """Sample the synthetic spec and cache it as a real edge-list file."""
    spec = dict(ds.spec)
    model, n, seed = spec["model"], int(spec["n"]), int(spec.get("seed", 0))
    if model == "er":
        p = float(spec["avg_degree"]) / (n - 1)
        g = graphs.erdos_renyi(n, p, seed=seed)
    elif model == "pl":
        g = graphs.power_law(n, float(spec["gamma"]), seed=seed)
    else:
        raise ValueError(f"{ds.name}: unknown synthetic model {model!r}")
    dest.parent.mkdir(parents=True, exist_ok=True)
    tmp = dest.with_suffix(dest.suffix + ".tmp")
    graphs.write_edge_list(
        g, tmp, header=f"synthetic stand-in {ds.name}: {dict(ds.spec)}")
    tmp.replace(dest)
    dest.with_suffix(dest.suffix + ".sha256").write_text(_sha256(dest) + "\n")


def fetch(name: str, cache_dir: str | os.PathLike | None = None,
          download: bool | None = None) -> pathlib.Path:
    """Path of the dataset's edge-list file, materializing it if needed.

    `download=None` defers to ``$REPRO_DOWNLOAD`` (unset -> offline).
    Fixture entries return the committed path directly; synthetic entries
    sample once into the cache; snap entries must either be cached already
    or have downloading opted in.
    """
    ds = DATASETS.get(name)
    if ds is None:
        known = ", ".join(sorted(DATASETS))
        raise KeyError(f"unknown dataset {name!r}; registered: {known}")
    if ds.kind == "fixture":
        return graphs.fixture_path(ds.name)
    dest = data_dir(cache_dir) / f"{ds.name}.edges"
    if dest.exists():
        _verify(ds, dest)
        return dest
    if ds.kind == "synthetic":
        _synthesize(ds, dest)
        return dest
    if download is None:
        download = os.environ.get(_ENV_DOWNLOAD, "") not in ("", "0")
    if not download:
        raise DatasetUnavailable(
            f"{ds.name} is not cached at {dest} and downloading is off. "
            f"Re-run with download=True / REPRO_DOWNLOAD=1, or fetch "
            f"manually:  curl -L {ds.url} | gunzip > {dest}")
    _download(ds, dest)
    return dest


def load(name: str, cache_dir: str | os.PathLike | None = None,
         download: bool | None = None) -> Graph:
    """Fetch + ingest a registered dataset into a CSR-native `Graph`."""
    path = fetch(name, cache_dir, download)     # raises on unknown names
    ds = DATASETS[name]
    g = graphs.load_graph(path, largest_cc=ds.largest_cc, name=name)
    g.params["dataset"] = dataclasses.asdict(ds)
    return g
