"""Experiments subsystem (PR 5): dataset registry + Table II harness.

Covers the registry contract (offline-first fetch, cache-once synthesis,
opt-in-only network), the harness's measured-vs-closed-form rows, and the
headline acceptance: the full registry -> parse -> normalize -> allocate ->
compile -> count-bits pipeline at >= 76k vertices, dense-free (the default
`dense_limit` guard makes any [n, n] touch a hard error at that n) with
O(edges) peak memory and ER gains matching Theorem 1.
"""
import json
import tracemalloc

import numpy as np
import pytest

from repro import graphs
from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import er_allocation
from repro.experiments import (DATASETS, Dataset, DatasetUnavailable,
                               registry, run_table2, to_markdown)

# ---- registry ----


def test_fixture_resolves_offline(tmp_path):
    path = registry.fetch("karate", cache_dir=tmp_path)
    assert path == graphs.fixture_path("karate")
    g = registry.load("karate", cache_dir=tmp_path)
    assert g.n == 34 and g.num_edges == 78 and g.is_csr_native
    assert g.params["dataset"]["kind"] == "fixture"
    assert not list(tmp_path.iterdir())          # fixtures bypass the cache


def test_unknown_dataset_lists_names(tmp_path):
    with pytest.raises(KeyError, match="soc-Epinions1"):
        registry.fetch("no-such-dataset", cache_dir=tmp_path)


def test_snap_fetch_is_opt_in(tmp_path, monkeypatch):
    monkeypatch.delenv("REPRO_DOWNLOAD", raising=False)
    with pytest.raises(DatasetUnavailable, match="soc-Epinions1.txt.gz"):
        registry.fetch("soc-Epinions1", cache_dir=tmp_path)
    # A cached file short-circuits: no network, no opt-in needed.
    cached = tmp_path / "soc-Epinions1.edges"
    cached.write_text("# tiny stand-in\n0 1\n1 2\n2 0\n3 4\n")
    assert registry.fetch("soc-Epinions1", cache_dir=tmp_path) == cached
    g = registry.load("soc-Epinions1", cache_dir=tmp_path)
    assert g.n == 3 and g.num_edges == 3         # largest CC of the stub


def test_env_cache_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_DATA_DIR", str(tmp_path / "from-env"))
    assert registry.data_dir() == tmp_path / "from-env"
    assert registry.data_dir(tmp_path) == tmp_path      # override wins


@pytest.fixture
def tiny_synthetic():
    ds = Dataset(name="er-tiny-test", kind="synthetic",
                 spec=(("model", "er"), ("n", 300), ("avg_degree", 6.0),
                       ("seed", 1)))
    registry.register(ds)
    yield ds
    DATASETS.pop(ds.name)


def test_synthetic_sampled_once_then_cached(tmp_path, tiny_synthetic):
    p1 = registry.fetch("er-tiny-test", cache_dir=tmp_path)
    raw = p1.read_bytes()
    assert p1.parent == tmp_path and raw.startswith(b"# synthetic stand-in")
    p2 = registry.fetch("er-tiny-test", cache_dir=tmp_path)
    assert p2 == p1 and p2.read_bytes() == raw   # cache hit, not re-sampled
    g = registry.load("er-tiny-test", cache_dir=tmp_path)
    assert g.is_csr_native and 250 < g.n <= 300 and g.num_edges > 500


def test_cached_file_verified_against_sidecar(tmp_path, tiny_synthetic):
    """A corrupted/truncated cache entry fails loudly on the next fetch
    (the sidecar digest written at synthesis/download time catches it)."""
    p = registry.fetch("er-tiny-test", cache_dir=tmp_path)
    sidecar = p.with_suffix(p.suffix + ".sha256")
    assert sidecar.exists()
    registry.fetch("er-tiny-test", cache_dir=tmp_path)   # intact: fine
    p.write_bytes(p.read_bytes()[: p.stat().st_size // 2])   # truncate
    with pytest.raises(RuntimeError, match="sha256 mismatch"):
        registry.fetch("er-tiny-test", cache_dir=tmp_path)


# ---- Table II harness ----


def test_table2_karate_rows_match_dense_reference(tmp_path):
    result = run_table2(("karate",), K=4, r_grid=(1, 2), cache_dir=tmp_path)
    assert [r["r"] for r in result["rows"]] == [1, 2]
    g = registry.load("karate")
    for row in result["rows"]:
        assert row["n"] == 34 and row["edges"] == 78
        alloc = er_allocation(g.n, 4, row["r"], interleave=True, pad=True)
        assert row["n_padded"] == alloc.n
        want = loads.empirical_loads(g.padded(alloc.n), alloc)
        assert row["uncoded"] == want["uncoded"]          # bitwise: same plan
        assert row["coded"] == want["coded"]
        assert row["gain"] == want["gain"]
    # uncoded load never below coded; r=1 has no multicast gain.
    assert result["rows"][0]["gain"] == pytest.approx(1.0)
    assert result["rows"][1]["coded"] < result["rows"][1]["uncoded"]


def test_table2_markdown_and_json_round_trip(tmp_path):
    result = run_table2(("karate",), K=4, r_grid=(2,), cache_dir=tmp_path)
    md = to_markdown(result)
    assert "| karate | 34 | 78 | 2 |" in md
    assert md.count("\n") >= 4                    # header + rule + row
    again = json.loads(json.dumps(result))        # JSON-serializable rows
    assert again["rows"][0]["dataset"] == "karate"


def test_table2_report_callback(tmp_path):
    seen = []
    run_table2(("karate",), K=4, r_grid=(2,), cache_dir=tmp_path,
               report=lambda tag, us, text: seen.append((tag, text)))
    assert seen and seen[0][0] == "table2_karate_r2"
    assert "gain=" in seen[0][1]


# ---- acceptance: >= 76k vertices, dense-free, O(edges), Theorem-1 gains ----


@pytest.fixture(scope="module")
def standin_cache(tmp_path_factory):
    """Module-scoped cache so er-76k is sampled+written exactly once."""
    cache = tmp_path_factory.mktemp("repro-data")
    registry.fetch("er-76k", cache_dir=cache)
    return cache


def test_table2_76k_standin_dense_free_o_edges(standin_cache):
    tracemalloc.start()
    result = run_table2(("er-76k",), K=6, r_grid=(1, 2, 3),
                        cache_dir=standin_cache)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    rows = result["rows"]
    assert rows[0]["n"] >= 76_000
    directed = rows[0]["edges"] * 2
    # O(edges): a single [n, n] bool at this n would be >= 5.7 GB.
    assert peak < 600 * directed, f"peak {peak / 1e6:.0f}MB is not O(edges)"
    for row in rows:
        # Theorem-1 closed forms at the empirical density: the measured
        # coded load sits between the converse and the finite-n bound, and
        # the measured gain is the inverse-linear r within tolerance.
        assert row["uncoded"] == pytest.approx(row["uncoded_er"], rel=0.05)
        assert row["coded"] <= row["coded_er_finite"] * 1.02
        assert row["coded"] >= row["lower_bound_er"] * 0.97
        assert 0.85 <= row["gain"] / row["r"] <= 1.02


def test_table2_76k_guard_blocks_dense_touch(standin_cache):
    """The whole pipeline ran CSR-native: the same graph object refuses to
    materialize [n, n], so no stage could have touched `.adj`."""
    g = registry.load("er-76k", cache_dir=standin_cache)
    assert g.is_csr_native and g.n > gm.DENSE_LIMIT
    with pytest.raises(ValueError, match="dense_limit"):
        g.adj
    with pytest.raises(ValueError, match="dense_limit"):
        g.padded(er_allocation(g.n, 6, 3, pad=True).n).adj
    # The engine-facing artifacts stay sparse: CSR + padded CSR only.
    assert g.csr.nnz == 2 * g.num_edges
    assert np.all(np.diff(g.csr.indptr) >= 0)
