"""Session checkpoint/restore for long-running coded graph jobs.

DESIGN
======
A `CompiledEngine.run` epoch is fully determined by four things: the
iterate state vector, the iteration counter, the cumulative shuffle-bit
counter, and the allocation the schedule was compiled from (the graph and
program are the caller's inputs, and the schedule itself is a pure function
of (graph, allocation) — recompiling it is cheaper and safer than
serializing compiled index arrays). So that is exactly what a checkpoint
persists, and nothing else:

    <dir>/epoch_<N>/
        manifest.json       # written LAST: iteration, bits, alloc scalars,
                            # subsets, per-file sha256, alloc fingerprint
        state.npy           # [n] or [n, B] float32 iterate
        batch_of.npy        # alloc arrays (omitted for single-machine runs)
        map_sets.npy
        reduce_owner.npy

Durability contract (mirrors `checkpoint/manager.py`, the training-style
manager this module is the session-scoped sibling of):

  * **manifest-last, atomic publish** — everything is written into a
    `.tmp_epoch_<N>` scratch directory, the manifest is the final write,
    and the scratch dir is `os.replace`d into place. A directory without
    a manifest is garbage by definition, so a crash at ANY byte of a save
    leaves every previously-published epoch intact and readable
    (`epochs()` only lists directories whose manifest exists).
  * **background-thread saves** — `save()` snapshots the arrays
    synchronously (callers may mutate their state right after) and writes
    on a daemon thread; the iteration loop never blocks on disk. A failed
    write is re-raised from the next `save()`/`wait()` call instead of
    vanishing in the thread.
  * **bounded retention** — after each publish, all but the newest `keep`
    epochs are deleted (newest-N is the restart set; older epochs carry no
    extra information since the run is deterministic).
  * **integrity** — every array file's sha256 is recorded in the manifest
    and verified on load; `alloc_fingerprint` (sha256 over the allocation's
    defining arrays) names the schedule, so `engine.restore` can tell
    "resume the same schedule" from "elastic restore onto K' servers"
    without comparing arrays.

Restore (`load_checkpoint` here, `engine.restore` for the full session)
reconstructs the exact `Allocation`, so resuming is bitwise-identical to
the uninterrupted run; an *elastic* restore re-derives the allocation for a
new K via `faults.rebalance` — the state vector carries over unchanged
because the sparse Reduce is allocation-agnostic (canonical CSR entry
order; see engine.py).

This module is numpy-only on purpose: core/ stays importable without jax.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import threading

import numpy as np

from ..obs import get_tracer
from .allocation import Allocation

_FORMAT = "repro-session-checkpoint-v1"


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def alloc_fingerprint(alloc: Allocation) -> str:
    """sha256 naming the allocation (hence the schedule) up to identity."""
    h = hashlib.sha256()
    h.update(f"{alloc.n},{alloc.K},{alloc.r},{alloc.subsets}".encode())
    for arr in (alloc.batch_of, alloc.map_sets, alloc.reduce_owner):
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


@dataclasses.dataclass(frozen=True)
class SessionCheckpoint:
    """One restored epoch (see `load_checkpoint` / `engine.restore`)."""

    iteration: int                 # iterations completed when saved
    state: np.ndarray              # [n] or [n, B] float32 iterate
    shuffle_bits: int              # cumulative bits up to `iteration`
    alloc: Allocation | None       # None for single-machine sessions
    fingerprint: str               # alloc_fingerprint ("" when alloc is None)


class SessionCheckpointer:
    """Atomic, async, bounded-retention checkpoint writer (module docstring
    has the layout and durability contract)."""

    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None

    # ---- save ----

    def save(self, iteration: int, state: np.ndarray, shuffle_bits: int,
             alloc: Allocation | None, blocking: bool = False) -> None:
        """Snapshot synchronously, write to disk on a background thread."""
        with get_tracer().span("checkpoint.save", iteration=int(iteration),
                               shuffle_bits=int(shuffle_bits)):
            self.wait()                      # also re-raises a prior failure
            snap = np.array(state, dtype=np.float32, copy=True)
            self._thread = threading.Thread(
                target=self._guarded_write,
                args=(int(iteration), snap, int(shuffle_bits), alloc),
                daemon=True)
            self._thread.start()
        if blocking:
            self.wait()

    def _guarded_write(self, iteration, state, bits, alloc):
        try:
            # Own root span: this runs on the checkpoint writer thread, so
            # it lands on its own trace track rather than inside the
            # iteration that triggered it.
            with get_tracer().span("checkpoint.write", iteration=iteration,
                                   bytes=int(state.nbytes)):
                self._write(iteration, state, bits, alloc)
        except BaseException as exc:         # surfaced by the next wait()
            self._error = exc

    def _write(self, iteration: int, state: np.ndarray, bits: int,
               alloc: Allocation | None) -> None:
        tmp = os.path.join(self.dir, f".tmp_epoch_{iteration}")
        final = os.path.join(self.dir, f"epoch_{iteration}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"format": _FORMAT, "iteration": iteration,
                    "shuffle_bits": bits, "arrays": {}}
        arrays = {"state": state}
        if alloc is not None:
            arrays.update(batch_of=alloc.batch_of, map_sets=alloc.map_sets,
                          reduce_owner=alloc.reduce_owner)
            manifest["alloc"] = {
                "n": alloc.n, "K": alloc.K, "r": alloc.r,
                "subsets": [list(s) for s in alloc.subsets]}
            manifest["alloc_fingerprint"] = alloc_fingerprint(alloc)
        for name, arr in arrays.items():
            np.save(os.path.join(tmp, f"{name}.npy"), arr)
            manifest["arrays"][name] = {
                "file": f"{name}.npy", "shape": list(arr.shape),
                "dtype": str(arr.dtype), "sha256": _sha256(arr)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)           # manifest LAST
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)               # atomic publish
        self._gc()

    def _gc(self) -> None:
        for e in self.epochs()[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"epoch_{e}"),
                          ignore_errors=True)

    def wait(self) -> None:
        """Join the in-flight save; re-raise its failure, if any."""
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        if self._error is not None:
            exc, self._error = self._error, None
            raise exc

    # ---- enumerate ----

    def epochs(self) -> list[int]:
        return _epochs(self.dir)

    def latest(self) -> int | None:
        e = self.epochs()
        return e[-1] if e else None


def _epochs(directory: str) -> list[int]:
    out = []
    for d in os.listdir(directory):
        if d.startswith("epoch_") and os.path.exists(
                os.path.join(directory, d, "manifest.json")):
            out.append(int(d.split("_")[1]))
    return sorted(out)


def load_checkpoint(directory: str,
                    epoch: int | None = None) -> SessionCheckpoint:
    """Read one published epoch back (newest by default), verifying every
    array against its manifest sha256."""
    with get_tracer().span("checkpoint.load",
                           epoch=-1 if epoch is None else int(epoch)):
        return _load_checkpoint(directory, epoch)


def _load_checkpoint(directory: str,
                     epoch: int | None = None) -> SessionCheckpoint:
    epochs = _epochs(directory)
    if epoch is None:
        if not epochs:
            raise FileNotFoundError(f"no checkpoints in {directory}")
        epoch = epochs[-1]
    elif epoch not in epochs:
        raise FileNotFoundError(
            f"epoch {epoch} not in {directory} (have {epochs})")
    d = os.path.join(directory, f"epoch_{epoch}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    if manifest.get("format") != _FORMAT:
        raise ValueError(f"unrecognized checkpoint format in {d}: "
                         f"{manifest.get('format')!r}")
    arrays = {}
    for name, meta in manifest["arrays"].items():
        arr = np.load(os.path.join(d, meta["file"]))
        if _sha256(arr) != meta["sha256"]:
            raise ValueError(f"checkpoint {d} corrupt: {name} digest mismatch")
        arrays[name] = arr
    alloc = None
    if "alloc" in manifest:
        a = manifest["alloc"]
        alloc = Allocation(a["n"], a["K"], a["r"],
                           tuple(tuple(s) for s in a["subsets"]),
                           arrays["batch_of"], arrays["map_sets"],
                           arrays["reduce_owner"])
        if alloc_fingerprint(alloc) != manifest["alloc_fingerprint"]:
            raise ValueError(f"checkpoint {d} corrupt: allocation "
                             "fingerprint mismatch")
    return SessionCheckpoint(int(manifest["iteration"]), arrays["state"],
                             int(manifest["shuffle_bits"]), alloc,
                             manifest.get("alloc_fingerprint", ""))
