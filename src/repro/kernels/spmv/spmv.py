"""Blocked-dense SpMV Pallas TPU kernel.

The TPU adaptation of PageRank's Map+Reduce hot loop (DESIGN.md §3): the
adjacency is consumed as MXU-aligned dense tiles streamed HBM->VMEM; each grid
step contracts one [bm, bk] tile against a [bk, 1] slice of the source vector
and accumulates into the [bm, 1] output block, which stays resident in VMEM
across the k-sweep (revisiting output blocks is the standard Pallas matmul
accumulation pattern).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _spmv_kernel(a_ref, x_ref, o_ref):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, x, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("bm", "bk", "interpret"))
def spmv_pallas(adj: jnp.ndarray, x: jnp.ndarray, *, bm: int = 128,
                bk: int = 128, interpret: bool = True) -> jnp.ndarray:
    """y = adj @ x via pallas_call. Shapes must tile evenly by (bm, bk)."""
    m, n = adj.shape
    assert m % bm == 0 and n % bk == 0, (m, n, bm, bk)
    x2 = x.reshape(n, 1)
    out = pl.pallas_call(
        _spmv_kernel,
        grid=(m // bm, n // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
            pl.BlockSpec((bk, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(adj, x2)
    return out.reshape(m)
