"""Fault tolerance: node failure, straggler degradation, elastic rebalance."""
import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation


@pytest.fixture
def setup():
    K, r = 5, 2
    n = divisible_n(50, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=8)
    return g, er_allocation(n, K, r), algo.pagerank()


def test_single_failure_is_transparent(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 4)
    for f in range(alloc.K):
        res, stats = faults.run_with_failure(prog, g, alloc, 4, failed=(f,),
                                             fail_at_iter=2)
        np.testing.assert_array_equal(res.state, ref)
        # r=2 replication: nothing needs re-Mapping for a single failure.
        assert stats.remapped_vertices == 0


def test_r_minus_one_failures_need_no_remap(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    res, stats = faults.run_with_failure(prog, g, alloc, 3, failed=(1,),
                                         fail_at_iter=0)
    np.testing.assert_array_equal(res.state, ref)
    assert stats.remapped_vertices == 0


def test_r_failures_trigger_remap_but_still_correct(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    res, stats = faults.run_with_failure(prog, g, alloc, 3, failed=(0, 1),
                                         fail_at_iter=1)
    np.testing.assert_array_equal(res.state, ref)
    # Batch B_{0,1} was only at the failed pair -> must be re-Mapped.
    assert stats.remapped_vertices == alloc.g


def test_rebalance_preserves_results(setup):
    g, alloc, prog = setup
    ref = algo.reference_run(prog, g, 3)
    for K_new in (2, 5, 10):
        try:
            alloc2 = faults.rebalance(alloc, K_new)
        except ValueError:
            continue  # n not compatible; rebalance() is explicit about padding
        res = engine.run(prog, g, alloc2, 3, mode="coded")
        np.testing.assert_array_equal(res.state, ref)


def test_degraded_allocation_is_valid(setup):
    g, alloc, prog = setup
    degraded, _ = faults.degrade_allocation(alloc, (3,))
    assert not degraded.map_sets[3].any()
    assert (degraded.reduce_owner != 3).all()
    # Every vertex still Mapped somewhere and Reduced exactly once.
    assert degraded.map_sets.any(axis=0).all()
    assert len(degraded.reduce_owner) == alloc.n


def test_all_failures_rejected(setup):
    g, alloc, _ = setup
    with pytest.raises(ValueError):
        faults.degrade_allocation(alloc, tuple(range(alloc.K)))


def test_straggler_load_degrades_gracefully():
    """Coded shuffle with straggling senders stays well below uncoded."""
    from repro.core.coded_shuffle import coded_load
    from repro.core.uncoded_shuffle import uncoded_load
    import repro.core.graph_models as gm
    from repro.core.allocation import divisible_n, er_allocation

    K, r = 6, 3
    n = divisible_n(120, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=2)
    alloc = er_allocation(n, K, r)
    base = coded_load(g.adj, alloc)
    unc = uncoded_load(g.adj, alloc)
    prev = base
    for s in range(1, r):
        load = faults.straggler_coded_load(g.adj, alloc, tuple(range(s)))
        assert base <= load < unc          # graceful, still beats uncoded
        assert load >= prev
        prev = load


def test_straggler_load_plan_matches_dense_reference():
    """The CSR/plan entry point (PR 5) reproduces the dense subset-
    enumeration reference exactly: same sizes, same hand-over accounting."""
    from repro import graphs
    from repro.core.shuffle_plan import compile_plan_csr

    for K, r in [(6, 3), (5, 2)]:
        n = divisible_n(120, K, r)
        g = graphs.erdos_renyi(n, 0.15, seed=11)
        alloc = er_allocation(n, K, r)
        plan = compile_plan_csr(g.csr, alloc, validate=False)
        for s in range(1, r):
            strag = tuple(range(s))
            want = faults.straggler_coded_load(g.adj, alloc, strag)  # dense
            assert faults.straggler_coded_load(g, alloc, strag) == want
            assert faults.straggler_coded_load(g.csr, alloc, strag) == want
            assert faults.straggler_coded_load(plan, alloc, strag) == want
            assert faults.straggler_coded_load_plan(plan, strag) == want


def test_straggler_plan_rejects_unhealthy_groups_and_no_schedule():
    from repro import graphs
    from repro.core.shuffle_plan import compile_plan_csr

    K, r = 6, 3
    n = divisible_n(120, K, r)
    g = graphs.erdos_renyi(n, 0.15, seed=11)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    with pytest.raises(ValueError, match="lacks healthy senders"):
        faults.straggler_coded_load_plan(plan, (0, 1, 2))
    bare = compile_plan_csr(g.csr, alloc, validate=False, schedule=False)
    with pytest.raises(ValueError, match="schedule=False"):
        faults.straggler_coded_load_plan(bare, (0,))
    # Mismatched (plan, alloc) pairs are an error, not a silent wrong load.
    other = er_allocation(2 * n, K, r)
    with pytest.raises(ValueError, match="compiled for"):
        faults.straggler_coded_load(plan, other, (0,))
