"""Bit-exact (de)serialization of intermediate values for the coded Shuffle.

The paper splits each T-bit intermediate value v_{i,j} into r segments of T/r
bits. We represent values as float32 (T = 32) and operate on their exact bit
patterns so XOR coding and recovery are bit-perfect for *any* r (segment
boundaries need not divide 32 evenly; segments are the ceil/floor split).
"""
from __future__ import annotations

import numpy as np

T_BITS = 32


def floats_to_bits(x: np.ndarray) -> np.ndarray:
    """[m] float32 -> [m, 32] uint8 in {0,1} (big-endian bit order)."""
    x = np.ascontiguousarray(x, dtype=np.float32)
    return np.unpackbits(x.view(np.uint8).reshape(-1, 4), axis=1)


def bits_to_floats(bits: np.ndarray) -> np.ndarray:
    """[m, 32] uint8 bits -> [m] float32."""
    packed = np.packbits(bits.astype(np.uint8), axis=1)
    return packed.reshape(-1, 4).copy().view(np.float32).ravel()


def segment_bounds(r: int, t_bits: int = T_BITS) -> list[tuple[int, int]]:
    """Split [0, t_bits) into r near-equal contiguous segments."""
    edges = np.linspace(0, t_bits, r + 1).round().astype(int)
    return [(int(edges[s]), int(edges[s + 1])) for s in range(r)]


def split_segments(bits: np.ndarray, r: int) -> list[np.ndarray]:
    """[m, 32] bits -> r arrays [m, seg_len_s]."""
    return [bits[:, a:b] for a, b in segment_bounds(r, bits.shape[1])]
