"""Sharded checkpointing with async save and elastic restore.

Layout:  <dir>/step_<N>/manifest.json + one .npy per leaf (flattened path
key).  Params are saved with their logical axes, so restore re-shards onto
whatever mesh the restarted job has (elastic scaling across K / pod counts).
Saves run on a background thread (training never blocks on disk); the
manifest is written last and atomically, so a crash mid-save never corrupts
the latest complete checkpoint.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import ml_dtypes
import numpy as np

# np.save round-trips only standard dtypes; bf16 etc. are stored as a
# same-width integer view and reconstructed from the manifest dtype string.
_VIEW_DTYPES = {"bfloat16": (np.uint16, ml_dtypes.bfloat16),
                "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
                "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2)}


def _flat(tree):
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in leaves_with_paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None

    # ---- save ----

    def save(self, step: int, params, opt_state=None, extra: dict | None = None,
             blocking: bool = False):
        """Snapshot to host memory synchronously, write to disk async."""
        host = {
            "params": jax.tree.map(np.asarray, params),
            "opt_state": jax.tree.map(np.asarray, opt_state)
            if opt_state is not None else None,
        }
        self.wait()
        self._thread = threading.Thread(
            target=self._write, args=(step, host, extra or {}), daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def _write(self, step: int, host: dict, extra: dict):
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        for group in ("params", "opt_state"):
            if host[group] is None:
                continue
            for key, arr in _flat(host[group]).items():
                fname = f"{group}__{key.replace('/', '.')}.npy"
                dtype = str(arr.dtype)
                if dtype in _VIEW_DTYPES:
                    arr = arr.view(_VIEW_DTYPES[dtype][0])
                np.save(os.path.join(tmp, fname), arr)
                manifest["leaves"][f"{group}/{key}"] = {
                    "file": fname, "shape": list(arr.shape), "dtype": dtype}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()

    # ---- restore ----

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "manifest.json")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    def restore(self, template_params, template_opt=None, step: int | None = None,
                shardings=None):
        """Restore onto the *current* job's tree/mesh.

        template_*: pytrees (arrays or ShapeDtypeStructs) defining structure.
        shardings: optional matching tree of NamedShardings (elastic re-shard:
        the checkpoint may have been written from a different mesh).
        """
        step = self.latest() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        def load_group(name, template, shards):
            if template is None:
                return None
            flat_t = _flat(template)
            flat_s = _flat(shards) if shards is not None else {}
            out = {}
            for key in flat_t:
                meta = manifest["leaves"][f"{name}/{key}"]
                arr = np.load(os.path.join(d, meta["file"]))
                if meta["dtype"] in _VIEW_DTYPES:
                    arr = arr.view(_VIEW_DTYPES[meta["dtype"]][1])
                # Always produce jax arrays (donation-safe); re-shard when the
                # new mesh's shardings are provided (elastic restore).
                arr = jax.device_put(arr, flat_s.get(key))
                out[key] = arr
            # Rebuild tree from template structure.
            leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
            ordered = []
            for path, _ in leaves_with_paths:
                k = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                             for p in path)
                ordered.append(out[k])
            return jax.tree_util.tree_unflatten(treedef, ordered)

        params = load_group("params", template_params, shardings)
        opt = load_group("opt_state", template_opt, None) \
            if template_opt is not None else None
        return step, params, opt, manifest["extra"]
