"""Admission-batched serving: coalescing, exactness, and amortization.

`GraphService` must (a) return per-query results identical to standalone
engine runs (bitwise for SSSP - min reductions), (b) actually coalesce
concurrent queries into shared batched runs (fewer batches than queries,
shuffle bits = schedule bits x total payload columns), and (c) validate
inputs and refuse work after close.
"""
import numpy as np
import pytest

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.serve import GraphService


def _case(n=48, K=4, r=2, p=0.2, seed=11):
    n = divisible_n(n, K, r)
    return graphs.erdos_renyi(n, p, seed=seed), er_allocation(n, K, r)


def test_sssp_queries_match_standalone_bitwise():
    g, alloc = _case()
    roots = [0, 3, 7, 11, 19, 23]
    with GraphService(g, alloc, max_batch=3, max_wait_s=0.05) as svc:
        futs = [svc.submit("sssp", s, iters=6) for s in roots]
        results = [f.result(timeout=60) for f in futs]
    for s, d in zip(roots, results):
        ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(6)
        assert np.array_equal(d, ref.state), s
    assert svc.stats.queries == len(roots)


def test_ppr_queries_match_standalone():
    g, alloc = _case()
    rng = np.random.default_rng(4)
    prefs = rng.random((3, g.n)).astype(np.float32)
    prefs /= prefs.sum(axis=1, keepdims=True)
    with GraphService(g, alloc, max_batch=3, max_wait_s=0.05) as svc:
        futs = [svc.submit("ppr", p, iters=5) for p in prefs]
        results = [f.result(timeout=60) for f in futs]
    for p, v in zip(prefs, results):
        ref = engine.compile(algo.personalized_pagerank(p),
                             g, alloc, "coded").run(5)
        np.testing.assert_allclose(v, ref.state[:, 0], rtol=1e-6, atol=1e-9)


def test_full_batches_amortize_one_shuffle_run():
    g, alloc = _case()
    B = 4
    # Generous admission window + exactly-full batches => deterministic
    # coalescing: the worker admits each batch the moment it fills.
    with GraphService(g, alloc, max_batch=B, max_wait_s=5.0) as svc:
        futs = [svc.submit("sssp", s, iters=4) for s in range(2 * B)]
        for f in futs:
            f.result(timeout=120)
    assert svc.stats.queries == 2 * B
    assert svc.stats.batches == 2
    assert svc.stats.mean_batch == B
    single = engine.compile(algo.sssp(0), g, alloc, "coded").run(4)
    # Bits scale with payload columns only: schedule paid once per batch.
    assert svc.stats.shuffle_bits == 2 * B * single.shuffle_bits
    assert svc.stats.bits_per_query == single.shuffle_bits


def test_lanes_keep_kinds_and_iter_counts_separate():
    g, alloc = _case()
    with GraphService(g, alloc, max_batch=8, max_wait_s=0.02) as svc:
        f_sssp = svc.submit("sssp", 1, iters=3)
        f_ppr = svc.submit("ppr", algo.uniform_prefs(g.n)[:, 0], iters=3)
        f_long = svc.submit("sssp", 1, iters=5)
        a, b, c = (f.result(timeout=60) for f in (f_sssp, f_ppr, f_long))
    assert np.array_equal(
        a, engine.compile(algo.sssp(1), g, alloc, "coded").run(3).state)
    assert np.array_equal(
        c, engine.compile(algo.sssp(1), g, alloc, "coded").run(5).state)
    assert b.shape == (g.n,)
    assert svc.stats.batches == 3      # three (kind, iters) lanes


def test_validation_and_lifecycle():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=2, max_wait_s=0.01)
    try:
        with pytest.raises(ValueError, match="out of range"):
            svc.submit("sssp", g.n)
        with pytest.raises(ValueError, match=rf"n={g.n}"):
            svc.submit("ppr", np.ones(3, dtype=np.float32))
        with pytest.raises(ValueError, match="unknown query kind"):
            svc.submit("bfs", 0)
        assert set(svc.loads()) == {"uncoded", "coded",
                                    "coded_leftover_unicast", "gain"}
    finally:
        svc.close()
    with pytest.raises(RuntimeError, match="closed"):
        svc.submit("sssp", 0)
    with pytest.raises(ValueError, match="max_batch"):
        GraphService(g, alloc, max_batch=0)


def test_close_drains_pending_queries():
    g, alloc = _case()
    svc = GraphService(g, alloc, max_batch=4, max_wait_s=10.0)
    # A partial batch sits in its admission window; close() must flush it
    # rather than drop the futures.
    futs = [svc.submit("sssp", s, iters=3) for s in (0, 1)]
    svc.close()
    for s, f in zip((0, 1), futs):
        ref = engine.compile(algo.sssp(s), g, alloc, "coded").run(3)
        assert np.array_equal(f.result(timeout=5), ref.state)
