"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-scale
numbers; the BlockSpec tiling is the TPU deliverable)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.spmv import ops as spmv_ops
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.xor_code import ops as xor_ops


def _time(f, *args, reps=3):
    out = f(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run(report):
    rng = np.random.default_rng(0)
    adj = jnp.array((rng.random((512, 512)) < 0.1), jnp.float32)
    x = jnp.array(rng.standard_normal(512), jnp.float32)
    us_k = _time(lambda a, b: spmv_ops.spmv(a, b), adj, x)
    us_r = _time(lambda a, b: spmv_ops.spmv(a, b, use_kernel=False), adj, x)
    report("spmv_pallas_512", us_k, f"ref_us={us_r:.0f}")

    rows = jnp.array(rng.integers(0, 2**32, (3, 1024, 4), dtype=np.uint32))
    valid = jnp.array(rng.random((3, 1024)) < 0.7)
    us_k = _time(lambda a, b: xor_ops.xor_encode(a, b), rows, valid)
    us_r = _time(lambda a, b: xor_ops.xor_encode(a, b, use_kernel=False),
                 rows, valid)
    report("xor_encode_pallas_1024", us_k, f"ref_us={us_r:.0f}")

    G, L, P, N = 4, 256, 32, 16
    args = (jnp.array(rng.standard_normal((G, L, P)), jnp.float32),
            jnp.array(rng.uniform(0.01, 0.2, (G, L)), jnp.float32),
            jnp.array(-rng.uniform(0.5, 2, G), jnp.float32),
            jnp.array(rng.standard_normal((G, L, N)), jnp.float32),
            jnp.array(rng.standard_normal((G, L, N)), jnp.float32),
            jnp.array(rng.standard_normal(G), jnp.float32))
    us_k = _time(lambda *a: ssd_ops.ssd(*a, chunk=64)[0], *args)
    us_r = _time(lambda *a: ssd_ops.ssd(*a, use_kernel=False)[0], *args)
    report("ssd_chunk_pallas_256", us_k, f"seq_ref_us={us_r:.0f}")
