"""deepseek-v2-236b [moe] - MLA (kv_lora=512), 2 shared + 160 routed top-6
[arXiv:2405.04434; hf]."""
from .base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, head_dim=128,
    d_ff=3072,                       # 2 shared experts x 1536, fused
    vocab=102400, rope_theta=10_000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared=2, d_ff_expert=1536),
)
