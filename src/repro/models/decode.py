"""Serving path: KV/state cache layout, prefill (cache fill) and decode step.

Cache tensors are stacked over layers (leading L axis) so decode is one scan.
Decode is lockstep-batched (all sequences at the same position - the serving
driver pads/batches accordingly; DESIGN.md notes the raggedness simplification).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeSpec
from ..sharding.rules import constrain
from . import ssm as ssm_mod
from .layers import rms_norm
from .transformer import (_embed_inputs, _window_arr, block_decode,
                          block_forward, moe_interleave)


# ---------------- cache layout ----------------

def _attn_cache_struct(cfg: ModelConfig, L: int, B: int, S: int):
    from ..sharding.rules import tp_size
    if cfg.mla:
        # The latent cache has no head axis: always shard its seq dim over
        # the tensor axis (the per-chunk softmax reduces across it).
        m = cfg.mla
        return {"lat": ((L, B, S, m.kv_lora_rank),
                        ("layers", "batch", "act_seq_tp", None)),
                "rope": ((L, B, S, m.qk_rope_head_dim),
                         ("layers", "batch", "act_seq_tp", None))}
    # PERF (EXPERIMENTS.md SSPerf, cell internlm2/decode_32k): when the kv
    # heads can't split the tensor axis, shard the cache *sequence* over it
    # instead of replicating - replication both overflows HBM (48L x 32k x
    # 8kv caches) and forces a full-cache all-gather every decoded token.
    kv_div = cfg.n_kv_heads % tp_size() == 0
    seq_ax = "act_seq" if kv_div else "act_seq_tp"
    head_ax = "act_kv" if kv_div else None
    return {"k": ((L, B, S, cfg.n_kv_heads, cfg.head_dim),
                  ("layers", "batch", seq_ax, head_ax, None)),
            "v": ((L, B, S, cfg.n_kv_heads, cfg.head_dim),
                  ("layers", "batch", seq_ax, head_ax, None))}


def cache_struct(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """{name: (shape, logical_axes)} for every cache tensor."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family in ("ssm", "hybrid"):
        s = cfg.ssm
        di = s.d_inner(cfg.d_model)
        nh = s.n_heads(cfg.d_model)
        out = {"conv": ((cfg.n_layers, B, s.conv_width - 1, di + 2 * s.d_state),
                        ("layers", "batch", None, "inner")),
               "ssm": ((cfg.n_layers, B, nh, s.d_state, s.head_dim),
                       ("layers", "batch", "act_heads", None, None))}
        if cfg.family == "hybrid" and cfg.attn_every:
            n_attn = (cfg.n_layers + cfg.attn_every - 1) // cfg.attn_every
            out |= {f"attn_{k}": v for k, v in
                    _attn_cache_struct(cfg, n_attn, B, S).items()}
        return out
    unit = moe_interleave(cfg)
    L = cfg.n_layers // unit
    if unit == 1:
        return _attn_cache_struct(cfg, L, B, S)
    out = {}
    for part in ("dense", "moe"):
        out |= {f"{part}_{k}": v for k, v in
                _attn_cache_struct(cfg, L, B, S).items()}
    return out


def cache_specs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStruct cache stand-ins (+ position scalar) for the dry-run."""
    out = {name: jax.ShapeDtypeStruct(sh, jnp.float32 if "ssm" in name else dtype)
           for name, (sh, _) in cache_struct(cfg, shape).items()}
    out["pos"] = jax.ShapeDtypeStruct((), jnp.int32)
    return out


def init_cache(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16) -> dict:
    out = {name: jnp.zeros(sh, jnp.float32 if "ssm" in name else dtype)
           for name, (sh, _) in cache_struct(cfg, shape).items()}
    out["pos"] = jnp.zeros((), jnp.int32)
    return out


# ---------------- decode step ----------------

def _attn_decode_scan(params, cfg, x, pos, cache, prefix=""):
    unit = moe_interleave(cfg)
    gk = lambda k: f"{prefix}{k}" if prefix else k

    if unit == 1:
        windows = _window_arr(cfg, cfg.n_layers)
        keys = ("lat", "rope") if cfg.mla else ("k", "v")
        xs = ({k: cache[gk(k)] for k in keys}, windows, params["layers"])

        def body(h, inp):
            cl, w, lp = inp
            h, new_cl = block_decode(lp, cfg, h, pos, cl, w,
                                     moe_layer=bool(cfg.moe))
            return h, new_cl

        x, new_cache = jax.lax.scan(body, x, xs)
        return x, {gk(k): v for k, v in new_cache.items()}

    n_units = cfg.n_layers // unit
    keys = ("lat", "rope") if cfg.mla else ("k", "v")
    xs = ({k: cache[f"dense_{k}"] for k in keys},
          {k: cache[f"moe_{k}"] for k in keys},
          _window_arr(cfg, n_units, 0, unit), _window_arr(cfg, n_units, 1, unit),
          params["layers"])

    def body(h, inp):
        cd, cm, wd, wm, lp = inp
        h, ncd = block_decode(lp["dense"], cfg, h, pos, cd, wd, moe_layer=False)
        h, ncm = block_decode(lp["moe"], cfg, h, pos, cm, wm, moe_layer=True)
        return h, (ncd, ncm)

    x, (nd, nm) = jax.lax.scan(body, x, xs)
    out = {f"dense_{k}": v for k, v in nd.items()}
    out |= {f"moe_{k}": v for k, v in nm.items()}
    return x, out


def _ssm_decode_scan(params, cfg, x, pos, cache):
    from .transformer import _tree_slice, hybrid_segments

    use_shared = cfg.family == "hybrid" and cfg.attn_every
    attn_keys = ("lat", "rope") if cfg.mla else ("k", "v")

    def seg_scan(lp_seg, conv_seg, ssm_seg, h):
        def body(h, inp):
            lp, conv, ssm = inp
            hn = rms_norm(h, lp["norm"], cfg.norm_eps)
            out, (nconv, nssm) = ssm_mod.mamba2_block(lp["mixer"], cfg, hn,
                                                      state=(conv, ssm))
            return h + out, (nconv, nssm)

        h, (nconv, nssm) = jax.lax.scan(body, h, (lp_seg, conv_seg, ssm_seg))
        return h, nconv, nssm

    new_conv, new_ssm, new_attn = [], [], {k: [] for k in attn_keys}
    for j, (a, b) in enumerate(hybrid_segments(cfg)):
        if use_shared:
            cl = {k: cache[f"attn_{k}"][j] for k in attn_keys}
            x, ncl = block_decode(params["shared_attn"], cfg, x, pos, cl,
                                  jnp.int32(-1), moe_layer=False)
            for k in attn_keys:
                new_attn[k].append(ncl[k])
        x, nconv, nssm = seg_scan(_tree_slice(params["layers"], a, b),
                                  cache["conv"][a:b], cache["ssm"][a:b], x)
        new_conv.append(nconv)
        new_ssm.append(nssm)
    new_cache = {"conv": jnp.concatenate(new_conv),
                 "ssm": jnp.concatenate(new_ssm)}
    if use_shared:
        for k in attn_keys:
            new_cache[f"attn_{k}"] = jnp.stack(new_attn[k])
    return x, new_cache


def decode_step(params, cfg: ModelConfig, cache: dict, batch: dict):
    """One token for every sequence. batch = {'tokens': [B, 1]}.

    Returns (logits [B, vocab], new_cache with pos+1).
    """
    tokens = batch["tokens"]
    B = tokens.shape[0]
    scale = jnp.sqrt(jnp.float32(cfg.d_model)).astype(jnp.bfloat16)
    x = params["embed"][tokens] * scale
    x = constrain(x, "batch", None, None)
    pos = jnp.broadcast_to(cache["pos"], (B, 1))
    if cfg.family in ("ssm", "hybrid"):
        x, new_cache = _ssm_decode_scan(params, cfg, x, pos, cache)
    else:
        x, new_cache = _attn_decode_scan(params, cfg, x, pos, cache)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("btd,vd->btv", x, params["embed"]).astype(jnp.float32)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    new_cache["pos"] = cache["pos"] + 1
    return constrain(logits[:, 0], "batch", "vocab"), new_cache


def prefill(params, cfg: ModelConfig, batch: dict, *, chunk=1024):
    """Full-sequence forward for serving; returns last-position logits.

    (Cache fill for mid-sequence restart is handled by replaying decode or by
    examples/serve_lm.py's short-prompt path; the dry-run 'prefill' cells
    lower this function.)
    """
    from .transformer import forward
    logits = forward(params, cfg, batch, remat=False, chunk=chunk)
    return logits[:, -1]
