"""Theorems 1-4: the inverse-linear computation<->communication trade-off on
all four random graph models (measured coded gain vs r)."""
import time

import numpy as np

from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.coded_shuffle import coded_load
from repro.core.uncoded_shuffle import uncoded_load

SAMPLES = 3


def _measure(report, tag, graphs, alloc):
    lu, lc, t0 = [], [], time.perf_counter()
    for g in graphs:
        lu.append(uncoded_load(g.adj, alloc))
        lc.append(coded_load(g.adj, alloc))
    us = (time.perf_counter() - t0) / len(graphs) * 1e6
    gain = np.mean(lu) / np.mean(lc) if np.mean(lc) else float("nan")
    report(tag, us, f"uncoded={np.mean(lu):.4f} coded={np.mean(lc):.4f} "
           f"gain={gain:.2f}")
    return gain


def run(report):
    K = 6
    out = {}
    for r in (2, 3):
        # ER (Theorem 1)
        n = divisible_n(240, K, r)
        alloc = er_allocation(n, K, r)
        gs = [gm.erdos_renyi(n, 0.15, seed=s) for s in range(SAMPLES)]
        out[f"er_r{r}"] = _measure(report, f"thm1_er_r{r}", gs, alloc)
        # RB (Theorem 2) - balanced clusters, Appendix-A allocation.
        n1 = n2 = divisible_n(120, K // 2, min(r, K // 2))
        ab = bipartite_allocation(n1, n2, K, r)
        gs = [gm.random_bipartite(n1, n2, 0.2, seed=s) for s in range(SAMPLES)]
        out[f"rb_r{r}"] = _measure(report, f"thm2_rb_r{r}", gs, ab)
        # SBM (Theorem 3) - union ER allocation (interleaved batches).
        nn = divisible_n(240, K, r)
        sa = er_allocation(nn, K, r, interleave=True)
        gs = [gm.stochastic_block(nn // 2, nn // 2, 0.25, 0.08, seed=s)
              for s in range(SAMPLES)]
        out[f"sbm_r{r}"] = _measure(report, f"thm3_sbm_r{r}", gs, sa)
        # PL (Theorem 4) - gamma > 2.
        ga = er_allocation(nn, K, r, interleave=True)
        gs = [gm.power_law(nn, 2.5, seed=s) for s in range(SAMPLES)]
        out[f"pl_r{r}"] = _measure(report, f"thm4_pl_r{r}", gs, ga)
    return out
