"""Validate the trip-aware HLO analyzer against unrolled references."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_match_unrolled():
    D, T = 256, 6
    xs = jax.ShapeDtypeStruct((32, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, D, D), jnp.float32)

    def scanned(x, w):
        def body(x, wi):
            return jnp.dot(x, wi), None
        return jax.lax.scan(body, x, w)[0]

    def unrolled(x, w):
        for i in range(T):
            x = jnp.dot(x, w[i])
        return x

    c_scan = analyze(_compile(scanned, xs, ws).as_text())
    c_unr = analyze(_compile(unrolled, xs, ws).as_text())
    want = 2 * 32 * D * D * T
    assert c_scan.flops == want
    assert c_unr.flops == want


def test_nested_scan_multiplier():
    D, T1, T2 = 128, 3, 5
    xs = jax.ShapeDtypeStruct((8, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def nested(x, w):
        def outer(x, _):
            def inner(x, _):
                return jnp.dot(x, w), None
            return jax.lax.scan(inner, x, None, length=T2)[0], None
        return jax.lax.scan(outer, x, None, length=T1)[0]

    cost = analyze(_compile(nested, xs, ws).as_text())
    assert cost.flops == 2 * 8 * D * D * T1 * T2


def test_xla_cost_analysis_undercounts_but_we_dont():
    """Documents the very bug this module exists for."""
    D, T = 256, 8
    xs = jax.ShapeDtypeStruct((16, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((T, D, D), jnp.float32)

    def scanned(x, w):
        def body(x, wi):
            return jnp.dot(x, wi), None
        return jax.lax.scan(body, x, w)[0]

    compiled = _compile(scanned, xs, ws)
    ca = compiled.cost_analysis()
    if isinstance(ca, list):              # jax 0.4.x: one dict per computation
        ca = ca[0]
    xla_flops = ca["flops"]
    ours = analyze(compiled.as_text()).flops
    want = 2 * 16 * D * D * T
    assert xla_flops < want / 2          # XLA counts the body once
    assert ours == want


def test_collectives_inside_scan_are_trip_multiplied():
    import os
    T, D = 4, 64
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >1 device (dryrun covers the multi-device path)")


def test_bytes_scale_with_trip_count():
    D = 128
    xs = jax.ShapeDtypeStruct((8, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def make(T):
        def f(x, w):
            def body(x, _):
                return jnp.dot(x, w), None
            return jax.lax.scan(body, x, None, length=T)[0]
        return f

    b2 = analyze(_compile(make(2), xs, ws).as_text()).bytes_accessed
    b8 = analyze(_compile(make(8), xs, ws).as_text()).bytes_accessed
    # Per-trip traffic is 4x; entry-computation overhead (copies of the
    # loop-invariant weights etc.) dilutes the ratio at toy sizes.
    per_trip = (b8 - b2) / 6
    assert per_trip == pytest.approx(73_728, rel=0.35)  # dot in/out bytes


def test_parse_recovers_computations():
    D = 32
    xs = jax.ShapeDtypeStruct((4, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((D, D), jnp.float32)
    txt = _compile(lambda x, w: jnp.dot(x, w), xs, ws).as_text()
    comps = parse_hlo(txt)
    assert any(c.is_entry for c in comps.values())
    assert any(i.op == "dot" for c in comps.values() for i in c.instrs)
