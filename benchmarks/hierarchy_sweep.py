"""Topology-aware shuffle: SBM blocks aligned vs misaligned with racks.

The hierarchical scheme (`compile_hierarchical`) codes across racks and
exchanges plainly within them, so the load that matters on a real fabric is
the *inter-rack* bits. This sweep builds the paper's two-block SBM on a
R x S rack topology with a rack-spanning allocation - each block's batches
live on one server of each of the block's two home racks (so every
within-block value has an in-rack copy at its home reducers, and
cross-block values code across racks at r_rack = 2) - and compares:

  * aligned    - Reduce ownership block-local (each block reduced inside
                 its home racks): within-block traffic never leaves a rack,
                 only the sparse cross-block edges cross, coded.
  * misaligned - same Map structure, Reduce ownership round-robin over all
                 K servers: within-block deliveries land in far racks and
                 the inter-rack load balloons.

Both are measured against what the *flat* K-server schedule costs on the
same fabric (`empirical_loads(plan, alloc, topology=)`), per level. The
aligned hierarchical inter-rack bits beating the flat scheme is the
ROADMAP's hierarchical-coding acceptance and is asserted here (the CI
benchmark gate runs this module via ``run.py --smoke``).

Pure NumPy end to end - plans and loads only, no devices.
"""
import time

import numpy as np

from repro import graphs
from repro.core.allocation import Allocation
from repro.core.bitcodec import T_BITS
from repro.core.loads import empirical_loads
from repro.core.shuffle_plan import compile_hierarchical, compile_plan_csr
from repro.launch.mesh import Topology


def rack_spanning_allocation(n: int, topology: Topology, *,
                             aligned: bool) -> Allocation:
    """Two-block allocation whose Map structure spans each block's home
    racks one-server-per-rack.

    Block b owns racks [b * R/2, (b+1) * R/2); its vertices split into S
    batches, batch s mapped at server s of *every* home rack (r = R/2
    replicas, one per rack - so the rack-level subset has size R/2 and the
    inter-rack plan codes at r_rack = R/2). `aligned=True` reduces each
    block inside its home racks; `aligned=False` spreads Reduce ownership
    round-robin over all K servers.
    """
    R, S = topology.racks, topology.servers_per_rack
    if R % 2 or n % (2 * S):
        raise ValueError(f"need even racks and 2*S | n, got R={R}, S={S}, "
                         f"n={n}")
    K, half, r = topology.K, n // 2, R // 2
    subsets, batch_of = [], np.empty(n, dtype=np.int64)
    for b in range(2):                       # block -> home racks
        home = range(b * r, (b + 1) * r)
        for s in range(S):
            subsets.append(tuple(rho * S + s for rho in home))
            vs = np.arange(b * half + s, (b + 1) * half, S)
            batch_of[vs] = len(subsets) - 1
    map_sets = np.zeros((K, n), dtype=bool)
    for bi, T in enumerate(subsets):
        for k in T:
            map_sets[k, batch_of == bi] = True
    if aligned:                              # block-local Reduce ownership
        owners = np.concatenate([
            np.arange(half) % (r * S) + b * r * S for b in range(2)])
    else:                                    # spread over the whole cluster
        owners = np.arange(n) % K
    return Allocation(n=n, K=K, r=r, subsets=tuple(subsets),
                      batch_of=batch_of, map_sets=map_sets,
                      reduce_owner=owners.astype(np.int64))


def _measure(g, alloc, topology):
    """(flat inter-rack bits, hier inter/intra bits, hier compile seconds)."""
    flat = compile_plan_csr(g.csr, alloc, validate=False)
    on_fabric = empirical_loads(flat, alloc, topology=topology)
    t = time.perf_counter()
    hplan = compile_hierarchical(g.csr, alloc, topology)
    dt = time.perf_counter() - t
    split = empirical_loads(hplan, alloc)
    return on_fabric, split, hplan, dt


def run(report, smoke=False):
    R, S = 4, 2
    topo = Topology(R, S)
    n = 160
    g = graphs.stochastic_block(n // 2, n // 2, 0.4, 0.05, seed=7)
    rows = {}
    best_dt = None
    for aligned in (True, False):
        alloc = rack_spanning_allocation(n, topo, aligned=aligned)
        flat_on_fabric, split, hplan, dt = _measure(g, alloc, topo)
        name = "aligned" if aligned else "misaligned"
        rows[name] = {
            "flat_inter": int(flat_on_fabric["inter_rack_bits"]),
            "hier_inter": int(split["inter_rack_bits"]),
            "hier_intra": int(split["intra_rack_bits"]),
            "r_rack": hplan.rack_alloc.r,
        }
        if aligned:
            # Max-of-3 compile wall-clock: the CI-gated record.
            for _ in range(2):
                dt = max(dt, _measure(g, alloc, topo)[3])
            best_dt = dt
            # Acceptance: the rack-aligned SBM's hierarchical inter-rack
            # bits beat the flat schedule on the same fabric, by a margin.
            flat_b, hier_b = rows[name]["flat_inter"], rows[name]["hier_inter"]
            if not hier_b < flat_b:
                raise RuntimeError(
                    f"hierarchical inter-rack bits {hier_b} do not beat the "
                    f"flat scheme's {flat_b} on the rack-aligned SBM")
        denom = n * n * T_BITS
        report(f"hierarchy_sbm_{name}_n{n}", 0.0,
               f"flat_inter={rows[name]['flat_inter']} "
               f"hier_inter={rows[name]['hier_inter']} "
               f"hier_intra={rows[name]['hier_intra']} "
               f"inter_load={rows[name]['hier_inter'] / denom:.4f} "
               f"win={rows[name]['flat_inter'] / max(rows[name]['hier_inter'], 1):.2f}x")
    report(f"scale_hierarchy_sbm_n{n}", best_dt * 1e6,
           f"R={R} S={S} r_rack={rows['aligned']['r_rack']} "
           f"aligned_inter={rows['aligned']['hier_inter']} "
           f"flat_inter={rows['aligned']['flat_inter']} "
           f"misaligned_inter={rows['misaligned']['hier_inter']}")
    return rows
