"""Graph representation (CSR-primary) plus the legacy dense samplers.

CSR-primary contract
--------------------
`Graph` stores one of two representations of the same undirected simple
graph and derives the other lazily:

  * **CSR-native** (`Graph.from_csr` / `Graph.from_edges`, what the
    `repro.graphs` samplers and loaders produce): only `(indptr, indices)`
    live in memory - O(edges). This is the production representation; the
    whole sparse pipeline (Map -> compiled Shuffle -> segment Reduce, see
    `engine.py`) consumes nothing else, so graphs of n >= 1e5 run end to
    end without any [n, n] buffer ever existing.
  * **dense** (`Graph(adj, model, params)`, what the legacy samplers below
    return): the [n, n] boolean adjacency the paper-literal validation
    oracle and the blocked-dense TPU kernels consume. The CSR view is
    derived (and cached) on first use.

Dense materialization is *guarded*: accessing `adj` / `weights()` /
`to_dense()` on a CSR-native graph raises above `dense_limit` vertices
(default `DENSE_LIMIT`), so a stray dense touch on a large graph is a loud
error instead of a silent 10+ GB allocation. Below the guard the dense view
is materialized lazily - small-n A/B tests rely on that to compare the
sparse path against the dense oracle.

Bitwise per-path oracle rule: the canonical CSR entry order (row major,
ascending column - exactly `np.nonzero(adj)` order) is the reduction order
of the sparse path, so every distributed sparse run is *bitwise* equal to
the sparse single-machine oracle, and every dense run to the dense oracle;
across paths only float sums (pagerank) may differ, by reduction order
within ulp (see `algorithms.py`).

Samplers: the dense O(n^2) samplers below are the legacy/validation
reference. Their O(edges) streaming counterparts - statistically
equivalent, CSR-native, usable to n ~ 3e5+ - live in `repro.graphs`.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

# Vertices above which materializing any [n, n] view of a CSR-native graph
# raises (20_000^2 bools = 400 MB; the sparse path never needs it).
DENSE_LIMIT = 20_000


@dataclasses.dataclass(frozen=True)
class CSR:
    """Compressed-sparse-row view of a symmetric adjacency.

    One entry per *directed* edge (i, j), in `np.nonzero(adj)` order: row
    major, ascending column within each row. That canonical entry order is
    the bitwise contract of the sparse path - every segment reduction
    (single-machine oracle or distributed engine) accumulates each row's
    values in exactly this order.
    """

    indptr: np.ndarray       # [n+1] int64 row offsets
    indices: np.ndarray      # [nnz] int32 column (source vertex j) per entry
    rows: np.ndarray         # [nnz] int32 row (destination vertex i) per entry

    @property
    def n(self) -> int:
        return self.indptr.size - 1

    @property
    def nnz(self) -> int:
        return int(self.indices.size)

    def apply_delta(self, delta) -> "CSR":
        """Mutated CSR after an `EdgeDelta` batch, in O(nnz + delta).

        Both orientations of every inserted (deleted) undirected edge are
        spliced into (dropped from) the canonical entry stream by a sorted
        merge - untouched rows are copied, never re-sorted, so the result
        is bitwise identical to `csr_from_undirected` on the mutated edge
        set. Raises `ValueError` if a deleted edge is absent or an
        inserted edge already present.
        """
        del_pos, ins_pos, ins_rows, ins_cols = csr_delta_entries(self, delta)
        new_old, new_ins, nnz2 = merge_maps(self.nnz, del_pos, ins_pos)
        tgt = new_old.copy()
        tgt[del_pos] = nnz2                  # deleted entries -> trash slot
        indices2 = np.empty(nnz2 + 1, dtype=np.int32)
        indices2[tgt] = self.indices
        indices2[new_ins] = ins_cols
        indices2 = indices2[:nnz2]
        rows2 = np.empty(nnz2 + 1, dtype=np.int32)
        rows2[tgt] = self.rows
        rows2[new_ins] = ins_rows
        rows2 = rows2[:nnz2]
        counts = np.bincount(rows2, minlength=self.n)
        indptr2 = np.zeros(self.n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr2[1:])
        return CSR(indptr2, indices2, rows2)


def merge_maps(size: int, del_pos: np.ndarray, ins_pos: np.ndarray):
    """Index bookkeeping for one sorted-merge splice.

    Given a length-`size` sorted sequence, sorted positions `del_pos` of
    elements to drop and sorted insertion points `ins_pos` (searchsorted
    convention: an element with point p lands before old element p; ties
    keep their given order), returns ``(new_old, new_ins, new_size)``:
    `new_old[a]` is the new index of old element a (meaningful only for
    survivors - callers scatter deletions to a trash slot, see
    `CSR.apply_delta`), `new_ins[t]` the new index of inserted element t.
    O(size + delta), no sorting: the old->new offset changes only at delta
    positions, so it is one difference-array cumsum.
    """
    diff = np.zeros(size + 2, dtype=np.int32)   # |offset| <= |delta|
    np.add.at(diff, ins_pos, 1)            # +1 from each insert point on
    np.add.at(diff, del_pos + 1, -1)       # -1 after each deleted element
    offset = np.cumsum(diff[:size + 1], dtype=np.int32)
    new_old = np.arange(size, dtype=np.int64) + offset[:size]
    new_ins = (ins_pos + np.arange(ins_pos.size, dtype=np.int64)
               - np.searchsorted(del_pos, ins_pos, side="left"))
    return new_old, new_ins, size - del_pos.size + ins_pos.size


def csr_delta_entries(csr: CSR, delta):
    """Locate an `EdgeDelta`'s directed entries in `csr`'s canonical order.

    Returns ``(del_pos, ins_pos, ins_rows, ins_cols)``: sorted entry
    positions of the 2 x num_delete deleted directed entries, sorted
    insertion points of the 2 x num_insert new ones, and the new entries'
    (row, col) in insertion-point order. Raises `ValueError` on a deleted
    edge that is absent or an inserted edge already present.

    Both the result (per delta) and the entry-key array (per CSR) are
    cached: `CSR.apply_delta` and `ShufflePlan.apply_delta` locate the
    same delta in the same CSR, and the second call must not redo the
    O(nnz log delta) work.
    """
    n = csr.n
    if delta.n != n:
        raise ValueError(
            f"delta is bound to n={delta.n} but the graph has n={n}")
    cached = csr.__dict__.get("_delta_entries")
    if cached is not None and cached[0] is delta:
        return cached[1]
    key = csr.__dict__.get("_entry_key")
    if key is None:
        key = csr.rows.astype(np.int64) * n + csr.indices
        csr.__dict__["_entry_key"] = key
    out = []
    for what, pairs, must_exist in (("delete", delta.delete, True),
                                    ("insert", delta.insert, False)):
        if pairs.shape[0] == 0:
            out.append((np.zeros(0, dtype=np.int64),) * 3)
            continue
        dk = np.concatenate([pairs[:, 0] * n + pairs[:, 1],
                             pairs[:, 1] * n + pairs[:, 0]])
        dk.sort()
        pos = np.searchsorted(key, dk)
        present = (pos < key.size) & (key[np.minimum(pos, key.size - 1)] == dk)
        offend = ~present if must_exist else present
        if offend.any():
            k = int(dk[np.flatnonzero(offend)[0]])
            u, v = min(k // n, k % n), max(k // n, k % n)
            raise ValueError(
                f"{what} edge ({u}, {v}) is "
                + ("not in the graph" if must_exist
                   else "already in the graph"))
        out.append((pos, dk // n, dk % n))
    (del_pos, _, _), (ins_pos, ins_r, ins_c) = out
    res = (del_pos, ins_pos,
           ins_r.astype(np.int32), ins_c.astype(np.int32))
    csr.__dict__["_delta_entries"] = (delta, res)
    return res


def csr_from_undirected(u: np.ndarray, v: np.ndarray, n: int) -> CSR:
    """Symmetric CSR from undirected edge endpoints (u[e], v[e]), u != v.

    Pairs must be unique as undirected edges (dedup first - see
    `repro.graphs.io.normalize_edges`); both orientations are emitted and
    sorted into the canonical entry order. O(edges log edges).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    rows = np.concatenate([u, v])
    cols = np.concatenate([v, u])
    order = np.lexsort((cols, rows))
    rows, cols = rows[order], cols[order]
    counts = np.bincount(rows, minlength=n)
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return CSR(indptr, cols.astype(np.int32), rows.astype(np.int32))


class Graph:
    """An undirected graph realization plus the model metadata.

    Construct densely (`Graph(adj, model, params)`) or CSR-natively
    (`Graph.from_csr` / `Graph.from_edges`); see the module docstring for
    the CSR-primary contract and the dense-materialization guard.
    """

    def __init__(self, adj: np.ndarray | None = None, model: str = "",
                 params: dict | None = None, *, csr: CSR | None = None,
                 dense_limit: int = DENSE_LIMIT):
        if (adj is None) == (csr is None):
            raise ValueError("construct from exactly one of adj= or csr=")
        self.model = model
        self.params = {} if params is None else params
        self.dense_limit = int(dense_limit)
        self._dense_built = adj is not None
        if adj is not None:
            adj = np.asarray(adj)
            self._adj = adj if adj.dtype == bool else adj.astype(bool)
            self._n = int(adj.shape[0])
        else:
            self._adj = None
            self._n = csr.n
            self.__dict__["csr"] = csr      # pre-fill the cached_property

    # ---- constructors ----

    @classmethod
    def from_csr(cls, indptr: np.ndarray, indices: np.ndarray,
                 model: str = "", params: dict | None = None, *,
                 dense_limit: int = DENSE_LIMIT) -> "Graph":
        """CSR-native graph from (indptr, indices); indices must be sorted
        ascending within each row (the canonical entry order)."""
        indptr = np.asarray(indptr, dtype=np.int64)
        indices = np.asarray(indices, dtype=np.int32)
        n = indptr.size - 1
        rows = np.repeat(np.arange(n, dtype=np.int32), np.diff(indptr))
        return cls(model=model, params=params,
                   csr=CSR(indptr, indices, rows), dense_limit=dense_limit)

    @classmethod
    def from_edges(cls, u: np.ndarray, v: np.ndarray, n: int,
                   model: str = "", params: dict | None = None, *,
                   dense_limit: int = DENSE_LIMIT) -> "Graph":
        """CSR-native graph from deduped undirected edge endpoint arrays."""
        return cls(model=model, params=params,
                   csr=csr_from_undirected(u, v, n), dense_limit=dense_limit)

    def __repr__(self) -> str:
        rep = "csr" if self._adj is None else "dense"
        return (f"Graph(model={self.model!r}, n={self._n}, "
                f"edges={self.num_edges}, {rep})")

    # ---- representations ----

    @property
    def n(self) -> int:
        return self._n

    @property
    def is_csr_native(self) -> bool:
        return self._adj is None

    @functools.cached_property
    def csr(self) -> CSR:
        """Cached CSR view (derived from `adj` for dense-built graphs)."""
        rows, cols = np.nonzero(self._adj)
        counts = np.bincount(rows, minlength=self._n)
        indptr = np.zeros(self._n + 1, dtype=np.int64)
        np.cumsum(counts, out=indptr[1:])
        return CSR(indptr, cols.astype(np.int32), rows.astype(np.int32))

    def _check_dense(self, what: str, limit: int | None = None) -> None:
        limit = self.dense_limit if limit is None else limit
        if self._n > limit:
            raise ValueError(
                f"{what} would materialize an [{self._n}, {self._n}] dense "
                f"buffer (> dense_limit={limit}); the sparse path never "
                f"needs it - stay on path='sparse', or force with "
                f"to_dense(limit=...) for a validation-scale graph")

    @property
    def adj(self) -> np.ndarray:
        """[n, n] bool adjacency; lazily materialized (and guarded) for
        CSR-native graphs - only the dense validation path touches it."""
        return self.to_dense()

    def to_dense(self, limit: int | None = None) -> np.ndarray:
        """Dense adjacency; `limit` overrides the construction-time
        `dense_limit` guard for one deliberate materialization."""
        if self._adj is None:
            self._check_dense("dense adjacency", limit)
            csr = self.csr
            a = np.zeros((self._n, self._n), dtype=bool)
            a[csr.rows, csr.indices] = True
            self._adj = a
        return self._adj

    # ---- derived quantities (representation-agnostic, cached) ----

    def degrees(self) -> np.ndarray:
        """[n] int64 vertex degrees, from whichever representation already
        exists (a dense-built graph is NOT forced through CSR construction
        just to count its edges)."""
        d = self.__dict__.get("_degrees")
        if d is None:
            if "csr" in self.__dict__ or self._adj is None:
                d = np.diff(self.csr.indptr)
            else:
                d = self._adj.sum(axis=1, dtype=np.int64)
            self.__dict__["_degrees"] = d
        return d

    @property
    def num_edges(self) -> int:
        """Undirected edge count, via `degrees()` (no CSR side effects on
        the dense path)."""
        return int(self.degrees().sum()) // 2

    @property
    def density(self) -> float:
        """Directed-entry density nnz / n^2 == `adj.mean()` of the dense
        view (the empirical `p` the benchmarks report)."""
        if self._n == 0:
            return 0.0
        return float(self.degrees().sum()) / (self._n * self._n)

    def edge_weights(self, low: float = 0.5, high: float = 1.5) -> np.ndarray:
        """[nnz] float64 positive edge weights in CSR entry order (for SSSP).

        One uniform draw per *undirected* edge, in canonical upper-triangle
        CSR order, shared bit-for-bit by both directed entries - so
        ``weights()[i, j] == edge_weights()[e]`` exactly for the CSR entry
        e = (i, j), and the sparse SSSP path is bitwise consistent with the
        dense oracle. O(edges) time and memory; cached per (low, high).
        """
        key = ("_edge_weights", float(low), float(high))
        w = self.__dict__.get(key)
        if w is None:
            csr = self.csr
            i64 = csr.rows.astype(np.int64)
            j64 = csr.indices.astype(np.int64)
            ukey = np.minimum(i64, j64) * self._n + np.maximum(i64, j64)
            upper = i64 < j64         # upper-tri entries: ukey already sorted
            rng = np.random.default_rng(0)
            w_upper = rng.uniform(low, high, size=int(np.count_nonzero(upper)))
            w = w_upper[np.searchsorted(ukey[upper], ukey)]
            self.__dict__[key] = w
        return w

    def weights(self, low: float = 0.5, high: float = 1.5) -> np.ndarray:
        """Dense [n, n] scatter of `edge_weights()`; +inf on non-edges.

        Cached per (low, high) and guarded like `adj` on CSR-native graphs
        (even after a deliberate `to_dense(limit=...)` override - this
        float64 view is 8x the bool adjacency); dense-*built* graphs
        already opted into [n, n] views at construction, so the guard does
        not block the legacy oracle path there. Only the dense reference
        path calls this - the sparse path consumes `edge_weights()`.
        """
        key = ("_weights", float(low), float(high))
        w = self.__dict__.get(key)
        if w is None:
            if not self._dense_built:
                self._check_dense("weights()")
            w = np.full((self._n, self._n), np.inf)
            w[self.csr.rows, self.csr.indices] = self.edge_weights(low, high)
            self.__dict__[key] = w
        return w

    def padded(self, n2: int) -> "Graph":
        """This graph plus `n2 - n` virtual isolated vertices (CSR-native).

        Lets an arbitrary real-graph n meet the allocation's divisibility
        requirement (`allocation.divisible_n`): isolated vertices have no
        edges, hence no Map values, no Shuffle traffic, and no effect on
        any other vertex's reduction order.
        """
        if n2 < self._n:
            raise ValueError(f"cannot pad n={self._n} down to {n2}")
        if n2 == self._n:
            return self
        csr = self.csr
        indptr = np.concatenate([
            csr.indptr,
            np.full(n2 - self._n, csr.indptr[-1], dtype=np.int64)])
        params = dict(self.params)
        params["padded_from"] = self._n
        return Graph(model=self.model, params=params,
                     csr=CSR(indptr, csr.indices, csr.rows),
                     dense_limit=self.dense_limit)


def _symmetrize(upper: np.ndarray) -> np.ndarray:
    upper = np.triu(upper, 1)
    return upper | upper.T


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """ER(n, p): every edge present independently w.p. p."""
    rng = np.random.default_rng(seed)
    adj = _symmetrize(rng.random((n, n)) < p)
    return Graph(adj, "er", {"n": n, "p": p, "seed": seed})


def random_bipartite(n1: int, n2: int, q: float, seed: int = 0) -> Graph:
    """RB(n1, n2, q): only cross-cluster edges, each present w.p. q.

    Vertices [0, n1) form cluster 1 and [n1, n1+n2) cluster 2.
    """
    rng = np.random.default_rng(seed)
    n = n1 + n2
    adj = np.zeros((n, n), dtype=bool)
    cross = rng.random((n1, n2)) < q
    adj[:n1, n1:] = cross
    adj[n1:, :n1] = cross.T
    return Graph(adj, "rb", {"n1": n1, "n2": n2, "q": q, "seed": seed})


def stochastic_block(n1: int, n2: int, p: float, q: float, seed: int = 0) -> Graph:
    """SBM(n1, n2, p, q): intra-cluster w.p. p, cross-cluster w.p. q (q < p)."""
    rng = np.random.default_rng(seed)
    n = n1 + n2
    probs = np.full((n, n), q)
    probs[:n1, :n1] = p
    probs[n1:, n1:] = p
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "sbm", {"n1": n1, "n2": n2, "p": p, "q": q, "seed": seed})


def power_law(n: int, gamma: float, rho: float | None = None, seed: int = 0,
              d_min: float = 1.0) -> Graph:
    """PL(n, gamma, rho): expected degrees are iid power-law(gamma) samples and
    P[(i,j) in E] = min(1, rho * d_i * d_j) (Chung-Lu style, paper Appendix E).

    If rho is None it is set to 1 / vol so that expected degrees are honored.
    """
    rng = np.random.default_rng(seed)
    # Inverse-CDF sampling of a Pareto-like pmf P[d] ~ d^-gamma, d >= d_min.
    u = rng.random(n)
    degrees = d_min * (1.0 - u) ** (-1.0 / (gamma - 1.0))
    if rho is None:
        rho = 1.0 / degrees.sum()
    probs = np.minimum(1.0, rho * np.outer(degrees, degrees))
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "pl", {"n": n, "gamma": gamma, "rho": rho, "seed": seed})


def sample(model: str, seed: int = 0, **kw) -> Graph:
    return {
        "er": erdos_renyi,
        "rb": random_bipartite,
        "sbm": stochastic_block,
        "pl": power_law,
    }[model](seed=seed, **kw)
