"""Baseline uncoded Shuffle (paper §IV-A 'Uncoded Shuffle').

Every intermediate value v_{i,j} that Reducer-owner k needs but did not Map
locally is unicast by one designated Mapper of j. Achieves the expected load
L^UC = p (1 - r/K) under the ER allocation.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .allocation import Allocation
from .bitcodec import T_BITS


@dataclasses.dataclass
class ShuffleResult:
    """Delivered values per server plus exact load accounting."""

    delivered: dict[int, dict[tuple[int, int], float]]  # k -> {(i, j): v}
    bits_sent: int
    n: int

    @property
    def normalized_load(self) -> float:
        """Definition 2: total bits / (n^2 T)."""
        return self.bits_sent / (self.n * self.n * T_BITS)


def missing_pairs(adj: np.ndarray, alloc: Allocation, k: int) -> np.ndarray:
    """[(i, j)] rows that Reducer k needs and has not Mapped: i in R_k,
    (i, j) in E, j not in M_k."""
    rk = alloc.reduce_owner == k
    need = adj & rk[:, None] & ~alloc.map_sets[k][None, :]
    return np.argwhere(need)


def run_uncoded(adj: np.ndarray, values: np.ndarray, alloc: Allocation) -> ShuffleResult:
    """values: [n, n] float32 with V[i, j] = v_{i,j} (valid on edges)."""
    delivered: dict[int, dict[tuple[int, int], float]] = {k: {} for k in range(alloc.K)}
    bits = 0
    for k in range(alloc.K):
        pairs = missing_pairs(adj, alloc, k)
        for i, j in pairs:
            delivered[k][(int(i), int(j))] = float(values[i, j])
        bits += len(pairs) * T_BITS
    return ShuffleResult(delivered, bits, alloc.n)


def uncoded_load(adj: np.ndarray, alloc: Allocation) -> float:
    """Exact normalized uncoded load of a realization (no data movement)."""
    bits = sum(len(missing_pairs(adj, alloc, k)) for k in range(alloc.K)) * T_BITS
    return bits / (alloc.n * alloc.n * T_BITS)
