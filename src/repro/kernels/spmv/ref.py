"""Pure-jnp oracle for the blocked adjacency SpMV (PageRank Map+Reduce)."""
import jax.numpy as jnp


def spmv(adj: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """y = A @ x with A a dense {0,1} (or weighted) adjacency, fp32 accum.

    adj: [m, n] float32 (blocked-dense adjacency tile row)
    x:   [n] float32 (per-source Map values, e.g. rank/degree)
    ->   [m] float32 Reduce accumulations.
    """
    return jnp.dot(adj.astype(jnp.float32), x.astype(jnp.float32),
                   precision="highest")


def pagerank_step(adj: jnp.ndarray, rank: jnp.ndarray, damping: float = 0.15
                  ) -> jnp.ndarray:
    """One full PageRank iteration (paper Example 1) on dense adjacency."""
    deg = jnp.maximum(adj.sum(axis=0), 1.0)
    contrib = rank / deg
    acc = spmv(adj, contrib)
    return (1.0 - damping) * acc + damping / adj.shape[0]
