"""Render dryrun_results.json into the EXPERIMENTS.md roofline tables."""
import json
import sys


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def render(path="dryrun_results.json", mesh="single"):
    with open(path) as f:
        rows = json.load(f)
    out = []
    out.append("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
               "bottleneck | MODEL/HLO flops | roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "skip":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"skip: {r['reason']} | - | - |")
            continue
        if r["status"] == "fail":
            out.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                       f"FAIL: {r['error'][:60]} | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3g} | "
            f"{r['t_memory_s']:.3g} | {r['t_collective_s']:.3g} | "
            f"{r['bottleneck']} | {r['useful_flops_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3g} |")
    return "\n".join(out)


def render_memory(path="dryrun_results.json"):
    with open(path) as f:
        rows = json.load(f)
    out = ["| arch | shape | mesh | args/dev | temps/dev | fits 16GB HBM? |",
           "|---|---|---|---|---|---|"]
    for r in rows:
        if r["status"] != "ok":
            continue
        tot = r["arg_bytes"] + r["temp_bytes"]
        out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                   f"{fmt_bytes(r['arg_bytes'])} | {fmt_bytes(r['temp_bytes'])} | "
                   f"{'YES' if tot < 16e9 else 'NO (' + fmt_bytes(tot) + ')'} |")
    return "\n".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    if mesh == "memory":
        print(render_memory())
    else:
        print(render(mesh=mesh))
