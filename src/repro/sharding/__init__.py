from .rules import (LOGICAL_RULES, activation_sharding, constrain,  # noqa: F401
                    param_shardings, set_mesh)
