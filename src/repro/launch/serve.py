"""Serving driver: prefill a batch of prompts, then lockstep greedy decode."""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..configs.base import ModelConfig, ShapeSpec
from ..models import decode as dec
from ..models import transformer as tfm
from ..models.layers import init_params
from ..sharding import rules
from .mesh import make_local_mesh


def generate(cfg: ModelConfig, params, prompts: jnp.ndarray, max_new: int,
             *, mesh=None, greedy: bool = True, seed: int = 0):
    """prompts [B, P] int32 -> generated tokens [B, max_new].

    Prompt is fed token-by-token through the decode path (cache fill), then
    generation continues greedily - one jitted step function for both phases.
    """
    mesh = mesh or make_local_mesh()
    rules.set_mesh(mesh)
    try:
        B, P = prompts.shape
        total = P + max_new
        cache = dec.init_cache(cfg, ShapeSpec("serve", total, B, "decode"))
        step = jax.jit(lambda p, c, b: dec.decode_step(p, cfg, c, b),
                       donate_argnums=(1,))
        key = jax.random.PRNGKey(seed)
        out = []
        tok = prompts[:, :1]
        with mesh:
            for t in range(total - 1):
                logits, cache = step(params, cache, {"tokens": tok})
                if t + 1 < P:
                    tok = prompts[:, t + 1:t + 2]
                else:
                    if greedy:
                        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
                    else:
                        key, k2 = jax.random.split(key)
                        tok = jax.random.categorical(k2, logits)[:, None].astype(jnp.int32)
                    out.append(np.asarray(tok))
        return np.concatenate(out, axis=1)
    finally:
        rules.set_mesh(None)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    cfg = configs.get(args.arch).reduced()
    params = init_params(tfm.model_spec(cfg), jax.random.PRNGKey(0))
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0, cfg.vocab)
    toks = generate(cfg, params, prompts, args.max_new)
    print("generated:", toks)


if __name__ == "__main__":
    main()
