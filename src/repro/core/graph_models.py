"""Random graph samplers for the four models studied in the paper.

All samplers return a dense symmetric boolean adjacency matrix (no self loops),
which is the representation the validation-scale engine and the blocked-dense
TPU kernels consume (see DESIGN.md §7.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """An undirected graph realization plus the model metadata."""

    adj: np.ndarray          # [n, n] bool, symmetric, zero diagonal
    model: str               # 'er' | 'rb' | 'sbm' | 'pl'
    params: dict

    @property
    def n(self) -> int:
        return self.adj.shape[0]

    @property
    def num_edges(self) -> int:
        return int(self.adj.sum()) // 2

    def degrees(self) -> np.ndarray:
        return self.adj.sum(axis=1)

    def weights(self, rng: np.random.Generator | None = None,
                low: float = 0.5, high: float = 1.5) -> np.ndarray:
        """Symmetric positive edge weights (for SSSP); +inf on non-edges."""
        rng = rng or np.random.default_rng(0)
        w = rng.uniform(low, high, size=self.adj.shape)
        w = np.triu(w, 1)
        w = w + w.T
        return np.where(self.adj, w, np.inf)


def _symmetrize(upper: np.ndarray) -> np.ndarray:
    upper = np.triu(upper, 1)
    return upper | upper.T


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """ER(n, p): every edge present independently w.p. p."""
    rng = np.random.default_rng(seed)
    adj = _symmetrize(rng.random((n, n)) < p)
    return Graph(adj, "er", {"n": n, "p": p, "seed": seed})


def random_bipartite(n1: int, n2: int, q: float, seed: int = 0) -> Graph:
    """RB(n1, n2, q): only cross-cluster edges, each present w.p. q.

    Vertices [0, n1) form cluster 1 and [n1, n1+n2) cluster 2.
    """
    rng = np.random.default_rng(seed)
    n = n1 + n2
    adj = np.zeros((n, n), dtype=bool)
    cross = rng.random((n1, n2)) < q
    adj[:n1, n1:] = cross
    adj[n1:, :n1] = cross.T
    return Graph(adj, "rb", {"n1": n1, "n2": n2, "q": q, "seed": seed})


def stochastic_block(n1: int, n2: int, p: float, q: float, seed: int = 0) -> Graph:
    """SBM(n1, n2, p, q): intra-cluster w.p. p, cross-cluster w.p. q (q < p)."""
    rng = np.random.default_rng(seed)
    n = n1 + n2
    probs = np.full((n, n), q)
    probs[:n1, :n1] = p
    probs[n1:, n1:] = p
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "sbm", {"n1": n1, "n2": n2, "p": p, "q": q, "seed": seed})


def power_law(n: int, gamma: float, rho: float | None = None, seed: int = 0,
              d_min: float = 1.0) -> Graph:
    """PL(n, gamma, rho): expected degrees are iid power-law(gamma) samples and
    P[(i,j) in E] = min(1, rho * d_i * d_j) (Chung-Lu style, paper Appendix E).

    If rho is None it is set to 1 / vol so that expected degrees are honored.
    """
    rng = np.random.default_rng(seed)
    # Inverse-CDF sampling of a Pareto-like pmf P[d] ~ d^-gamma, d >= d_min.
    u = rng.random(n)
    degrees = d_min * (1.0 - u) ** (-1.0 / (gamma - 1.0))
    if rho is None:
        rho = 1.0 / degrees.sum()
    probs = np.minimum(1.0, rho * np.outer(degrees, degrees))
    adj = _symmetrize(rng.random((n, n)) < probs)
    return Graph(adj, "pl", {"n": n, "gamma": gamma, "rho": rho, "seed": seed})


def sample(model: str, seed: int = 0, **kw) -> Graph:
    return {
        "er": erdos_renyi,
        "rb": random_bipartite,
        "sbm": stochastic_block,
        "pl": power_law,
    }[model](seed=seed, **kw)
