"""Paper bridge (DESIGN.md §4): when does RB-coded token dispatch beat plain
all-to-all for MoE expert parallelism?

Token->expert dispatch is a bipartite shuffle: tokens on one side, experts on
the other, an edge where the router sends a token. Replicating token shards
r x across EP groups (Theorem-2 allocation) enables coded multicast of the
dispatched activations, cutting dispatch bytes ~1/r at the price of r x Map
(= router + pre-dispatch) compute. Model on v5e numbers:

  t_dispatch(r) = (T * topk * d * 2 bytes) / r / (chips * ici_bw)
  t_expert      = (3 * 2 * T * topk * d * d_ff) / (chips * peak)
  t_router(r)   = r * (2 * T * d * E) / (chips * peak)

Coding wins iff the saved dispatch time exceeds the added router/Map time -
i.e. only in the dispatch-bound regime (small d_ff_expert / high top-k).
"""
from repro.launch.mesh import ICI_BW, PEAK_FLOPS_BF16


def analyze(T, d, d_ff, E, topk, chips, r_values=(1, 2, 4)):
    rows = []
    for r in r_values:
        t_disp = T * topk * d * 2 / r / (chips * ICI_BW)
        t_expert = 3 * 2 * T * topk * d * d_ff / (chips * PEAK_FLOPS_BF16)
        t_router = r * 2 * T * d * E / (chips * PEAK_FLOPS_BF16)
        rows.append((r, t_disp, t_expert, t_router,
                     t_disp + t_expert + t_router))
    return rows


def run(report):
    cases = {
        # (tokens/step, d_model, d_ff_expert, E, topk)
        "llama4_moe": (1_048_576, 5120, 8192, 128, 1),
        "deepseek_moe": (1_048_576, 5120, 1536, 160, 6),
        "dispatch_bound_hypo": (1_048_576, 5120, 256, 256, 8),
    }
    for name, (T, d, dff, E, k) in cases.items():
        rows = analyze(T, d, dff, E, k, chips=256)
        base = rows[0][-1]
        best = min(rows, key=lambda x: x[-1])
        report(f"coded_dispatch_{name}", base * 1e6,
               f"best_r={best[0]} speedup={base / best[-1]:.3f} "
               f"t_disp_r1={rows[0][1] * 1e3:.2f}ms t_expert={rows[0][2] * 1e3:.2f}ms")
    # Conclusion mirrors DESIGN.md §4: for the two assigned MoE archs the
    # expert FLOPs dominate dispatch, so r=1 is optimal; coding only pays in
    # contrived dispatch-bound settings.
