"""Recovery cost: coded plan repair vs the legacy uncoded fallback.

For each failure-set size m the bench kills m servers mid-run and measures
what the rest of the job pays, both ways:

  * **coded repair** (the PR 7 default): `ShufflePlan.repair` hands the dead
    senders' columns to healthy (r+1)-group members, so post-failure
    iterations keep the paper's inverse-linear coded gain and only pay the
    stand-ins' unicast hand-over overhead;
  * **uncoded fallback** (the legacy behavior, `mode="uncoded"`): every
    post-failure iteration ships the degraded missing set as unicast.

Reported per m: first post-failure Shuffle bits (= `recovery_bits`), total
job bits, wall-clock, and the repair-vs-fresh-recompile plan times. The
sweep asserts the coded path's bits stay strictly below the fallback's for
every m < r, and that both end states stay bitwise equal to the
single-machine oracle.

The smoke row is the CI-gated `scale_recovery_*` record in
`BENCH_scale.json` (`benchmarks/check_regression.py`).
"""
from __future__ import annotations

import pathlib
import sys

import numpy as np

try:
    from repro.core import algorithms as algo
except ImportError:
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]
    from repro.core import algorithms as algo

from repro import graphs, obs
from repro.core.allocation import divisible_n, er_allocation
from repro.core.faults import degrade_allocation, run_with_failure
from repro.core.shuffle_plan import compile_plan_csr


def run(report, smoke=False):
    n_req, K, r, p = (240, 6, 3, 0.15) if smoke else (1200, 10, 3, 0.04)
    iters, fail_at = (4, 1) if smoke else (10, 3)
    n = divisible_n(n_req, K, r)
    prog = algo.pagerank()
    g = graphs.erdos_renyi(n, p, seed=11)
    alloc = er_allocation(n, K, r)
    oracle = algo.reference_run(prog, g, iters, path="sparse")
    plan = compile_plan_csr(g.csr, alloc)
    rows = []
    for m in range(1, r):
        failed = tuple(range(m))

        with obs.stopwatch() as sw_c:
            res_c, st_c = run_with_failure(prog, g, alloc, iters, failed,
                                           fail_at_iter=fail_at)
        t_coded = sw_c.s
        with obs.stopwatch() as sw_u:
            res_u, st_u = run_with_failure(prog, g, alloc, iters, failed,
                                           fail_at_iter=fail_at,
                                           mode="uncoded")
        t_uncoded = sw_u.s
        assert np.array_equal(res_c.state, oracle), "coded failover != oracle"
        assert np.array_equal(res_u.state, oracle), "uncoded failover != oracle"
        assert st_c.recovery_bits < st_u.recovery_bits, \
            (m, st_c.recovery_bits, st_u.recovery_bits)
        assert res_c.shuffle_bits < res_u.shuffle_bits, \
            (m, res_c.shuffle_bits, res_u.shuffle_bits)

        # Plan surgery vs recompiling from scratch on the degraded alloc.
        with obs.stopwatch() as sw_rep:
            rep, degraded, rstats = plan.repair(g.csr, alloc, failed)
        t_repair = sw_rep.s
        with obs.stopwatch() as sw_fresh:
            compile_plan_csr(g.csr, degrade_allocation(alloc, failed)[0],
                             validate=False)
        t_fresh = sw_fresh.s

        gain = st_u.recovery_bits / st_c.recovery_bits
        report(f"recovery_f{m}", t_coded / iters * 1e6,
               f"recovery_bits coded={st_c.recovery_bits} "
               f"uncoded={st_u.recovery_bits} gain={gain:.2f}x "
               f"handover={rstats.handover_bits} "
               f"total coded={res_c.shuffle_bits} "
               f"uncoded={res_u.shuffle_bits} "
               f"repair_ms={t_repair * 1e3:.1f} "
               f"recompile_ms={t_fresh * 1e3:.1f}")
        rows.append({"failed": m, "coded_bits": res_c.shuffle_bits,
                     "uncoded_bits": res_u.shuffle_bits,
                     "recovery_coded": st_c.recovery_bits,
                     "recovery_uncoded": st_u.recovery_bits,
                     "s_coded": t_coded, "s_uncoded": t_uncoded,
                     "s_repair": t_repair, "s_recompile": t_fresh})
    report(f"scale_recovery_coded_n{n}",
           rows[0]["s_coded"] / iters * 1e6,
           f"K={K} r={r} |failed|=1 recovery gain="
           f"{rows[0]['recovery_uncoded'] / rows[0]['recovery_coded']:.2f}x "
           f"coded-repair failover (PR 7)")
    return {"n": n, "K": K, "r": r, "rows": rows}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(_report, smoke=smoke)
