"""Chunked Mamba2 SSD Pallas TPU kernel.

State-space duality: within a chunk of Q tokens the recurrence is a small
causal "attention" M = (C B^T) ∘ decay (an MXU matmul per tile); across chunks
only an [N, P] state is carried. The kernel computes, per (head, chunk):

  y_intra[t] = sum_{s<=t} (C_t.B_s) dt_s e^{cum_t-cum_s} x_s
  S          = sum_s e^{cum_Q-cum_s} dt_s B_s x_s^T     (chunk-local end state)
  G          = e^{cum_Q}                                (chunk decay)
  Cexp[t]    = C_t e^{cum_t}                            (inter-chunk readout)

ops.py stitches chunks with an associative scan over (G, S) - the only
sequential dependence, O(L/Q) instead of O(L).
VMEM working set per grid step: Q*(P+2N) inputs + Q^2 scores + N*P state;
Q=128/256 with P,N<=128 keeps it well under 16 MB at fp32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(x_ref, dt_ref, dta_ref, b_ref, c_ref,
                      y_ref, s_ref, g_ref, cexp_ref):
    x = x_ref[0, 0].astype(jnp.float32)        # [Q, P]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [Q]
    dta = dta_ref[0, 0].astype(jnp.float32)    # [Q]  (= dt * A, <= 0)
    b = b_ref[0, 0].astype(jnp.float32)        # [Q, N]
    c = c_ref[0, 0].astype(jnp.float32)        # [Q, N]
    q = x.shape[0]

    cum = jnp.cumsum(dta)                      # [Q], inclusive
    # Intra-chunk causal scores: M[t, s] = (C_t.B_s) dt_s e^{cum_t - cum_s}.
    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    tri = s_idx <= t_idx
    # Mask inside the exp (upper triangle would overflow / break backward).
    decay = jnp.exp(jnp.where(tri, cum[:, None] - cum[None, :], -1e30))
    m = scores * decay * dt[None, :]
    y_ref[0, 0] = jnp.dot(m, x, preferred_element_type=jnp.float32)

    # Chunk-local end state and decay.
    w = jnp.exp(cum[-1] - cum) * dt            # [Q]
    s_ref[0, 0] = jnp.dot((b * w[:, None]).T, x,
                          preferred_element_type=jnp.float32)
    g_ref[0, 0] = jnp.exp(cum[-1])
    cexp_ref[0, 0] = c * jnp.exp(cum)[:, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(x, dt, dta, b, c, *, interpret: bool = True):
    """x [G, Ch, Q, P]; dt/dta [G, Ch, Q]; b/c [G, Ch, Q, N].

    -> y_intra [G, Ch, Q, P], S [G, Ch, N, P], Gdecay [G, Ch], Cexp [G, Ch, Q, N]
    """
    g, ch, q, p = x.shape
    n = b.shape[-1]
    grid = (g, ch)
    specs4 = lambda d3, d4: pl.BlockSpec((1, 1, d3, d4), lambda i, j: (i, j, 0, 0))
    spec3 = pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0))
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[specs4(q, p), spec3, spec3, specs4(q, n), specs4(q, n)],
        out_specs=(specs4(q, p), specs4(n, p),
                   pl.BlockSpec((1, 1), lambda i, j: (i, j)), specs4(q, n)),
        out_shape=(jax.ShapeDtypeStruct((g, ch, q, p), jnp.float32),
                   jax.ShapeDtypeStruct((g, ch, n, p), jnp.float32),
                   jax.ShapeDtypeStruct((g, ch), jnp.float32),
                   jax.ShapeDtypeStruct((g, ch, q, n), jnp.float32)),
        interpret=interpret,
    )(x, dt, dta, b, c)
