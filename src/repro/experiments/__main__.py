"""``python -m repro.experiments`` == the Table II harness CLI."""
from .table2 import main

raise SystemExit(main())
