"""Public chunked-SSD op: Pallas intra-chunk kernel + associative cross-chunk
state scan. Matches ref.ssd_scan to fp32 tolerance for any chunk size."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import ssd_scan_batched
from .ssd_scan import ssd_chunk_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "use_kernel", "interpret"))
def ssd(x: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray, B: jnp.ndarray,
        C: jnp.ndarray, D: jnp.ndarray, h0: jnp.ndarray | None = None, *,
        chunk: int = 64, use_kernel: bool = True, interpret: bool = True
        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Batched SSD. x [G, L, P]; dt [G, L]; A [G]; B/C [G, L, N]; D [G].

    Returns (y [G, L, P], h_final [G, N, P]). L must be a multiple of chunk
    (the model pads); h0 seeds the scan (decode restarts).
    """
    if not use_kernel:
        return ssd_scan_batched(x, dt, A, B, C, D, h0)
    g, L, p = x.shape
    n = B.shape[-1]
    assert L % chunk == 0, (L, chunk)
    ch = L // chunk
    xr = x.reshape(g, ch, chunk, p).astype(jnp.float32)
    dtr = dt.reshape(g, ch, chunk).astype(jnp.float32)
    dta = dtr * A[:, None, None].astype(jnp.float32)
    br = B.reshape(g, ch, chunk, n).astype(jnp.float32)
    cr = C.reshape(g, ch, chunk, n).astype(jnp.float32)

    y_intra, S, G, Cexp = ssd_chunk_pallas(xr, dtr, dta, br, cr,
                                           interpret=interpret)

    # Cross-chunk state: H_c = G_c H_{c-1} + S_c, associative in (G, S).
    def combine(a, b):
        ga, sa = a
        gb, sb = b
        return ga * gb, gb[..., None, None] * sa + sb

    Gs, Ss = jax.lax.associative_scan(combine, (G, S), axis=1)
    h0 = jnp.zeros((g, n, p), jnp.float32) if h0 is None else h0.astype(jnp.float32)
    # H_prev[c] = state entering chunk c.
    h_in = jnp.concatenate([h0[:, None], Gs[:, :-1, None, None] * h0[:, None]
                            + Ss[:, :-1]], axis=1)
    y_inter = jnp.einsum("gcqn,gcnp->gcqp", Cexp, h_in)
    y = (y_intra + y_inter).reshape(g, L, p) + D[:, None, None] * x
    h_final = Gs[:, -1, None, None] * h0 + Ss[:, -1]
    return y, h_final


def ssd_decode_step(x, dt, A, B, C, D, h):
    """Single-token decode: x [G, P], dt [G], B/C [G, N], h [G, N, P]."""
    a = jnp.exp(dt * A)[:, None, None]
    h = a * h + dt[:, None, None] * jnp.einsum("gn,gp->gnp", B, x)
    y = jnp.einsum("gn,gnp->gp", C, h) + D[:, None] * x
    return y, h
