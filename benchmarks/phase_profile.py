"""Phase-level profiler: span-timed Map/encode/exchange/decode/Reduce vs roofline.

The ROADMAP's kernel-campaign item asks for a profiler-instrumented
phase-timing microbenchmark connected to `launch/roofline.py`. This module
is it: it replays a coded PageRank session under an enabled `obs.Tracer`,
aggregates the per-phase spans the engine emits (`phase.map` /
`phase.encode` / `phase.exchange` / `phase.decode` / `phase.reduce`),
cross-checks that the summed exchange-span bits equal the run's
`shuffle_bits`, and judges each phase's measured seconds + payload bytes
against its bandwidth roof (`launch.roofline.phase_roofline`: HBM for the
streaming phases, ICI for the exchange) - printing a %-of-roofline figure
per phase. On CPU the fractions are methodology numbers (the roofs are the
TPU v5e constants); on hardware the same spans produce the real figure.

Outputs: per-phase report rows, the CI-gated ``scale_phase_profile_*``
record (untraced replay wall-clock, so the gate measures the engine, not
the tracer), and - via ``--trace PATH`` - a Chrome-trace JSON artifact
loadable in chrome://tracing or ui.perfetto.dev.
"""
import argparse
import pathlib
import sys

try:
    import repro  # noqa: F401  (run.py already put src/ on the path)
except ImportError:
    sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro import graphs, obs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.launch.roofline import phase_roofline

SMOKE = {"n": 360, "K": 4, "r": 2, "p": 0.05, "iters": 3}
FULL = {"n": 2048, "K": 10, "r": 3, "p": 0.01, "iters": 10}

PHASES = ("map", "encode", "exchange", "decode", "reduce")


def _phase_bytes_per_iter(plan, g) -> dict:
    """Payload-byte estimates of one iteration's phases (float32/uint32).

    Deliberately simple traffic models - each counts the arrays a phase
    streams, not cache behavior: Map reads the state and writes the [nnz]
    edge values; encode gathers the covered-pair words and builds + XORs
    the [C, r] slot words; the exchange moves the schedule's
    bits-on-the-wire (counted from the spans' exact `bits` attrs, so it is
    NOT estimated here); decode re-masks the slot words, shifts the pair
    segments back, and writes the delivery vector; Reduce gathers every
    CSR entry and writes the new state.
    """
    n, nnz = g.n, g.csr.nnz
    P = int(plan.pair_k.size)        # covered pairs
    C = int(plan.col_width.size)     # coded columns
    M = int(plan.all_k.size)         # delivered values
    r = plan.r
    return {
        "map": 4 * (n + nnz),
        "encode": 4 * (P + 2 * C * r + C),
        "exchange": None,            # exact, from the span bits
        "decode": 4 * (C * r + P * r + M),
        "reduce": 4 * (2 * nnz + n),
    }


def profile(smoke: bool = False, trace_path: str | None = None) -> dict:
    """Trace one coded PageRank session; return the per-phase profile."""
    cfg = SMOKE if smoke else FULL
    n = divisible_n(cfg["n"], cfg["K"], cfg["r"])
    iters = cfg["iters"]
    g = graphs.erdos_renyi(n, cfg["p"], seed=7)
    alloc = er_allocation(n, cfg["K"], cfg["r"])

    tracer = obs.Tracer(enabled=True)
    prev = obs.set_tracer(tracer)
    try:
        sess = engine.compile(algo.pagerank(), g, alloc, "coded",
                              path="sparse")
        res = sess.run(iters)
    finally:
        obs.set_tracer(prev)

    span_bits = sum(s.attrs["bits"] for s in tracer.find("phase.exchange"))
    if span_bits != res.shuffle_bits:
        raise AssertionError(
            f"span bits {span_bits} != run shuffle_bits {res.shuffle_bits}")

    est = _phase_bytes_per_iter(sess.plan, g)
    phases = {}
    for ph in PHASES:
        spans = tracer.find(f"phase.{ph}")
        secs = sum(s.duration_s for s in spans)
        byts = (span_bits / 8 if ph == "exchange"
                else est[ph] * len(spans))
        rl = phase_roofline(ph, secs, byts, chips=cfg["K"])
        phases[ph] = {"count": len(spans), "seconds": secs,
                      "bytes": byts, "roof": rl.roof,
                      "roofline_fraction": rl.fraction}

    if trace_path:
        tracer.dump_chrome_trace(trace_path)

    # The CI-gated wall-clock replays the session *untraced* so the
    # regression gate watches the engine, not the tracer.
    m = obs.measure(lambda: sess.run(iters), reps=3, warmup=0)
    return {"n": n, "K": cfg["K"], "r": cfg["r"], "iters": iters,
            "edges": g.num_edges, "shuffle_bits": res.shuffle_bits,
            "phases": phases, "untraced_s_per_iter": m.best_s / iters,
            "trace_path": trace_path}


def _fractions_str(phases: dict) -> str:
    return " ".join(
        f"{ph}:{100 * p['roofline_fraction']:.4f}%({p['roof']})"
        for ph, p in phases.items())


def run(report, smoke: bool = False, trace_path: str | None = None) -> dict:
    prof = profile(smoke=smoke, trace_path=trace_path)
    phases = prof["phases"]
    for ph, p in phases.items():
        report(f"phase_{ph}_n{prof['n']}",
               p["seconds"] / max(p["count"], 1) * 1e6,
               f"bytes_per_iter={p['bytes'] / max(p['count'], 1):.0f} "
               f"roof={p['roof']} "
               f"roofline={100 * p['roofline_fraction']:.4f}%")
    total = sum(p["seconds"] for p in phases.values())
    report(f"scale_phase_profile_n{prof['n']}",
           prof["untraced_s_per_iter"] * 1e6,
           f"iters={prof['iters']} edges={prof['edges']} "
           f"bits={prof['shuffle_bits']} phase_s={total:.4f} "
           f"roofline%=[{_fractions_str(phases)}] "
           "(span-attributed phase profile, PR 8)")
    return prof


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized problem (n~360, 3 iterations)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="write a Chrome-trace/perfetto JSON artifact")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    prof = run(lambda name, us, derived="": print(f"{name},{us:.1f},{derived}"),
               smoke=args.smoke, trace_path=args.trace)
    ws = max(len(p) for p in PHASES)
    print(f"\nper-phase roofline ({prof['iters']} iterations, "
          f"n={prof['n']}, K={prof['K']}, r={prof['r']}):")
    for ph, p in prof["phases"].items():
        print(f"  {ph:<{ws}}  {p['seconds'] * 1e3:8.2f} ms  "
              f"{p['bytes'] / 1e6:9.3f} MB  vs {p['roof'].upper()} roof: "
              f"{100 * p['roofline_fraction']:.4f}% of roofline")
    if args.trace:
        print(f"\ntrace written to {args.trace} "
              "(load in chrome://tracing or ui.perfetto.dev)")


if __name__ == "__main__":
    main()
