"""CSR-native plan compilation + the dense-free end-to-end path.

Contract under test (see shuffle_plan.py / graph_models.py docstrings):
  * `compile_plan_csr` is schedule-identical - every plan array bitwise
    equal, same bits-on-the-wire - to the adjacency-driven `compile_plan`,
    across all four graph models and both schedule variants;
  * the engine on a CSR-native graph runs entirely adjacency-free: coded
    PageRank at n >= 1e5 completes on the sparse path with O(edges) peak
    memory, bitwise equal to the sparse single-machine oracle, while the
    dense-materialization guard proves no [n, n] buffer can exist;
  * the committed real-world fixture loads, pads, and runs coded vs
    uncoded end-to-end, bitwise equal to the oracle.
"""
import dataclasses
import tracemalloc

import numpy as np
import pytest

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.shuffle_plan import compile_plan, compile_plan_csr

PLAN_MODES = ["uncoded", "coded", "coded-fast"]


def _case(model):
    """(CSR-native graph, allocation) per model; small n so the dense view
    can be materialized for the adjacency-driven reference compile."""
    if model == "er":
        n = divisible_n(48, 4, 2)
        return graphs.erdos_renyi(n, 0.2, seed=11), er_allocation(n, 4, 2)
    if model == "pl":
        n = divisible_n(60, 4, 2)
        return graphs.power_law(n, 2.5, seed=9), er_allocation(n, 4, 2)
    if model == "rb":
        return (graphs.random_bipartite(48, 24, 0.3, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    if model == "sbm":
        return (graphs.stochastic_block(48, 24, 0.25, 0.1, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    raise ValueError(model)


_CASES = {m: _case(m) for m in ("er", "rb", "sbm", "pl")}


def _assert_plans_identical(a, b):
    for f in dataclasses.fields(a):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            assert vb is not None and va.dtype == vb.dtype, f.name
            np.testing.assert_array_equal(va, vb, err_msg=f.name)
        else:
            assert va == vb, f.name


@pytest.mark.parametrize("model", ["er", "rb", "sbm", "pl"])
@pytest.mark.parametrize("schedule", [True, False], ids=["coded", "missing"])
def test_csr_plan_schedule_identical_to_adjacency_plan(model, schedule):
    """Same bits, same slot arrays: every field of the compiled plan."""
    g, alloc = _CASES[model]
    pa = compile_plan(g.adj, alloc, schedule=schedule)
    pc = compile_plan_csr(g.csr, alloc, schedule=schedule)
    _assert_plans_identical(pa, pc)
    if schedule:
        assert pa.coded_bits == pc.coded_bits
        assert pa.uncoded_bits == pc.uncoded_bits
        assert pa.leftover_bits == pc.leftover_bits


@pytest.mark.parametrize("model", ["er", "rb", "sbm", "pl"])
@pytest.mark.parametrize("mode", PLAN_MODES)
def test_engine_identical_under_either_plan(model, mode):
    g, alloc = _CASES[model]
    prog = algo.pagerank()
    pa = compile_plan(g.adj, alloc, schedule=mode != "uncoded")
    pc = compile_plan_csr(g.csr, alloc, schedule=mode != "uncoded")
    ra = engine.run(prog, g, alloc, 3, mode=mode, plan=pa, path="sparse")
    rc = engine.run(prog, g, alloc, 3, mode=mode, plan=pc, path="sparse")
    np.testing.assert_array_equal(ra.state, rc.state)
    assert ra.shuffle_bits == rc.shuffle_bits


def test_csr_plan_rejects_mismatched_n():
    g, _ = _CASES["er"]
    with pytest.raises(ValueError, match="pad"):
        compile_plan_csr(g.csr, er_allocation(g.n + 12, 4, 2))


def test_large_csr_native_end_to_end_dense_free():
    """Acceptance: 10-iteration coded PageRank at n >= 1e5 on a CSR-native
    ER graph - sparse path only, O(edges) peak memory, no [n, n] buffer
    (guard-enforced), bitwise equal to the sparse oracle."""
    K, r = 4, 2
    n = divisible_n(100_000, K, r)
    g = graphs.erdos_renyi(n, 6.0 / n, seed=7)
    alloc = er_allocation(n, K, r)
    prog = algo.pagerank()
    tracemalloc.start()
    plan = compile_plan_csr(g.csr, alloc)            # adjacency-free compile
    res = engine.run(prog, g, alloc, 10, mode="coded", plan=plan,
                     path="sparse")
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    nnz = g.csr.nnz
    assert peak < 500 * nnz                          # O(edges), not O(n^2)
    assert peak < n * n // 8                         # far below any [n, n]
    np.testing.assert_array_equal(
        res.state, algo.reference_run(prog, g, 10, path="sparse"))
    # The guard proves the dense view never existed and never can here.
    with pytest.raises(ValueError, match="dense_limit"):
        g.adj


def test_fixture_runs_coded_vs_uncoded_end_to_end():
    g, alloc = graphs.allocate(graphs.load_fixture(), 4, 2)
    prog = algo.pagerank()
    ref = algo.reference_run(prog, g, 10, path="sparse")
    res_c = engine.run(prog, g, alloc, 10, mode="coded", path="sparse")
    res_u = engine.run(prog, g, alloc, 10, mode="uncoded", path="sparse")
    np.testing.assert_array_equal(res_c.state, ref)
    np.testing.assert_array_equal(res_u.state, ref)
    assert 0 < res_c.shuffle_bits < res_u.shuffle_bits   # real coded gain


def test_fixture_sssp_and_faults_on_csr_native_graph():
    """SSSP (edge_weights CSR path) and mid-run failure recovery both ride
    the CSR-native graph without touching the dense view."""
    g, alloc = graphs.allocate(graphs.load_fixture(), 4, 2)
    prog = algo.sssp(0)
    ref = algo.reference_run(prog, g, 4, path="sparse")
    res = engine.run(prog, g, alloc, 4, mode="coded", path="sparse")
    np.testing.assert_array_equal(res.state, ref)
    pr = algo.pagerank()
    res_f, stats = faults.run_with_failure(pr, g, alloc, 3, failed=(1,),
                                           fail_at_iter=1)
    np.testing.assert_array_equal(
        res_f.state, algo.reference_run(pr, g, 3, path="sparse"))
    assert stats.recovery_bits > 0
