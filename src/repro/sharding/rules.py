"""Logical-axis -> mesh-axis sharding rules (MaxText-style, with fallbacks).

Params carry logical axis names (layers/embed/heads/mlp/expert/vocab/...);
rules map them to mesh axes with divisibility-checked fallback chains, so one
rule set serves every architecture (e.g. internvl2's 14 heads can't split 16
ways -> attention falls back to replicated-heads + fsdp'd embed).
"""
from __future__ import annotations

import contextlib
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Fallback chain per logical axis: first mesh axis (or tuple) that divides the
# dimension wins; None = replicate.
LOGICAL_RULES: dict[str, tuple] = {
    "embed": (("pod", "data"), "data", None),
    "vocab": ("model", None),
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "mlp": ("model", None),
    # PERF (EXPERIMENTS.md SSPerf, llama4/train_4k, iter 1 - REFUTED):
    # sharding experts over 'data' (expert parallelism) made collectives
    # *worse* (+15%) and doubled compute: with einsum-based dispatch XLA
    # all-gathers the token axis instead of emitting a token all-to-all.
    # Proper EP needs an explicit shard_map dispatch; until then experts
    # ride 'model' and FSDP's embed sharding.
    "expert": ("model", None),
    "inner": ("model", None),       # ssm d_inner
    "lora": (None,),
    "layers": (None,),
    "state": (None,),
    # activations
    "batch": (("pod", "data"), "data", None),
    "act_seq": ("data", None),      # sequence sharding (long-context cache)
    "act_seq_tp": ("model", None),  # kv-seq over tensor axis (ragged-head archs)
    "act_heads": ("model", None),
    "act_kv": ("model", None),
}

_ctx = threading.local()


def set_mesh(mesh: Mesh | None):
    _ctx.mesh = mesh


def _mesh() -> Mesh | None:
    return getattr(_ctx, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    set_mesh(mesh)
    try:
        with mesh:
            yield mesh
    finally:
        set_mesh(None)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        size = 1
        for a in axis:
            size *= mesh.shape.get(a, 1)
        return size
    return mesh.shape.get(axis, 1)


def _resolve(mesh: Mesh, logical: str | None, dim: int):
    """First candidate mesh axis that exists and divides `dim`."""
    if logical is None:
        return None
    for cand in LOGICAL_RULES.get(logical, (None,)):
        if cand is None:
            return None
        axes = cand if isinstance(cand, tuple) else (cand,)
        if all(a in mesh.shape for a in axes) and dim % _axis_size(mesh, cand) == 0:
            return cand
    return None


def spec_for(mesh: Mesh, axes: tuple, shape: tuple[int, ...]) -> P:
    used: set = set()
    out = []
    for logical, dim in zip(axes, shape):
        m = _resolve(mesh, logical, dim)
        flat = tuple(m) if isinstance(m, tuple) else ((m,) if m else ())
        if any(a in used for a in flat):
            m = None                      # one mesh axis shards one dim only
        used.update(flat)
        out.append(m)
    return P(*out)


def param_shardings(mesh: Mesh, axes_tree, shapes_tree):
    """NamedSharding tree matching the params tree."""
    return jax.tree.map(
        lambda ax, sh: NamedSharding(mesh, spec_for(mesh, ax, sh.shape)),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def activation_sharding(mesh: Mesh, axes: tuple, shape: tuple[int, ...]):
    return NamedSharding(mesh, spec_for(mesh, axes, shape))


def constrain(x: jnp.ndarray, *axes: str | None) -> jnp.ndarray:
    """Sharding-constrain an activation by logical axes; no-op without mesh."""
    mesh = _mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(mesh, axes, x.shape)))


def tp_size() -> int:
    """Tensor-parallel degree of the active mesh (1 without a mesh)."""
    mesh = _mesh()
    return mesh.shape.get("model", 1) if mesh is not None else 1
