"""Production mesh definitions.

Defined as functions (never module-level constants) so importing this module
never touches jax device state - jax locks the device count on first init,
and only dryrun.py sets the 512-placeholder XLA flag.
"""
from __future__ import annotations

import jax


def make_mesh_auto(shape, axes):
    """jax.make_mesh with Auto axis types on every jax we support.

    jax >= 0.5 takes `axis_types`; on 0.4.x the argument does not exist and
    Auto is the only (default) behavior, so omitting it is equivalent.
    """
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = True):
    """shard_map across the jax versions we support.

    jax >= 0.6 exposes jax.shard_map with `check_vma`; 0.4.x has the
    experimental shard_map with the equivalent `check_rep`. `check=False`
    disables the output-replication check (needed when out_specs promise
    more replication than the checker can prove, e.g. psum-ed outputs).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)


def make_servers_mesh(K: int):
    """('servers',) mesh over the first K devices (devices = servers).

    The coded-Shuffle fused path maps one Shuffle server per device.
    `jax.make_mesh` wants the axis sizes to consume *all* devices, so this
    builds the Mesh explicitly from a device prefix - a host with 8 forced
    CPU devices can still run a K=4 plan.
    """
    import numpy as np
    from jax.sharding import Mesh

    devs = jax.devices()
    if len(devs) < K:
        raise ValueError(
            f"need one device per server (K={K}) but only {len(devs)} "
            f"devices exist; force host devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={K}")
    return Mesh(np.asarray(devs[:K]), ("servers",))


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_auto(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh_auto((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
