"""Zero-dependency phase tracing: nestable spans + Chrome-trace export.

The paper's argument is a computation<->communication trade-off, so the
repo needs to *attribute time* to the Map / encode / exchange / decode /
Reduce phases that Theorem 1 reasons about — not just count bits.  This
module provides the span layer every hot path threads through:

* ``Tracer.span(name, **attrs)`` opens a nestable span recording
  monotonic ``perf_counter_ns`` enter/exit stamps plus wall-clock, with
  arbitrary attributes (bits, words, nnz, B, iteration) attached at open
  or later via ``Span.set``.
* A disabled tracer is a hard no-op: ``span()`` returns a shared
  ``_NullSpan`` singleton (no allocation, no locking, no timestamps), so
  instrumented hot loops pay one attribute check + one method call —
  well under 1% on any real phase.
* ``Tracer.event(name, **attrs)`` records an instant (zero-duration)
  marker at the current nesting position — used for fault and
  checkpoint events.
* ``to_chrome_trace()`` exports the Chrome trace-event JSON that
  chrome://tracing and ui.perfetto.dev load directly; ``tree()``
  returns a deterministic ``(name, children)`` nesting for pinned tests.

Stdlib-only on purpose: ``core/`` must stay importable without jax, and
``obs`` must stay importable without anything at all.
"""
from __future__ import annotations

import json
import os
import threading
import time

__all__ = ["Span", "Tracer", "get_tracer", "set_tracer"]


class _NullSpan:
    """Shared do-nothing span handed out by a disabled tracer."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    """One timed region. Context manager; nests via the tracer's stack."""

    __slots__ = (
        "name", "attrs", "children", "t0_ns", "t1_ns", "wall_t0",
        "thread", "instant", "_tracer",
    )

    def __init__(self, tracer, name, attrs, *, instant=False):
        self._tracer = tracer
        self.name = name
        self.attrs = attrs
        self.children = []
        self.t0_ns = 0
        self.t1_ns = 0
        self.wall_t0 = 0.0
        self.thread = threading.current_thread().name
        self.instant = instant

    def __enter__(self):
        self.wall_t0 = time.time()
        self.t0_ns = time.perf_counter_ns() - self._tracer._origin_ns
        self._tracer._push(self)
        return self

    def __exit__(self, exc_type, exc, tb):
        self.t1_ns = time.perf_counter_ns() - self._tracer._origin_ns
        if exc_type is not None:
            self.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self)
        return False

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    @property
    def duration_s(self) -> float:
        return (self.t1_ns - self.t0_ns) / 1e9

    def tree(self):
        """Deterministic (name, (child trees...)) — timestamps stripped."""
        return (self.name, tuple(c.tree() for c in self.children))

    def walk(self):
        yield self
        for c in self.children:
            yield from c.walk()

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Span({self.name!r}, {self.duration_s * 1e6:.1f}us, {self.attrs})"


class Tracer:
    """Process-local span collector. Thread-safe; per-thread nesting stacks."""

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._roots: list[Span] = []
        self._origin_ns = time.perf_counter_ns()
        self._origin_wall = time.time()

    # -- control ---------------------------------------------------------
    def enable(self):
        self.enabled = True
        return self

    def disable(self):
        self.enabled = False
        return self

    def reset(self):
        """Drop collected spans and restart the clock origin."""
        with self._lock:
            self._roots = []
        self._tls = threading.local()
        self._origin_ns = time.perf_counter_ns()
        self._origin_wall = time.time()
        return self

    # -- recording -------------------------------------------------------
    def span(self, name: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, name, attrs)

    def event(self, name: str, **attrs) -> None:
        """Instant marker at the current nesting position."""
        if not self.enabled:
            return
        now = time.perf_counter_ns() - self._origin_ns
        sp = Span(self, name, attrs, instant=True)
        sp.wall_t0 = time.time()
        sp.t0_ns = sp.t1_ns = now
        self._attach(sp)

    def _stack(self) -> list:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _push(self, span: Span) -> None:
        self._stack().append(span)

    def _pop(self, span: Span) -> None:
        st = self._stack()
        while st and st[-1] is not span:  # tolerate mis-nested exits
            st.pop()
        if st:
            st.pop()
        if st:
            st[-1].children.append(span)
        else:
            with self._lock:
                self._roots.append(span)

    def _attach(self, span: Span) -> None:
        st = self._stack()
        if st:
            st[-1].children.append(span)
        else:
            with self._lock:
                self._roots.append(span)

    # -- inspection ------------------------------------------------------
    @property
    def roots(self) -> list:
        with self._lock:
            return list(self._roots)

    def tree(self):
        return tuple(r.tree() for r in self.roots)

    def spans(self):
        for r in self.roots:
            yield from r.walk()

    def find(self, name: str) -> list:
        return [s for s in self.spans() if s.name == name]

    # -- export ----------------------------------------------------------
    def to_chrome_trace(self) -> dict:
        """Chrome trace-event format (load in chrome://tracing / perfetto)."""
        events = []
        pid = os.getpid()
        tids: dict[str, int] = {}
        for root in self.roots:
            for sp in root.walk():
                tid = tids.setdefault(sp.thread, len(tids) + 1)
                args = {k: _json_safe(v) for k, v in sp.attrs.items()}
                if sp.instant:
                    events.append({
                        "name": sp.name, "ph": "i", "s": "t",
                        "pid": pid, "tid": tid,
                        "ts": sp.t0_ns / 1e3, "args": args,
                    })
                else:
                    events.append({
                        "name": sp.name, "ph": "X",
                        "pid": pid, "tid": tid,
                        "ts": sp.t0_ns / 1e3,
                        "dur": (sp.t1_ns - sp.t0_ns) / 1e3,
                        "args": args,
                    })
        for name, tid in tids.items():
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": name},
            })
        events.sort(key=lambda e: (e.get("ts", 0.0), e["name"]))
        return {
            "traceEvents": events,
            "displayTimeUnit": "ms",
            "otherData": {"origin_unix_s": self._origin_wall},
        }

    def dump_chrome_trace(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        return path


def _json_safe(v):
    if isinstance(v, (str, bool)) or v is None:
        return v
    if isinstance(v, (int, float)):
        return v
    try:  # numpy scalars and friends
        return v.item()
    except AttributeError:
        return str(v)


_TRACER = Tracer(enabled=os.environ.get("REPRO_TRACE", "") not in ("", "0"))


def get_tracer() -> Tracer:
    """The process-local tracer every instrumented layer shares."""
    return _TRACER


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-local tracer (tests); returns the previous one."""
    global _TRACER
    prev = _TRACER
    _TRACER = tracer
    return prev
