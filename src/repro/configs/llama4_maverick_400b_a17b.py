"""llama4-maverick-400b-a17b [moe] - MoE with dense/MoE interleave, shared
expert, top-1 of 128 routed [hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=16384,                      # dense layers + shared expert width
    vocab=202048, rope_theta=500_000.0,
    moe=MoEConfig(num_experts=128, top_k=1, num_shared=1, d_ff_expert=8192),
    moe_every=2,
)
