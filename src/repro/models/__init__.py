"""Assigned-architecture model substrate (pure JAX, scan-over-layers)."""
from . import decode, layers, mla, moe, ssm, transformer  # noqa: F401
