"""Admission-batched multi-query serving over one compiled coded session.

The coded Shuffle schedule is a function of (graph, allocation) only, so a
single `engine.CompiledEngine` can carry any number of concurrent queries as
payload columns of one exchange. `GraphService` is the front end: callers
`submit` individual queries (SSSP roots, personalized-PageRank preference
vectors), the service coalesces them - up to `max_batch` or an admission
timeout - and runs each admitted batch as ONE batched execution, fanning the
per-query result columns back out through futures.
"""
from .service import GraphService, ServeStats

__all__ = ["GraphService", "ServeStats"]
