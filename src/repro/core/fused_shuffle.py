"""Multi-device coded Shuffle under shard_map (devices = servers).

The literal scheme multicasts per (r+1)-group columns one at a time - fine on
an Ethernet bus, wrong on an ICI torus. Here every server packs ALL its coded
columns (across all groups it serves) into one dense uint32 buffer and a
single jax.lax.all_gather moves every buffer to every server in one fused
collective; receivers slice their groups and XOR-strip locally (kernels/
xor_code). Bit volume on the wire equals the literal schedule's (padding
aside); latency collapses from O(#groups * #columns) transmissions to one
collective phase - this is the hardware adaptation of the paper's shared-bus
assumption.

Two executors share that design:

  * **Sparse (production path)** - `partition_plan` splits a compiled CSR
    `ShufflePlan` per server: each device holds only its own slice of the
    Map output (`loc_e`, the [nnz]-indexed values it Mapped, O(r nnz / K))
    plus its encode/decode/strip tables (O(plan / K)). One iteration under
    `shard_map` on a ('servers',) mesh is (a) per-shard gather-shift-mask +
    XOR encode through the batched `kernels/xor_code` route, (b) one packed
    dense all_gather of uint32 coded words, (c) per-shard strip + shift-back
    into each receiver's delivery slice. No [n, n] or O(n^2)-shaped array
    exists anywhere on this path; `FusedSparseShuffle` jits the exchange
    once and replays it every iteration, bit-exact against
    `ShufflePlan.execute_coded_sparse` (unicast leftovers ride the same
    all_gather as single-slot full-width columns).

  * **Dense (small-n validation reference)** - `fused_exchange` consumes a
    replicated [n, n] value matrix through [n, n]-indexed schedule tensors;
    kept only to cross-check the collective layout at validation scale.

The column/slot structure comes straight off the compiled `ShufflePlan`
(compile-once) via `compile_plan_csr` - `build_schedule` accepts a `Graph`
and never touches `.adj`, so schedule construction works on CSR-native
graphs beyond `dense_limit`.

Word format: one uint32 per coded column and slot, in *codec bit order*
(`bitcodec.floats_to_words`), so segment s of a value travels left-aligned
as ``(word << shift_s) & mask_s`` - identical bit semantics to the NumPy
plan executor, which is what makes the device path bitwise comparable.

**Topology-aware two-level path.** Given a non-flat `Topology` (racks x
servers) and a `HierarchicalPlan`, the exchange runs on a
('racks', 'servers') mesh in two collectives: a *plain* all_gather of the
local Map words on the cheap 'servers' (intra-rack) axis, then the coded XOR
all_gather of rack-level packed buffers on the expensive 'racks' axis -
every rack encodes from its phase-A union buffer (replicated within the
rack, so recompute beats a leader branch), and each server decodes its own
delivery slice from the rack buffers plus direct intra-rack gathers.
Delivered words stay bitwise equal to the flat path (`partition_plan` /
`FusedSparseShuffle` accept a `Topology` and degenerate to the single-level
exchange on `Topology.flat(K)`).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.xor_code import ops as xor_ops
from ..launch.mesh import (Topology, make_racks_mesh, make_servers_mesh,
                           shard_map_compat)
from ..obs import get_tracer
from ..obs.metrics import get_registry
from .allocation import Allocation
from .bitcodec import floats_to_words, words_to_floats
from .graph_models import CSR, Graph
from .shuffle_plan import (HierarchicalPlan, PlanShuffleResult, ShufflePlan,
                           _rack_first_mapper, _run_ranks, compile_plan_csr)

FULL_MASK = np.uint32(0xFFFFFFFF)


def _sender_layout(plan: ShufflePlan) -> tuple[np.ndarray, np.ndarray]:
    """Per-sender packing of the plan's coded columns.

    Deterministic order within each sender: (group, in-group column rank).
    Returns (colpos [C] - position of column c in its sender's buffer,
    ncols [K] - coded-column count per sender).
    """
    order = np.lexsort((plan.col_rank, plan.col_gm, plan.col_sender))
    _, rank = _run_ranks(plan.col_sender[order])
    colpos = np.empty(plan.col_sender.size, dtype=np.int64)
    colpos[order] = rank
    ncols = np.bincount(plan.col_sender, minlength=plan.K)
    return colpos, ncols


# ---------------------------------------------------------------------------
# Sparse multi-device path (production)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSparseSchedule:
    """Per-server partition of a compiled CSR plan (all arrays plan-sized).

    Device k's shard (row k of every array) is everything it needs for one
    coded Shuffle: `loc_e` selects the [nnz] edge values it Mapped (column
    vertex in M_k - O(r nnz / K) entries), the `enc_*` tables lay its coded
    columns (+ its unicast leftovers, as single-slot full-width columns)
    into a [W]-word buffer, and the `dec_*`/`strip_*` tables recover its
    delivery slice from the all_gathered [K, W] buffer matrix.

    Sentinels: local index `Lmax` is a guaranteed-zero word; buffer column
    `W` is a guaranteed-zero column (padded after the all_gather); masks of
    sentinel slots are 0, so they OR/XOR away - encode and decode are plain
    gather-shift-mask pipelines with no control flow.
    """

    K: int
    r: int
    W: int                        # per-sender buffer width (words)
    Lmax: int                     # max local-value count over servers
    Dmax: int                     # max delivery count over receivers
    loc_e: np.ndarray             # [K, Lmax] int64 CSR entry (nnz = zero pad)
    enc_l: np.ndarray             # [K, W, r] int32 local index (Lmax = zero)
    enc_shift: np.ndarray         # [K, W, r] uint32 segment left-shift
    enc_mask: np.ndarray          # [K, W, r] uint32 segment keep-mask
    dec_s: np.ndarray             # [K, Dmax, r] int32 sender of segment t
    dec_w: np.ndarray             # [K, Dmax, r] int32 buffer column (W = zero)
    dec_mask: np.ndarray          # [K, Dmax, r] uint32 own-slot keep-mask
    dec_shift: np.ndarray         # [K, Dmax, r] uint32 shift back into place
    strip_l: np.ndarray           # [K, Dmax, r, r-1] int32 local index
    strip_shift: np.ndarray       # [K, Dmax, r, r-1] uint32
    strip_mask: np.ndarray        # [K, Dmax, r, r-1] uint32


def partition_plan(plan: ShufflePlan | HierarchicalPlan, csr: CSR,
                   alloc: Allocation, topology: Topology | None = None):
    """Partition a compiled plan per server for the fused sparse path.

    Pure compile-time layout (no data): every output array is [nnz]- or
    [plan]-sized. Unicast leftovers are assigned to the smallest server
    that Mapped their column vertex and appended to that sender's buffer as
    single-slot full-width columns, so they ride the same all_gather.

    Topology-aware form: a `HierarchicalPlan` (its `Topology` non-flat)
    routes to `partition_hierarchical`; `Topology.flat(K)` degenerates to
    the single-level partition of the plan's flat schedule.
    """
    if isinstance(plan, HierarchicalPlan):
        if topology is not None and topology != plan.topology:
            raise ValueError(
                f"topology {topology} disagrees with the plan's "
                f"{plan.topology}")
        if not plan.topology.is_flat:
            return partition_hierarchical(plan, csr, alloc)
        plan = plan.flat
    elif topology is not None and not topology.is_flat:
        raise ValueError(
            "a non-flat Topology needs a HierarchicalPlan "
            "(core.shuffle_plan.compile_hierarchical), got a flat "
            "ShufflePlan")
    plan._require_schedule()
    tables = plan.edge_tables(csr, alloc)     # locates edges + validates
    K, r = plan.K, plan.r
    C = plan.col_sender.size
    Pn = plan.pair_k.size
    L = plan.left_k.size
    nstrip = max(r - 1, 0)

    colpos, ncols = _sender_layout(plan)

    # Leftover layout: sender = smallest mapper of the column vertex,
    # appended after that sender's coded columns (stable (k, i, j) order).
    if L:
        lsender = np.argmax(alloc.map_sets[:, plan.left_j], axis=0)
        if not alloc.map_sets[lsender, plan.left_j].all():
            raise RuntimeError("leftover value has no Mapping server")
        lorder = np.argsort(lsender, kind="stable")
        _, lrank = _run_ranks(lsender[lorder])
        leftw = np.empty(L, dtype=np.int64)
        leftw[lorder] = ncols[lsender[lorder]] + lrank
        nleft = np.bincount(lsender, minlength=K)
    else:
        lsender = np.zeros(0, dtype=np.int64)
        leftw = np.zeros(0, dtype=np.int64)
        nleft = np.zeros(K, dtype=np.int64)
    W = max(int((ncols + nleft).max()), 1)

    # Per-server local Map slices: CSR entries whose column vertex the
    # server Mapped (it can recompute exactly these values locally).
    member = alloc.map_sets[:, csr.indices]             # [K, nnz] bool
    counts = member.sum(axis=1)
    Lmax = max(int(counts.max()), 1)
    loc_e = np.full((K, Lmax), csr.nnz, dtype=np.int64)  # nnz = zero pad

    # --- encode tables: valid plan slots + leftover slots, per sender ---
    enc_l = np.full((K, W, r), Lmax, dtype=np.int32)     # Lmax = zero word
    enc_shift = np.zeros((K, W, r), dtype=np.uint32)
    enc_mask = np.zeros((K, W, r), dtype=np.uint32)
    cs, sl = np.nonzero(plan.slot_pair < Pn) if C else (
        np.zeros(0, np.int64), np.zeros(0, np.int64))
    e_of_slot = tables.pair_e[plan.slot_pair[cs, sl]] if cs.size else cs
    s_of_slot = plan.col_sender[cs] if cs.size else cs

    # --- decode tables, first in flat (k, i, j) delivery order ---
    M = plan.all_k.size
    f_s = np.zeros((M, r), dtype=np.int32)
    f_w = np.full((M, r), W, dtype=np.int32)             # W = zero column
    f_mask = np.zeros((M, r), dtype=np.uint32)
    f_shift = np.zeros((M, r), dtype=np.uint32)
    f_sl = np.full((M, r, nstrip), Lmax, dtype=np.int32)
    f_ssh = np.zeros((M, r, nstrip), dtype=np.uint32)
    f_smk = np.zeros((M, r, nstrip), dtype=np.uint32)
    if Pn:
        mpos = plan.pos_covered
        c, slot = plan.pair_col, plan.pair_slot          # [P, r]
        f_s[mpos] = plan.col_sender[c]
        f_w[mpos] = colpos[c]
        f_mask[mpos] = plan.slot_mask[c, slot]
        f_shift[mpos] = np.broadcast_to(plan.seg_shift[None, :], (Pn, r))
        if nstrip:
            ar = np.broadcast_to(np.arange(r)[None, None, :], (Pn, r, r))
            others = ar[~(ar == slot[..., None])].reshape(Pn, r, nstrip)
            c3 = np.broadcast_to(c[:, :, None], (Pn, r, nstrip))
            sp = plan.slot_pair[c3, others]              # [P, r, r-1]
            svalid = sp < Pn
            f_ssh[mpos] = plan.slot_shift[c3, others]
            f_smk[mpos] = plan.slot_mask[c3, others]
            e_strip = tables.pair_e[np.minimum(sp, max(Pn - 1, 0))]
    if L:
        f_s[plan.pos_left, 0] = lsender
        f_w[plan.pos_left, 0] = leftw
        f_mask[plan.pos_left, 0] = FULL_MASK             # full word, shift 0

    # --- per-server local index conversions (one vectorized pass each) ---
    for k in range(K):
        lset = np.flatnonzero(member[k])
        loc_e[k, :lset.size] = lset
        lpos = np.cumsum(member[k]) - 1                  # entry -> local idx
        if cs.size:
            m = s_of_slot == k                           # encode slots k sends
            if not member[k][e_of_slot[m]].all():
                raise RuntimeError(f"sender {k} schedules a value it "
                                   "did not Map")
            enc_l[k, colpos[cs[m]], sl[m]] = lpos[e_of_slot[m]]
            enc_shift[k, colpos[cs[m]], sl[m]] = plan.slot_shift[cs[m], sl[m]]
            enc_mask[k, colpos[cs[m]], sl[m]] = plan.slot_mask[cs[m], sl[m]]
        if L:
            m = lsender == k                             # leftovers k unicasts
            if not member[k][tables.left_e[m]].all():
                raise RuntimeError(f"sender {k} unicasts a value it "
                                   "did not Map")
            enc_l[k, leftw[m], 0] = lpos[tables.left_e[m]]
            enc_mask[k, leftw[m], 0] = FULL_MASK         # full word, shift 0
        if Pn and nstrip:
            m = plan.pair_k == k                         # strips k recomputes
            li = np.where(svalid[m], lpos[e_strip[m]], Lmax)
            if not (member[k][e_strip[m]] | ~svalid[m]).all():
                raise RuntimeError(f"receiver {k} must strip a value it "
                                   "did not Map")
            f_sl[plan.pos_covered[m]] = li.astype(np.int32)

    # --- scatter the flat decode tables into per-receiver padded rows ---
    dcount = np.diff(plan.ptr)
    Dmax = max(int(dcount.max()) if K else 0, 1)
    kk = plan.all_k
    dd = np.arange(M, dtype=np.int64) - plan.ptr[kk]
    dec_s = np.zeros((K, Dmax, r), dtype=np.int32)
    dec_w = np.full((K, Dmax, r), W, dtype=np.int32)
    dec_mask = np.zeros((K, Dmax, r), dtype=np.uint32)
    dec_shift = np.zeros((K, Dmax, r), dtype=np.uint32)
    strip_l = np.full((K, Dmax, r, nstrip), Lmax, dtype=np.int32)
    strip_shift = np.zeros((K, Dmax, r, nstrip), dtype=np.uint32)
    strip_mask = np.zeros((K, Dmax, r, nstrip), dtype=np.uint32)
    dec_s[kk, dd] = f_s
    dec_w[kk, dd] = f_w
    dec_mask[kk, dd] = f_mask
    dec_shift[kk, dd] = f_shift
    strip_l[kk, dd] = f_sl
    strip_shift[kk, dd] = f_ssh
    strip_mask[kk, dd] = f_smk

    return FusedSparseSchedule(
        K=K, r=r, W=W, Lmax=Lmax, Dmax=Dmax, loc_e=loc_e,
        enc_l=enc_l, enc_shift=enc_shift, enc_mask=enc_mask,
        dec_s=dec_s, dec_w=dec_w, dec_mask=dec_mask, dec_shift=dec_shift,
        strip_l=strip_l, strip_shift=strip_shift, strip_mask=strip_mask)


@dataclasses.dataclass(frozen=True)
class FusedHierarchicalSchedule:
    """Per-device partition of a `HierarchicalPlan` for the two-level path.

    Phase A all_gathers each server's `loc` words on the 'servers' axis, so
    every server holds its rack's union buffer ``rflat`` of
    ``S * (Lmax + 1)`` words (block s = server s of the rack, word `Lmax`
    of block 0 a guaranteed zero - the sentinel `ZERO = Lmax`). The rack
    encode tables (`enc_*`, one row per *rack*, replicated over its
    servers) index `rflat`; phase B all_gathers the [Wx]-word rack buffers
    on the 'racks' axis. Per-server decode reads coded segments from
    ``allbufs[dec_rk, dec_w]`` (rack column `Wx` = zero pad), strips the
    other slots from `rflat`, and ORs in `direct_l`/`direct_mask` gathers
    for the intra-only deliveries that never crossed a rack.
    """

    K: int
    R: int
    S: int
    rr: int                       # rack-level redundancy (inter.r)
    Wx: int                       # per-rack buffer width (words)
    Lmax: int                     # max local-value count over servers
    Dmax: int                     # max delivery count over receivers
    loc_e: np.ndarray             # [K, Lmax] int64 CSR entry (nnz = zero pad)
    enc_l: np.ndarray             # [R, Wx, rr] int32 into rflat (ZERO = pad)
    enc_shift: np.ndarray         # [R, Wx, rr] uint32
    enc_mask: np.ndarray          # [R, Wx, rr] uint32
    dec_rk: np.ndarray            # [K, Dmax, rr] int32 sending rack
    dec_w: np.ndarray             # [K, Dmax, rr] int32 rack column (Wx = zero)
    dec_mask: np.ndarray          # [K, Dmax, rr] uint32
    dec_shift: np.ndarray         # [K, Dmax, rr] uint32
    strip_f: np.ndarray           # [K, Dmax, rr, rr-1] int32 into rflat
    strip_shift: np.ndarray       # [K, Dmax, rr, rr-1] uint32
    strip_mask: np.ndarray        # [K, Dmax, rr, rr-1] uint32
    direct_l: np.ndarray          # [K, Dmax] int32 into rflat (ZERO = pad)
    direct_mask: np.ndarray       # [K, Dmax] uint32 (FULL for intra-only)


def partition_hierarchical(hplan: HierarchicalPlan, csr: CSR,
                           alloc: Allocation) -> FusedHierarchicalSchedule:
    """Partition a `HierarchicalPlan` per device for the two-level exchange.

    Same compile-time/no-data discipline as `partition_plan`; every value
    read from a rack's phase-A buffer comes from the rack's *designated
    source* (its lowest Mapping server - the same rule the plan's
    `intra_rack_bits` accounting charges), and every coded segment decodes
    bitwise like the NumPy hierarchical executor because identical floats
    produce identical codec words on every holder.
    """
    flat, inter, topo = hplan.flat, hplan.inter, hplan.topology
    R, S = topo.racks, topo.servers_per_rack
    K, rr = flat.K, inter.r
    nstrip = max(rr - 1, 0)
    flat._require_schedule()
    inter._require_schedule()
    ft = flat.edge_tables(csr, alloc)           # locates + validates
    xt = inter.edge_tables(csr, hplan.rack_alloc)
    has = hplan.rack_alloc.map_sets             # [R, n] rack Mapped vertex
    first, _ = _rack_first_mapper(alloc, R, S)

    member = alloc.map_sets[:, csr.indices]     # [K, nnz]
    Lmax = max(int(member.sum(axis=1).max()), 1)
    loc_e = np.full((K, Lmax), csr.nnz, dtype=np.int64)
    for k in range(K):
        lset = np.flatnonzero(member[k])
        loc_e[k, :lset.size] = lset
    lpos_all = np.where(member, np.cumsum(member, axis=1) - 1, 0)
    blk = Lmax + 1
    ZERO = Lmax                                 # rflat[Lmax] == 0 pad word

    def rfidx(rack, j, e):
        """Phase-A buffer position of vertex j's value (CSR entry e) as
        held by `rack`'s designated source server."""
        if not has[rack, j].all():
            raise RuntimeError("hierarchical schedule references a vertex "
                               "its consuming rack never Mapped")
        off = first[rack, j].astype(np.int64)
        src = rack.astype(np.int64) * S + off
        if not member[src, e].all():
            raise RuntimeError("designated in-rack source did not Map its "
                               "assigned value")
        return (off * blk + lpos_all[src, e]).astype(np.int32)

    # --- rack-level sender layout + encode tables (one row per rack) ---
    colpos, ncols = _sender_layout(inter)
    Px = inter.pair_k.size
    Lx = inter.left_k.size
    if Lx:
        lsender = np.argmax(has[:, inter.left_j], axis=0)
        if not has[lsender, inter.left_j].all():
            raise RuntimeError("rack-level leftover has no Mapping rack")
        lorder = np.argsort(lsender, kind="stable")
        _, lrank = _run_ranks(lsender[lorder])
        leftw = np.empty(Lx, dtype=np.int64)
        leftw[lorder] = ncols[lsender[lorder]] + lrank
        nleft = np.bincount(lsender, minlength=R)
    else:
        lsender = np.zeros(0, dtype=np.int64)
        leftw = np.zeros(0, dtype=np.int64)
        nleft = np.zeros(R, dtype=np.int64)
    Wx = max(int((ncols + nleft).max()), 1)

    enc_l = np.full((R, Wx, rr), ZERO, dtype=np.int32)
    enc_shift = np.zeros((R, Wx, rr), dtype=np.uint32)
    enc_mask = np.zeros((R, Wx, rr), dtype=np.uint32)
    if inter.col_sender.size:
        cs, sl = np.nonzero(inter.slot_pair < Px)
        p = inter.slot_pair[cs, sl]
        sr = inter.col_sender[cs]               # sending rack per slot
        enc_l[sr, colpos[cs], sl] = rfidx(sr, inter.pair_j[p], xt.pair_e[p])
        enc_shift[sr, colpos[cs], sl] = inter.slot_shift[cs, sl]
        enc_mask[sr, colpos[cs], sl] = inter.slot_mask[cs, sl]
    if Lx:
        enc_l[lsender, leftw, 0] = rfidx(lsender, inter.left_j, xt.left_e)
        enc_mask[lsender, leftw, 0] = FULL_MASK

    # --- decode tables, first in flat (k, i, j) delivery order ---
    M = flat.all_k.size
    f_rk = np.zeros((M, rr), dtype=np.int32)
    f_w = np.full((M, rr), Wx, dtype=np.int32)
    f_mask = np.zeros((M, rr), dtype=np.uint32)
    f_shift = np.zeros((M, rr), dtype=np.uint32)
    f_sf = np.full((M, rr, nstrip), ZERO, dtype=np.int32)
    f_ssh = np.zeros((M, rr, nstrip), dtype=np.uint32)
    f_smk = np.zeros((M, rr, nstrip), dtype=np.uint32)
    f_dl = np.full(M, ZERO, dtype=np.int32)
    f_dm = np.zeros(M, dtype=np.uint32)

    d_rho = hplan.rack_of[flat.all_k]
    intra = hplan.inter_pos < 0
    if intra.any():
        f_dl[intra] = rfidx(d_rho[intra], flat.all_j[intra], ft.all_e[intra])
        f_dm[intra] = FULL_MASK

    # Inter deliveries: invert the inter plan's pos_covered/pos_left to
    # find which covered pair / leftover each flat delivery resolves to.
    Mx = inter.all_k.size
    kind_left = np.zeros(Mx, dtype=bool)
    kind_left[inter.pos_left] = True
    idx_in = np.empty(Mx, dtype=np.int64)
    idx_in[inter.pos_covered] = np.arange(Px, dtype=np.int64)
    idx_in[inter.pos_left] = np.arange(Lx, dtype=np.int64)
    ms = np.flatnonzero(~intra)
    q = hplan.inter_pos[ms]
    is_l = kind_left[q]
    mc, pc = ms[~is_l], idx_in[q[~is_l]]
    if mc.size:
        c, slot = inter.pair_col[pc], inter.pair_slot[pc]   # [Pc, rr]
        f_rk[mc] = inter.col_sender[c]
        f_w[mc] = colpos[c]
        f_mask[mc] = inter.slot_mask[c, slot]
        f_shift[mc] = np.broadcast_to(inter.seg_shift[None, :],
                                      (mc.size, rr))
        if nstrip:
            ar = np.broadcast_to(np.arange(rr)[None, None, :],
                                 (mc.size, rr, rr))
            others = ar[~(ar == slot[..., None])].reshape(mc.size, rr,
                                                          nstrip)
            c3 = np.broadcast_to(c[:, :, None], (mc.size, rr, nstrip))
            sp = inter.slot_pair[c3, others]
            svalid = sp < Px
            if svalid.any():
                spv = sp[svalid]
                rho3 = np.broadcast_to(d_rho[mc][:, None, None],
                                       sp.shape)[svalid]
                fill = np.full(sp.shape, ZERO, dtype=np.int32)
                fill[svalid] = rfidx(rho3, inter.pair_j[spv],
                                     xt.pair_e[spv])
                f_sf[mc] = fill
            f_ssh[mc] = inter.slot_shift[c3, others]
            f_smk[mc] = inter.slot_mask[c3, others]
    ml, pl = ms[is_l], idx_in[q[is_l]]
    if ml.size:
        f_rk[ml, 0] = lsender[pl]
        f_w[ml, 0] = leftw[pl]
        f_mask[ml, 0] = FULL_MASK               # full word, shift 0

    # --- scatter into per-receiver padded rows (flat per-server CSR) ---
    Dmax = max(int(np.diff(flat.ptr).max()) if K else 0, 1)
    kk = flat.all_k
    dd = np.arange(M, dtype=np.int64) - flat.ptr[kk]
    dec_rk = np.zeros((K, Dmax, rr), dtype=np.int32)
    dec_w = np.full((K, Dmax, rr), Wx, dtype=np.int32)
    dec_mask = np.zeros((K, Dmax, rr), dtype=np.uint32)
    dec_shift = np.zeros((K, Dmax, rr), dtype=np.uint32)
    strip_f = np.full((K, Dmax, rr, nstrip), ZERO, dtype=np.int32)
    strip_shift = np.zeros((K, Dmax, rr, nstrip), dtype=np.uint32)
    strip_mask = np.zeros((K, Dmax, rr, nstrip), dtype=np.uint32)
    direct_l = np.full((K, Dmax), ZERO, dtype=np.int32)
    direct_mask = np.zeros((K, Dmax), dtype=np.uint32)
    dec_rk[kk, dd] = f_rk
    dec_w[kk, dd] = f_w
    dec_mask[kk, dd] = f_mask
    dec_shift[kk, dd] = f_shift
    strip_f[kk, dd] = f_sf
    strip_shift[kk, dd] = f_ssh
    strip_mask[kk, dd] = f_smk
    direct_l[kk, dd] = f_dl
    direct_mask[kk, dd] = f_dm

    return FusedHierarchicalSchedule(
        K=K, R=R, S=S, rr=rr, Wx=Wx, Lmax=Lmax, Dmax=Dmax, loc_e=loc_e,
        enc_l=enc_l, enc_shift=enc_shift, enc_mask=enc_mask,
        dec_rk=dec_rk, dec_w=dec_w, dec_mask=dec_mask, dec_shift=dec_shift,
        strip_f=strip_f, strip_shift=strip_shift, strip_mask=strip_mask,
        direct_l=direct_l, direct_mask=direct_mask)


ENCODE_BACKENDS = ("xor-ref", "xor-kernel", "jnp")


class FusedSparseShuffle:
    """Jit-once / replay-every-iteration multi-device coded Shuffle.

    Wraps a compiled plan's per-server partition and the jitted shard_map
    exchange. `execute` is a drop-in peer of
    `ShufflePlan.execute_coded_sparse`: same [nnz] edge-value input, same
    `PlanShuffleResult` (bitwise-equal uint32 words, same bit accounting).

    Given a `HierarchicalPlan` (or a non-flat `topology=` plus one), the
    exchange runs the two-level ('racks' x 'servers') pipeline instead -
    see the module docstring - with `bits_sent` split into
    inter-rack/intra-rack on the exchange span and the metrics registry.
    `Topology.flat(K)` degenerates to the single-level exchange.

    encode:
      "xor-ref"    - batched kernels/xor_code route, jnp oracle (default).
      "xor-kernel" - same route through the Pallas kernel (interpret=True
                     off-TPU; pass interpret=False on real hardware).
      "jnp"        - plain jnp XOR reduce (no kernel route).
    """

    def __init__(self, plan: ShufflePlan | HierarchicalPlan, csr: CSR,
                 alloc: Allocation, mesh: Mesh | None = None, *,
                 topology: Topology | None = None, encode: str = "xor-ref",
                 interpret: bool = True):
        if encode not in ENCODE_BACKENDS:
            raise ValueError(f"unknown encode backend {encode!r}")
        self._bind(plan, csr, alloc, topology)
        if mesh is None:
            mesh = (make_racks_mesh(self.topology) if self._hier
                    else make_servers_mesh(self.plan.K))
        self.mesh = mesh
        if self.mesh.devices.size != self.plan.K:
            raise ValueError(
                f"mesh has {self.mesh.devices.size} devices but the plan "
                f"has K={self.plan.K} servers (one device per server)")
        self._encode = encode
        self._interpret = interpret
        build = self._build_hier if self._hier else self._build
        self._fn = build(encode, interpret, batched=False)
        self._fn_batched = None       # built lazily on the first [nnz, B] call
        self._dev_tables = self._make_dev_tables()

    def _bind(self, plan, csr, alloc, topology) -> None:
        """Resolve (plan, topology) into the flat or two-level partition.

        A `HierarchicalPlan` carries its own Topology; `Topology.flat(K)`
        (or no topology) degenerates to the single-level exchange on the
        plan's flat schedule.
        """
        if isinstance(plan, HierarchicalPlan):
            if topology is not None and topology != plan.topology:
                raise ValueError(
                    f"topology {topology} disagrees with the plan's "
                    f"{plan.topology}")
            topology = plan.topology
            if topology.is_flat:
                plan = plan.flat
        elif topology is not None and not topology.is_flat:
            raise ValueError(
                "a non-flat Topology needs a HierarchicalPlan "
                "(core.shuffle_plan.compile_hierarchical), got a flat "
                "ShufflePlan")
        self.topology = topology
        self._hier = isinstance(plan, HierarchicalPlan)
        if self._hier:
            self.hplan = plan
            self.plan = plan.flat
            self.sched = partition_hierarchical(plan, csr, alloc)
            self._schedule_bits = plan.inter_rack_bits + plan.intra_rack_bits
        else:
            self.hplan = None
            self.plan = plan
            self.sched = partition_plan(plan, csr, alloc)
            self._schedule_bits = plan.coded_bits + plan.leftover_bits

    def _make_dev_tables(self):
        s = self.sched
        if self._hier:
            R, S = self.topology.racks, self.topology.servers_per_rack

            def rs(a):
                # per-server rows -> mesh-shaped (racks, servers) blocks
                return a.reshape((R, S) + a.shape[1:])

            return tuple(jnp.asarray(a) for a in (
                s.enc_l, s.enc_shift, s.enc_mask,
                rs(s.dec_rk), rs(s.dec_w), rs(s.dec_mask), rs(s.dec_shift),
                rs(s.strip_f), rs(s.strip_shift), rs(s.strip_mask),
                rs(s.direct_l), rs(s.direct_mask)))
        return tuple(jnp.asarray(a) for a in (
            s.enc_l, s.enc_shift, s.enc_mask, s.dec_s, s.dec_w, s.dec_mask,
            s.dec_shift, s.strip_l, s.strip_shift, s.strip_mask))

    def rebind(self, plan: ShufflePlan | HierarchicalPlan, csr: CSR,
               alloc: Allocation) -> "FusedSparseShuffle":
        """New exchange bound to a mutated (plan, csr) on this instance's
        jitted callables.

        `CompiledEngine.update`'s hook: the per-server partition and device
        tables are rebuilt for the new plan (they index CSR entries, so any
        real delta moves them), but the traced shard_map exchange, mesh,
        and backend flags carry over - the tables are jit *arguments*, so
        XLA re-lowers only if the partition's padded shapes (W, Lmax, Dmax)
        actually changed, and replays the cached executable otherwise.
        A two-level instance expects a fresh `HierarchicalPlan` on the same
        Topology (repair keeps the rack structure).
        """
        ex = object.__new__(FusedSparseShuffle)
        ex._bind(plan, csr, alloc, self.topology)
        if ex._hier != self._hier:
            raise ValueError("rebind cannot switch between the flat and "
                             "two-level exchange; build a new instance")
        ex.mesh = self.mesh
        ex._encode = self._encode
        ex._interpret = self._interpret
        ex._fn = self._fn
        ex._fn_batched = self._fn_batched
        ex._dev_tables = ex._make_dev_tables()
        return ex

    def _build(self, encode: str, interpret: bool, batched: bool):
        use_kernel = encode == "xor-kernel"
        # Batched payloads append one trailing B axis to every *word* array
        # (loc, buffers, deliveries); the schedule tables are value-agnostic
        # and broadcast behind it. All device ops stay uint32 shift/mask/XOR,
        # so payload column b is bitwise the unbatched exchange of column b.
        bx = (lambda a: a[..., None]) if batched else (lambda a: a)

        def per_server(loc, enc_l, enc_shift, enc_mask, dec_s, dec_w,
                       dec_mask, dec_shift, strip_l, strip_shift, strip_mask):
            loc = loc[0]                          # [Lmax+1] (or [Lmax+1, B])
            if encode == "jnp":
                slotw = (loc[enc_l[0]] << bx(enc_shift[0])) & bx(enc_mask[0])
                coded = jax.lax.reduce(slotw, jnp.uint32(0),
                                       jax.lax.bitwise_xor, (1,))
            else:
                coded = xor_ops.xor_encode_slots(
                    loc, enc_l[0], enc_shift[0], enc_mask[0],
                    use_kernel=use_kernel, interpret=interpret)
            allbufs = jax.lax.all_gather(coded, "servers")  # [K, W(, B)]
            pad = ((0, 0), (0, 1)) + (((0, 0),) if batched else ())
            allbufs = jnp.pad(allbufs, pad)                 # zero col W
            got = allbufs[dec_s[0], dec_w[0]]               # [Dmax, r(, B)]
            sw = (loc[strip_l[0]] << bx(strip_shift[0])) & bx(strip_mask[0])
            strip = jax.lax.reduce(sw, jnp.uint32(0),
                                   jax.lax.bitwise_xor, (2,))
            rec = ((got ^ strip) & bx(dec_mask[0])) >> bx(dec_shift[0])
            words = jax.lax.reduce(rec, jnp.uint32(0),
                                   jax.lax.bitwise_or, (1,))
            return words[None]                              # [1, Dmax(, B)]

        # pallas_call has no replication rule, so the kernel route must
        # disable the output-replication checker (outputs are per-shard
        # anyway - nothing is claimed replicated).
        f = shard_map_compat(per_server, mesh=self.mesh,
                             in_specs=(P("servers"),) * 11,
                             out_specs=P("servers"), check=not use_kernel)
        return jax.jit(f)

    def _build_hier(self, encode: str, interpret: bool, batched: bool):
        use_kernel = encode == "xor-kernel"
        bx = (lambda a: a[..., None]) if batched else (lambda a: a)

        def fold(a, axis, op):
            # static unroll over a tiny (<= rr) axis: jax.lax.reduce has no
            # replication rule on a two-axis mesh in jax 0.4.x, plain
            # binary xor/or ops do
            parts = [jax.lax.index_in_dim(a, t, axis, keepdims=False)
                     for t in range(a.shape[axis])]
            out = parts[0]
            for x in parts[1:]:
                out = op(out, x)
            return out

        def per_server(loc, enc_l, enc_shift, enc_mask, dec_rk, dec_w,
                       dec_mask, dec_shift, strip_f, strip_shift,
                       strip_mask, direct_l, direct_mask):
            loc = loc[0, 0]                       # [Lmax+1(, B)]
            # Phase A: plain all_gather of local Map words on the cheap
            # intra-rack axis -> the rack's union buffer, on every member.
            rloc = jax.lax.all_gather(loc, "servers")   # [S, Lmax+1(, B)]
            rflat = rloc.reshape((-1,) + rloc.shape[2:])
            # Phase B: rack-level coded encode (replicated within the rack:
            # every member computes the same buffer from rflat - recompute
            # beats a leader branch) + one coded XOR all_gather on the
            # expensive inter-rack axis.
            el, esh, emk = enc_l[0], enc_shift[0], enc_mask[0]
            if encode == "jnp":
                slotw = (rflat[el] << bx(esh)) & bx(emk)
                coded = fold(slotw, 1, jnp.bitwise_xor)
            else:
                coded = xor_ops.xor_encode_slots(
                    rflat, el, esh, emk, use_kernel=use_kernel,
                    interpret=interpret)
            allbufs = jax.lax.all_gather(coded, "racks")    # [R, Wx(, B)]
            # zero col Wx appended via concatenate (not jnp.pad: the pad
            # scalar defeats the 0.4.x two-axis replication checker)
            allbufs = jnp.concatenate(
                [allbufs, jnp.zeros_like(allbufs[:, :1])], axis=1)
            got = allbufs[dec_rk[0, 0], dec_w[0, 0]]        # [Dmax, rr(, B)]
            if strip_f.shape[-1]:                           # rr > 1
                sw = ((rflat[strip_f[0, 0]] << bx(strip_shift[0, 0]))
                      & bx(strip_mask[0, 0]))
                strip = fold(sw, 2, jnp.bitwise_xor)
            else:
                strip = jnp.zeros_like(got)
            rec = (((got ^ strip) & bx(dec_mask[0, 0]))
                   >> bx(dec_shift[0, 0]))
            words = fold(rec, 1, jnp.bitwise_or)
            # Intra-only deliveries never crossed a rack: direct gather
            # from the phase-A buffer (mask 0 on inter deliveries).
            words = words | (rflat[direct_l[0, 0]] & bx(direct_mask[0, 0]))
            return words[None, None]              # [1, 1, Dmax(, B)]

        f = shard_map_compat(
            per_server, mesh=self.mesh,
            in_specs=(P("racks", "servers"),) + (P("racks"),) * 3
                     + (P("racks", "servers"),) * 9,
            out_specs=P("racks", "servers"), check=not use_kernel)
        return jax.jit(f)

    def exchange_words(self, edge_words: np.ndarray) -> np.ndarray:
        """One coded Shuffle on codec-order uint32 words.

        edge_words [nnz] -> recovered delivery words [M] in the plan's
        (k, i, j) order, bitwise equal to what `execute_coded_sparse`
        would deliver. The whole device computation is uint32 shift/mask/
        XOR - no float ops - which is what makes equality exact.

        Batched edge_words [nnz, B] -> [M, B]: one exchange moves all B
        payload columns (word arrays gain a trailing B axis; the jitted
        schedule tables are shared), column-b bitwise equal to the
        unbatched exchange of that column.
        """
        s = self.sched
        tr = get_tracer()
        ew = np.ascontiguousarray(edge_words, np.uint32)
        batched = ew.ndim == 2
        B = int(ew.shape[1]) if batched else 1
        with tr.span("phase.encode", backend="fused", B=B,
                     nnz=int(edge_words.shape[0])):
            if batched:
                if self._fn_batched is None:
                    build = self._build_hier if self._hier else self._build
                    self._fn_batched = build(self._encode, self._interpret,
                                             batched=True)
                ew = np.concatenate(
                    [ew, np.zeros((1, ew.shape[1]), np.uint32)], axis=0)
                loc = np.zeros((s.K, s.Lmax + 1, ew.shape[1]),
                               dtype=np.uint32)
                fn = self._fn_batched
            else:
                ew = np.append(ew, np.uint32(0))
                loc = np.zeros((s.K, s.Lmax + 1), dtype=np.uint32)
                fn = self._fn
            loc[:, :s.Lmax] = ew[s.loc_e]
            if self._hier:
                # device (rho, s) of the (racks, servers) mesh is server
                # rho * S + s, so the reshape is the identity placement
                loc = loc.reshape((self.topology.racks,
                                   self.topology.servers_per_rack)
                                  + loc.shape[1:])
        plan = self.plan
        bits = self._schedule_bits * B
        attrs = dict(backend="fused", bits=bits, B=B, K=s.K)
        if self._hier:
            attrs.update(inter_rack_bits=self.hplan.inter_rack_bits * B,
                         intra_rack_bits=self.hplan.intra_rack_bits * B)
        # Host-side timing around the jitted multi-device exchange: block
        # on the device buffers before stamping so the span covers the
        # collective's execution, not just its dispatch.
        with tr.span("phase.exchange", **attrs):
            dev = fn(jnp.asarray(loc), *self._dev_tables)
            jax.block_until_ready(dev)
        if self._hier:
            reg = get_registry()
            reg.counter("shuffle_inter_rack_bits_total",
                        "coded-Shuffle bits crossing rack boundaries") \
                .inc(self.hplan.inter_rack_bits * B)
            reg.counter("shuffle_intra_rack_bits_total",
                        "coded-Shuffle bits moving inside racks") \
                .inc(self.hplan.intra_rack_bits * B)
        with tr.span("phase.decode", backend="fused", B=B,
                     deliveries=int(plan.all_k.size)):
            out = np.asarray(dev)
            if self._hier:
                out = out.reshape((plan.K,) + out.shape[2:])
            M = plan.all_k.size
            return out[plan.all_k, np.arange(M, dtype=np.int64)
                       - plan.ptr[plan.all_k]]

    def execute(self, edge_vals: np.ndarray) -> PlanShuffleResult:
        """Drop-in peer of `ShufflePlan.execute_coded_sparse` (batched
        [nnz, B] edge values supported the same way)."""
        plan = self.plan
        edge_vals = np.asarray(edge_vals, np.float32)
        words = self.exchange_words(floats_to_words(edge_vals))
        bits = (self._schedule_bits
                * (edge_vals.shape[1] if edge_vals.ndim == 2 else 1))
        return PlanShuffleResult(plan.all_k, plan.all_i, plan.all_j,
                                 words_to_floats(words), plan.ptr, bits,
                                 plan.n)


def run_fused_sparse(g: Graph, edge_vals: np.ndarray, alloc: Allocation,
                     mesh: Mesh | None = None, *, encode: str = "xor-ref",
                     interpret: bool = True) -> PlanShuffleResult:
    """Convenience one-shot: compile + partition + one sparse exchange."""
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    ex = FusedSparseShuffle(plan, g.csr, alloc, mesh, encode=encode,
                            interpret=interpret)
    return ex.execute(edge_vals)


# ---------------------------------------------------------------------------
# Dense small-n validation reference
# ---------------------------------------------------------------------------


def build_schedule(g: Graph, alloc: Allocation,
                   plan: ShufflePlan | None = None):
    """Static (graph-dependent, data-independent) dense-reference schedule.

    Compiles the ShufflePlan once - adjacency-free via `compile_plan_csr`,
    so a CSR-native graph beyond `dense_limit` never materializes [n, n] -
    and lays its columns out per sender, padded to a common buffer length
    so the all_gather is dense. Returns numpy index tensors consumed by the
    jitted dense exchange (covered pairs only; leftovers are a sparse-path
    concern - see `partition_plan`).
    """
    K, r = alloc.K, alloc.r
    if plan is None:
        plan = compile_plan_csr(g.csr, alloc, validate=False)
    # Per-sender column order comes from the one shared layout rule
    # (`_sender_layout`), so the dense reference and the sparse partition
    # can never disagree on buffer positions.
    colpos, ncols = _sender_layout(plan)
    per_s: list[list[int]] = [[0] * int(ncols[s]) for s in range(K)]
    for c in range(plan.col_sender.size):
        per_s[int(plan.col_sender[c])][int(colpos[c])] = c
    width = int(ncols.max()) if ncols.size else 0

    P_pairs = plan.pair_k.size
    # Encode tensors: for slot t of server s, the XOR of values v[i,j] over
    # receivers. We express it as up-to-r (i, j) index pairs (-1 padded).
    enc_idx = np.full((K, width, r, 2), -1, dtype=np.int32)
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            for sl in range(r):
                p = int(plan.slot_pair[c, sl])
                if p == P_pairs:          # sentinel: empty slot
                    continue
                enc_idx[s, t, sl] = (plan.pair_i[p], plan.pair_j[p])
    # Decode map: receiver k strips every other member's value from the slot.
    # For each (sender s, slot t) useful to k: target (i, j) plus the strip
    # list; represent as target idx and r-1 strip idx pairs.
    dec: dict[int, list] = {k: [] for k in range(K)}
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            occupied = [sl for sl in range(r)
                        if int(plan.slot_pair[c, sl]) != P_pairs]
            for sl in occupied:
                p = int(plan.slot_pair[c, sl])
                k = int(plan.pair_k[p])
                strips = [(int(plan.pair_i[int(plan.slot_pair[c, sl2])]),
                           int(plan.pair_j[int(plan.slot_pair[c, sl2])]))
                          for sl2 in occupied if sl2 != sl]
                tgt = (int(plan.pair_i[p]), int(plan.pair_j[p]))
                dec[k].append((s, t, tgt, strips))
    dwidth = max((len(d) for d in dec.values()), default=0)
    dec_src = np.zeros((K, dwidth, 2), dtype=np.int32)       # (sender, slot)
    dec_tgt = np.full((K, dwidth, 2), -1, dtype=np.int32)    # (i, j)
    dec_strip = np.full((K, dwidth, r - 1, 2), -1, dtype=np.int32) \
        if r > 1 else np.zeros((K, dwidth, 0, 2), np.int32)
    for k, items in dec.items():
        for t, (s, slot_t, (i, j), strips) in enumerate(items):
            dec_src[k, t] = (s, slot_t)
            dec_tgt[k, t] = (i, j)
            for ri, (i2, j2) in enumerate(strips):
                dec_strip[k, t, ri] = (i2, j2)
    return enc_idx, dec_src, dec_tgt, dec_strip


def _as_words(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _as_floats(w):
    return jax.lax.bitcast_convert_type(w, jnp.float32)


def fused_exchange(values: jnp.ndarray, enc_idx, dec_src, dec_tgt, dec_strip,
                   mesh: Mesh):
    """One coded Shuffle as a single all_gather of packed XOR buffers.

    values [n, n] float32 (replicated Map output; each server only reads its
    own columns through the schedule indices). Returns [n, n] recovered
    missing values (0 where not delivered) - identical on every server.
    Validation reference only: the production path is `FusedSparseShuffle`.
    """
    words = _as_words(values)

    def per_server(enc_s, dec_src_s, dec_tgt_s, dec_strip_s):
        # enc_s [1, W, r, 2] on this shard.
        enc_s = enc_s[0]
        valid = enc_s[:, :, 0] >= 0
        vals = words[jnp.clip(enc_s[:, :, 0], 0), jnp.clip(enc_s[:, :, 1], 0)]
        buf = jnp.where(valid, vals, jnp.uint32(0))
        coded = jax.lax.reduce(buf, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        allbufs = jax.lax.all_gather(coded, "servers")       # [K, W]
        # Decode this server's targets.
        d_src, d_tgt, d_strip = dec_src_s[0], dec_tgt_s[0], dec_strip_s[0]
        got = allbufs[d_src[:, 0], d_src[:, 1]]
        sv = d_strip[:, :, 0] >= 0
        strip_vals = words[jnp.clip(d_strip[:, :, 0], 0),
                           jnp.clip(d_strip[:, :, 1], 0)]
        strip = jax.lax.reduce(jnp.where(sv, strip_vals, jnp.uint32(0)),
                               jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        rec = got ^ strip
        out = jnp.zeros(words.shape, jnp.uint32)
        tgt_ok = d_tgt[:, 0] >= 0
        out = out.at[jnp.clip(d_tgt[:, 0], 0),
                     jnp.clip(d_tgt[:, 1], 0)].set(
            jnp.where(tgt_ok, rec, jnp.uint32(0)))
        return jax.lax.psum(out, "servers")   # union of per-server recoveries

    f = shard_map_compat(per_server, mesh=mesh,
                         in_specs=(P("servers"), P("servers"), P("servers"),
                                   P("servers")),
                         out_specs=P())
    out_words = f(jnp.asarray(enc_idx), jnp.asarray(dec_src),
                  jnp.asarray(dec_tgt), jnp.asarray(dec_strip))
    return _as_floats(out_words)


def run_fused(g: Graph, values: np.ndarray, alloc: Allocation, mesh: Mesh):
    """Convenience wrapper: schedule + dense exchange; returns [n, n]."""
    sched = build_schedule(g, alloc)
    return fused_exchange(jnp.asarray(values, jnp.float32), *sched, mesh=mesh)
