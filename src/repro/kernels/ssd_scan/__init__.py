"""Pallas kernel package."""
