"""Batched multi-query throughput: queries/sec vs batch width B.

The coded Shuffle schedule is paid once per exchange regardless of how many
query payload columns ride it, so serving B concurrent queries as one
batched run must raise throughput: per-iteration wall-clock grows slower
than B (gather/reduce vectorize over the payload axis; the plan index
arithmetic is shared), while `shuffle_bits` grows exactly linearly - the
schedule never recompiles. This sweep measures both effects on one
`CompiledEngine` session, swapping a B-wide `personalized_pagerank` in per
width via `with_program` (asserting the plan object is literally reused),
then drives the same shape end to end through the `GraphService` admission
queue (threaded submit -> coalesce -> batched run -> futures).

The ``scale_batched_pagerank_*`` record is the CI-gated one
(`check_regression.py`): its wall-clock is the per-iteration time at the
widest B, and its derived string carries the full queries/sec-vs-B curve so
the committed baseline documents the amortization.
"""
import numpy as np

from repro import graphs, obs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.serve import GraphService

SMOKE = {"n": 360, "K": 4, "r": 2, "p": 0.05, "iters": 3,
         "widths": (1, 2, 4, 8)}
FULL = {"n": 2048, "K": 10, "r": 3, "p": 0.01, "iters": 10,
        "widths": (1, 2, 4, 8, 16, 32)}


def run(report, smoke=False):
    cfg = SMOKE if smoke else FULL
    n = divisible_n(cfg["n"], cfg["K"], cfg["r"])
    g = graphs.erdos_renyi(n, cfg["p"], seed=7)
    alloc = er_allocation(n, cfg["K"], cfg["r"])
    iters, widths = cfg["iters"], cfg["widths"]

    sess = engine.compile(
        algo.personalized_pagerank(algo.uniform_prefs(n)), g, alloc, "coded")
    plan = sess.plan
    sess.run(1)                                # warm CSR/degree/plan caches

    qps, last_dt, bits1 = [], 0.0, None
    for B in widths:
        s = sess.with_program(
            algo.personalized_pagerank(algo.uniform_prefs(n, B)))
        assert s.plan is plan, "batch width must not recompile the schedule"
        with obs.stopwatch() as sw:
            res = s.run(iters)
        last_dt = sw.s
        if bits1 is None:
            bits1 = res.shuffle_bits
        assert res.shuffle_bits == B * bits1, \
            "bits must scale with payload width only"
        qps.append(B * iters / last_dt)
        report(f"batched_pagerank_B{B}_n{n}", last_dt / iters * 1e6,
               f"qps={qps[-1]:.0f} bits={res.shuffle_bits} "
               f"s_per_iter={last_dt / iters:.4f}")
    # Amortization must be visible: the widest batch serves strictly more
    # queries per second than one-at-a-time execution.
    assert qps[-1] > qps[0], \
        f"no amortization: qps {qps[0]:.0f} -> {qps[-1]:.0f}"
    curve = " ".join(f"B{b}:{q:.0f}" for b, q in zip(widths, qps))
    report(f"scale_batched_pagerank_n{n}", last_dt / iters * 1e6,
           f"qps_per_B=[{curve}] amortization={qps[-1] / qps[0]:.1f}x "
           f"(one plan, one exchange/iter, bits = B x {bits1})")

    serve = _serve_throughput(report, g, alloc, n, widths[-1], smoke)
    return {"n": n, "widths": list(widths), "qps": qps, "serve": serve}


def _serve_throughput(report, g, alloc, n, max_batch, smoke):
    """End-to-end admission queue: threaded submits through GraphService."""
    rng = np.random.default_rng(0)
    iters = 3 if smoke else 5
    n_q = 2 * max_batch
    roots = rng.integers(0, n, size=n_q)
    with GraphService(g, alloc, max_batch=max_batch, max_wait_s=0.05) as svc:
        with obs.stopwatch() as sw:
            futs = [svc.submit("sssp", int(s), iters=iters) for s in roots]
            for f in futs:
                f.result(timeout=600)
        dt = sw.s
    stats = svc.stats
    report(f"serve_sssp_qps_n{n}", dt / n_q * 1e6,
           f"qps={n_q / dt:.0f} queries={stats.queries} "
           f"batches={stats.batches} mean_batch={stats.mean_batch:.1f} "
           f"bits_per_query={stats.bits_per_query:.0f}")
    return {"qps": n_q / dt, "mean_batch": stats.mean_batch}
