"""Pallas kernel micro-benchmarks (interpret mode on CPU: correctness-scale
numbers; the BlockSpec tiling is the TPU deliverable)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels.spmv import ops as spmv_ops
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.xor_code import ops as xor_ops


def _time(f, *args, reps=3):
    m = obs.measure(lambda: f(*args), reps=reps, warmup=1,
                    sync=jax.block_until_ready)
    return m.mean_us


def run(report, smoke=False):
    rng = np.random.default_rng(0)
    dim = 128 if smoke else 512
    adj = jnp.array((rng.random((dim, dim)) < 0.1), jnp.float32)
    x = jnp.array(rng.standard_normal(dim), jnp.float32)
    us_k = _time(lambda a, b: spmv_ops.spmv(a, b), adj, x)
    us_r = _time(lambda a, b: spmv_ops.spmv(a, b, use_kernel=False), adj, x)
    report(f"spmv_pallas_{dim}", us_k, f"ref_us={us_r:.0f}")

    cols = 256 if smoke else 1024
    rows = jnp.array(rng.integers(0, 2**32, (3, cols, 4), dtype=np.uint32))
    valid = jnp.array(rng.random((3, cols)) < 0.7)
    us_k = _time(lambda a, b: xor_ops.xor_encode(a, b), rows, valid)
    us_r = _time(lambda a, b: xor_ops.xor_encode(a, b, use_kernel=False),
                 rows, valid)
    report(f"xor_encode_pallas_{cols}", us_k, f"ref_us={us_r:.0f}")

    # The ShufflePlan batched route: [C, r] slot words through the kernel.
    slotw = jnp.array(rng.integers(0, 2**32, (cols, 3), dtype=np.uint32))
    us_k = _time(lambda a: xor_ops.xor_encode_columns(a), slotw)
    us_r = _time(lambda a: xor_ops.xor_encode_columns(a, use_kernel=False),
                 slotw)
    report(f"xor_encode_columns_pallas_{cols}", us_k, f"ref_us={us_r:.0f}")

    G, L, P, N = (2, 64, 8, 4) if smoke else (4, 256, 32, 16)
    args = (jnp.array(rng.standard_normal((G, L, P)), jnp.float32),
            jnp.array(rng.uniform(0.01, 0.2, (G, L)), jnp.float32),
            jnp.array(-rng.uniform(0.5, 2, G), jnp.float32),
            jnp.array(rng.standard_normal((G, L, N)), jnp.float32),
            jnp.array(rng.standard_normal((G, L, N)), jnp.float32),
            jnp.array(rng.standard_normal(G), jnp.float32))
    us_k = _time(lambda *a: ssd_ops.ssd(*a, chunk=min(L, 64))[0], *args)
    us_r = _time(lambda *a: ssd_ops.ssd(*a, use_kernel=False)[0], *args)
    report(f"ssd_chunk_pallas_{L}", us_k, f"seq_ref_us={us_r:.0f}")
