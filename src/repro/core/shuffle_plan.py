"""Compile-once / execute-many engine for the coded Shuffle (paper §IV-A).

The multicast schedule of the coded scheme is fixed by the graph realization
and the allocation alone - it never depends on the Map values. The legacy
reference (`coded_shuffle.run_coded`) nevertheless re-derives the per-group
need sets inside both encode and decode on *every* iteration, through
per-value Python dict bookkeeping. This module factors that schedule out:

  * `compile_plan(adj, alloc)` runs once and emits flat index arrays - the
    needed-value (pair) table, per-column sender/slot tables with pre-computed
    segment shifts and masks, per-receiver delivery segments, and the exact
    bit accounting (which is schedule-only, hence a compile-time constant).
  * `ShufflePlan.execute_*` replays the Shuffle for one iteration's values as
    a handful of vectorized uint32 gathers and XORs (NumPy fast path), or
    routes the column XOR-reduce through the `kernels/xor_code` Pallas kernel
    (`backend="xor-kernel"`) so the TPU path sees realistic batched tiles.

Everything is bit-exact against the literal reference; `tests/
test_shuffle_plan.py` asserts equality of delivered values AND bits sent.

Sparse execution: `edge_tables(csr, alloc)` binds a compiled plan to a CSR
view once - CSR entry indices for every scheduled value plus the per-server
reduce gather table - after which `execute_*_sparse` replay the Shuffle from
a [nnz] edge-value vector and the engine Reduces by segment without ever
materializing a dense [n, n] buffer (see `engine.py`).

Schedule derivation (why no subset enumeration is needed): a missing value
(i, j) of Reducer k has batch T = subsets[batch_of[j]] with k not in T, and
the unique (r+1)-group covering it is S = T u {k}. Enumerating the C(K, r+1)
groups is therefore equivalent to a single vectorized pass over the edges.
Batches whose subset size differs from r (the Appendix-A phase-III spill when
r > K2) are exactly the pairs no group covers - they become the unicast
leftovers, matching `engine._unicast_leftovers`.

Column/segment layout: each value is a codec-order uint32 word (see
`bitcodec.floats_to_words`); segment s travels left-aligned as
``(word << shift_s) & mask_s``. A coded column is the XOR of its <= r slot
words; a receiver strips the other slots (locally recomputable - it Mapped
those batches) and shifts its own segment back into place. Widths, hence
bits-on-the-wire, depend only on the schedule and are summed at compile time.
"""
from __future__ import annotations

import dataclasses
import functools

import numpy as np

from ..obs import get_tracer
from .allocation import Allocation
from .bitcodec import (T_BITS, floats_to_words, segment_bounds, segment_words,
                       words_to_floats)
from .graph_models import CSR, csr_delta_entries, merge_maps


def _batch_width(vals: np.ndarray) -> int:
    """Payload columns of a value array: 1 for [m], B for [m, B]."""
    return 1 if vals.ndim == 1 else int(vals.shape[1])


@dataclasses.dataclass
class PlanShuffleResult:
    """One executed Shuffle: delivery arrays (sorted by receiver) + load.

    Array-form counterpart of `uncoded_shuffle.ShuffleResult`; `delivered`
    materializes the legacy dict layout lazily for compatibility/tests.

    Batched execution (values [M, B]) delivers B independent query payloads
    through the one schedule; `bits_sent` then counts all B payload columns
    (B x the single-query schedule bits - the schedule itself never grows).
    """

    k: np.ndarray                # [M] int32 receiving server, ascending
    i: np.ndarray                # [M] int32 row index of the value
    j: np.ndarray                # [M] int32 column index of the value
    values: np.ndarray           # [M] (or [M, B]) float32 recovered values
    ptr: np.ndarray              # [K+1] CSR offsets into the arrays per server
    bits_sent: int
    n: int

    @property
    def batch(self) -> int:
        """Payload columns carried by this Shuffle (1 = unbatched)."""
        return 1 if self.values.ndim == 1 else int(self.values.shape[1])

    @property
    def normalized_load(self) -> float:
        """Definition 2, per query: bits / (B n^2 T)."""
        return self.bits_sent / (self.batch * self.n * self.n * T_BITS)

    @functools.cached_property
    def delivered(self) -> dict[int, dict[tuple[int, int], float]]:
        """Legacy per-value dict layout, built once and cached (tests and
        the coded-ref comparison path access it repeatedly)."""
        if self.values.ndim != 1:
            raise ValueError("delivered dict layout is single-query only; "
                             "index a batched result's values [M, B] instead")
        out: dict[int, dict[tuple[int, int], float]] = {
            k: {} for k in range(len(self.ptr) - 1)}
        for k, i, j, v in zip(self.k, self.i, self.j, self.values):
            out[int(k)][(int(i), int(j))] = float(v)
        return out


@dataclasses.dataclass(frozen=True)
class PlanEdgeTables:
    """CSR bindings of a compiled plan: every executor gather in O(edges).

    `pair_e`/`left_e`/`all_e` map each scheduled value to its CSR entry, so
    the sparse executors index a [nnz] edge-value vector instead of a dense
    [n, n] matrix. `gather` is the per-server reduce table flattened into
    canonical CSR entry order: entry e of row i (Reduced by k) reads from
    `concat(edge_vals, delivered.values)[gather[e]]` - the Map output when k
    Mapped column j locally, the delivery slot otherwise. Completeness of
    the schedule is re-verified edge-wise when the table is built.
    """

    pair_e: np.ndarray           # [P] int64 CSR entry of each covered pair
    left_e: np.ndarray           # [L] int64 CSR entry of each unicast leftover
    all_e: np.ndarray            # [M] int64 CSR entry of each delivered value
    gather: np.ndarray           # [nnz] int64 into concat(edge_vals, values)


def _locate_edges(csr: CSR, i: np.ndarray, j: np.ndarray) -> np.ndarray:
    """CSR entry index of each (i, j); raises if any pair is not an edge."""
    n = csr.n
    key = csr.rows.astype(np.int64) * n + csr.indices
    q = i.astype(np.int64) * n + j.astype(np.int64)
    e = np.searchsorted(key, q)
    ok = (e < key.size) & (key[np.minimum(e, key.size - 1)] == q)
    if not ok.all():
        bad = np.flatnonzero(~ok)[:5]
        raise RuntimeError(
            f"scheduled values are not edges of this CSR, e.g. pairs "
            f"{list(zip(i[bad].tolist(), j[bad].tolist()))}")
    return e


@dataclasses.dataclass(frozen=True)
class ShufflePlan:
    """The compiled coded-Shuffle schedule of one (graph, allocation) pair."""

    n: int
    K: int
    r: int
    # Needed-value table: group-covered (receiver, i, j) triples, sorted by
    # (group, receiver, i, j) - the legacy per-group argwhere order.
    pair_k: np.ndarray           # [P] int32
    pair_i: np.ndarray           # [P] int32
    pair_j: np.ndarray           # [P] int32
    # Column tables ([C] columns, <= r slots each). Slot entries are
    # pre-masked: invalid slots point at the sentinel pair P (zero word)
    # with mask 0, so encode is a plain gather-shift-mask-XOR.
    # col_width is None iff the plan was compiled with schedule=False
    # (missing set only); the coded executors then raise on use.
    col_width: np.ndarray | None  # [C] int64 column width in bits
    col_sender: np.ndarray       # [C] int32 multicasting server
    col_gm: np.ndarray           # [C] uint64 group membership bitmask
    col_rank: np.ndarray         # [C] int32 column index within (group, sender)
    slot_pair: np.ndarray        # [C, r] int64 pair index (P = sentinel)
    slot_shift: np.ndarray       # [C, r] uint32 segment left-shift
    slot_mask: np.ndarray        # [C, r] uint32 segment keep-mask (0 = empty)
    # Per-pair decode gather: segment t of pair p lives in column
    # pair_col[p, t] at slot pair_slot[p, t]; shift back by seg_shift[t].
    pair_col: np.ndarray         # [P, r] int64
    pair_slot: np.ndarray        # [P, r] int64
    seg_shift: np.ndarray        # [r] uint32
    # Unicast leftovers: missing pairs no (r+1)-group covers (batch subset
    # size != r, e.g. the Appendix-A phase-III spill).
    left_k: np.ndarray           # [L] int32
    left_i: np.ndarray           # [L] int32
    left_j: np.ndarray           # [L] int32
    # Full missing set (covered + leftovers) sorted by (k, i, j), plus the
    # positions the covered/leftover entries occupy in it and per-server CSR.
    all_k: np.ndarray            # [M] int32
    all_i: np.ndarray            # [M] int32
    all_j: np.ndarray            # [M] int32
    pos_covered: np.ndarray      # [P] int64 position of pair p in all_*
    pos_left: np.ndarray         # [L] int64
    ptr: np.ndarray              # [K+1] int64 CSR offsets by server

    # ---- compile-time load accounting (schedule-only, data-independent) ----

    @property
    def has_schedule(self) -> bool:
        """False for missing-set-only plans (compile_plan(schedule=False))."""
        return self.col_width is not None

    def check_alloc(self, alloc: Allocation) -> None:
        """Raise unless this plan was compiled for `alloc`'s (n, K, r) -
        the guard for entry points that accept a pre-compiled plan, so a
        stale plan reused across an r-sweep errors instead of silently
        reporting the wrong allocation's loads."""
        if (self.n, self.K, self.r) != (alloc.n, alloc.K, alloc.r):
            raise ValueError(
                f"plan was compiled for (n={self.n}, K={self.K}, "
                f"r={self.r}), allocation expects (n={alloc.n}, "
                f"K={alloc.K}, r={alloc.r})")

    def _require_schedule(self) -> None:
        if not self.has_schedule:
            raise ValueError(
                "plan was compiled with schedule=False (uncoded missing set "
                "only); recompile with schedule=True for the coded path")

    @property
    def coded_bits(self) -> int:
        """Multicast bits of one Shuffle (excludes unicast leftovers)."""
        self._require_schedule()
        return int(self.col_width.sum())

    @property
    def leftover_bits(self) -> int:
        return int(self.left_k.size) * T_BITS

    @property
    def uncoded_bits(self) -> int:
        return int(self.all_k.size) * T_BITS

    def coded_load(self) -> float:
        """Exact normalized coded load (legacy `coded_load` semantics)."""
        return self.coded_bits / (self.n * self.n * T_BITS)

    def uncoded_load(self) -> float:
        return self.uncoded_bits / (self.n * self.n * T_BITS)

    # ---- per-iteration executors ----

    def _slot_words(self, pair_vals: np.ndarray) -> np.ndarray:
        """Pre-masked left-aligned segment words for this iteration:
        [C, r] for single-query pair_vals [P], [C, r, B] for batched
        pair_vals [P, B] (the shift/mask tables are value-agnostic, so the
        payload axis just broadcasts behind them)."""
        words = floats_to_words(pair_vals)
        if words.ndim == 1:
            words = np.append(words, np.uint32(0))       # sentinel zero word
            return (words[self.slot_pair] << self.slot_shift) & self.slot_mask
        sentinel = np.zeros((1, words.shape[1]), dtype=np.uint32)
        words = np.concatenate([words, sentinel], axis=0)
        return ((words[self.slot_pair] << self.slot_shift[..., None])
                & self.slot_mask[..., None])

    def execute_coded(self, values: np.ndarray, *, backend: str = "numpy",
                      interpret: bool = True) -> PlanShuffleResult:
        """One bit-exact coded Shuffle (multicast groups + unicast leftovers).

        backend:
          "numpy"      - vectorized uint32 XOR (fast path).
          "xor-kernel" - column XOR-reduce through the Pallas xor_code kernel.
          "xor-ref"    - same route through the jnp reference (kernel oracle).
        """
        self._require_schedule()
        return self._coded_result(values[self.pair_i, self.pair_j],
                                  values[self.left_i, self.left_j],
                                  backend=backend, interpret=interpret)

    def _coded_result(self, pair_vals: np.ndarray, left_vals: np.ndarray, *,
                      backend: str = "numpy",
                      interpret: bool = True) -> PlanShuffleResult:
        """Coded encode/decode from already-gathered scheduled values.

        Batched pair_vals [P, B] / left_vals [L, B] ride the identical
        schedule with a trailing payload axis: every shift/mask/XOR below is
        elementwise per payload column, so column b of the batched result is
        bitwise the single-query result of that column's values, and the
        bits-on-the-wire are exactly B x the schedule bits.
        """
        batched = pair_vals.ndim == 2
        tr = get_tracer()
        B = int(pair_vals.shape[1]) if batched else 1
        with tr.span("phase.encode", backend=backend, B=B,
                     words=int(self.col_width.size)):
            slotw = self._slot_words(pair_vals)
            if backend == "numpy":
                coded = np.bitwise_xor.reduce(slotw, axis=1)
                # Receiver's strip = XOR of the other slots (locally
                # recomputable: it Mapped those batches).
                strip = coded[:, None] ^ slotw
            elif backend in ("xor-kernel", "xor-ref"):
                from ..kernels.xor_code import ops as xor_ops
                use_kernel = backend == "xor-kernel"
                coded = np.asarray(xor_ops.xor_encode_columns(
                    slotw, use_kernel=use_kernel, interpret=interpret))
                strip = np.asarray(xor_ops.xor_strip_columns(
                    slotw, use_kernel=use_kernel, interpret=interpret))
            else:
                raise ValueError(f"unknown backend {backend!r}")
        bits = (self.coded_bits + self.leftover_bits) * B
        # In-process execution moves no real bytes, so the exchange span is
        # an instant stamp carrying the schedule's bits-on-the-wire; the
        # fused backend times an actual multi-device collective here.
        with tr.span("phase.exchange", bits=bits, B=B,
                     words=int(coded.shape[0])):
            pass
        with tr.span("phase.decode", B=B, pairs=int(self.pair_k.size)):
            mask = self.slot_mask[..., None] if batched else self.slot_mask
            seg_shift = (self.seg_shift[None, :, None] if batched
                         else self.seg_shift[None, :])
            rec = (coded[:, None] ^ strip) & mask
            # Gather each pair's r recovered segments and shift into place.
            segs = rec[self.pair_col, self.pair_slot] >> seg_shift
            pair_words = np.bitwise_or.reduce(segs, axis=1)
            out = np.empty((self.all_k.size,) + pair_vals.shape[1:],
                           dtype=np.float32)
            out[self.pos_covered] = words_to_floats(pair_words)
            out[self.pos_left] = left_vals
        return PlanShuffleResult(self.all_k, self.all_i, self.all_j, out,
                                 self.ptr, bits, self.n)

    def _direct_result(self, vals: np.ndarray, bits: int) -> PlanShuffleResult:
        out = np.ascontiguousarray(vals, np.float32)
        total = bits * _batch_width(out)
        with get_tracer().span("phase.exchange", bits=total,
                               B=_batch_width(out), values=int(out.shape[0])):
            pass
        return PlanShuffleResult(self.all_k, self.all_i, self.all_j, out,
                                 self.ptr, total, self.n)

    def execute_fast(self, values: np.ndarray) -> PlanShuffleResult:
        """Coded loads with direct value movement (legacy "coded-fast")."""
        self._require_schedule()
        return self._direct_result(values[self.all_i, self.all_j],
                                   self.coded_bits)

    def execute_uncoded(self, values: np.ndarray) -> PlanShuffleResult:
        """Baseline unicast Shuffle off the same compiled missing set."""
        return self._direct_result(values[self.all_i, self.all_j],
                                   self.uncoded_bits)

    def execute(self, values: np.ndarray, mode: str) -> PlanShuffleResult:
        if mode == "coded":
            return self.execute_coded(values)
        if mode == "coded-fast":
            return self.execute_fast(values)
        if mode == "uncoded":
            return self.execute_uncoded(values)
        raise ValueError(f"unknown plan mode {mode!r}")

    # ---- sparse (O(edges)) executors ----

    def edge_tables(self, csr: CSR, alloc: Allocation) -> PlanEdgeTables:
        """Bind this plan to a CSR view (cached on the plan).

        Locates every scheduled value's CSR entry and builds the reduce
        gather table (see `PlanEdgeTables`); raises if any Reducer would be
        left without a source for one of its edges - the edge-wise
        counterpart of the compile-time `_validate` scan.
        """
        cached = self.__dict__.get("_edge_tables")
        if cached is not None:
            c_csr, c_alloc, tables = cached
            if c_csr is csr and c_alloc is alloc:
                return tables
            # Re-bound to a different (csr, alloc): rebuild rather than
            # silently serving stale gather tables.
        pair_e = _locate_edges(csr, self.pair_i, self.pair_j)
        left_e = _locate_edges(csr, self.left_i, self.left_j)
        all_e = _locate_edges(csr, self.all_i, self.all_j)
        # Reduce gather: local Map output where the owner Mapped the source,
        # the (k, i, j)-sorted delivery slot otherwise.
        n = np.int64(self.n)
        owners = alloc.reduce_owner[csr.rows]
        local = alloc.map_sets[owners, csr.indices]
        gather = np.arange(csr.nnz, dtype=np.int64)
        missing = ~local
        all_key = ((self.all_k.astype(np.int64) * n + self.all_i) * n
                   + self.all_j)
        need_key = ((owners[missing].astype(np.int64) * n
                     + csr.rows[missing]) * n + csr.indices[missing])
        pos = np.searchsorted(all_key, need_key)
        ok = (pos < all_key.size) & (all_key[np.minimum(pos, all_key.size - 1)]
                                     == need_key)
        if not ok.all():
            miss = np.flatnonzero(missing)[~ok][:5]
            raise RuntimeError(
                f"schedule incomplete: no delivery for CSR entries "
                f"{list(zip(csr.rows[miss].tolist(), csr.indices[miss].tolist()))}")
        gather[missing] = csr.nnz + pos
        tables = PlanEdgeTables(pair_e, left_e, all_e, gather)
        self.__dict__["_edge_tables"] = (csr, alloc, tables)
        return tables

    def execute_coded_sparse(self, edge_vals: np.ndarray,
                             tables: PlanEdgeTables, *,
                             backend: str = "numpy",
                             interpret: bool = True) -> PlanShuffleResult:
        """Coded Shuffle from a [nnz] edge-value vector; bit-exact against
        `execute_coded` on the dense scatter of the same values. Batched
        edge_vals [nnz, B] carry B query payloads through the one schedule
        (values [M, B] out, bits = B x schedule bits)."""
        self._require_schedule()
        return self._coded_result(edge_vals[tables.pair_e],
                                  edge_vals[tables.left_e],
                                  backend=backend, interpret=interpret)

    def execute_fast_sparse(self, edge_vals: np.ndarray,
                            tables: PlanEdgeTables) -> PlanShuffleResult:
        self._require_schedule()
        return self._direct_result(edge_vals[tables.all_e], self.coded_bits)

    def execute_uncoded_sparse(self, edge_vals: np.ndarray,
                               tables: PlanEdgeTables) -> PlanShuffleResult:
        return self._direct_result(edge_vals[tables.all_e], self.uncoded_bits)

    def execute_sparse(self, edge_vals: np.ndarray, mode: str,
                       tables: PlanEdgeTables) -> PlanShuffleResult:
        if mode == "coded":
            return self.execute_coded_sparse(edge_vals, tables)
        if mode == "coded-fast":
            return self.execute_fast_sparse(edge_vals, tables)
        if mode == "uncoded":
            return self.execute_uncoded_sparse(edge_vals, tables)
        raise ValueError(f"unknown plan mode {mode!r}")

    # ---- coded degraded-mode repair ----

    def repair(self, csr: CSR, alloc: Allocation, failed):
        """Survivors' coded schedule after `failed` servers die, by patching.

        Returns ``(plan, degraded_alloc, stats)`` where `plan` is the coded
        schedule of the degraded allocation (`faults.degrade_allocation`) and
        `stats` is a `faults.RepairStats`. Instead of recompiling over all
        edges, the repair splices two streams into `_compile_missing`:

          * kept entries - the original plan's deliveries whose receiver
            survived (minus any a recovery re-Map made locally available),
          * orphan-row entries - the CSR rows of the failed servers' Reduce
            partitions, recomputed against their new owners' Map sets,

        which is O(plan + edges in failed rows), and then patches the column
        sender table: a column whose sender died is handed to the
        lexicographically-first healthy member s' of its (r+1)-group (s'
        Mapped every batch in the column except its own receiver's, so it can
        re-encode the same bits; the s'-destined segments it cannot XOR are
        unicast by a third healthy member and accounted as
        `stats.handover_bits`). Pairs whose group keeps < 2 healthy members
        (possible only when |failed| >= r) are demoted to unicast leftovers.

        Contract (locked by `tests/test_faults.py`): for |failed| < r the
        repaired plan is schedule-equal to a fresh `compile_plan_csr` on the
        degraded allocation - identical arrays except `col_sender`, which
        fresh compilation would still point at dead servers - and its
        executors deliver bitwise-identical words. Composition works too:
        repairing an already-degraded (plan, alloc) treats every server with
        an empty Map row as dead when choosing stand-ins.
        """
        with get_tracer().span(
                "plan.repair",
                failed=",".join(str(f) for f in sorted(
                    {int(f) for f in np.atleast_1d(np.asarray(failed))}))) \
                as rsp:
            return self._repair(csr, alloc, failed, rsp)

    def _repair(self, csr: CSR, alloc: Allocation, failed, rsp):
        from .faults import RepairStats, degrade_allocation

        self._require_schedule()
        self.check_alloc(alloc)
        if csr.n != self.n:
            raise ValueError(
                f"CSR has n={csr.n}, plan was compiled for n={self.n}")
        failed = tuple(sorted({int(f) for f in failed}))
        if any(not 0 <= f < self.K for f in failed):
            raise ValueError(f"failed servers {failed} out of range "
                             f"[0, {self.K})")
        degraded, dstats = degrade_allocation(alloc, failed)

        # Kept deliveries: surviving receivers, minus entries a recovery
        # re-Map (|failed| >= r only) just made locally available.
        keep = ~np.isin(self.all_k, failed)
        keep &= ~degraded.map_sets[self.all_k, self.all_j]
        kk, ii, jj = self.all_k[keep], self.all_i[keep], self.all_j[keep]

        # Orphan rows (Reduce partitions of the dead): recompute their
        # missing entries against the new owners' Map sets from the CSR.
        orows = np.flatnonzero(np.isin(alloc.reduce_owner, failed))
        if orows.size:
            starts = csr.indptr[orows]
            counts = csr.indptr[orows + 1] - starts
            total = int(counts.sum())
            offs = np.zeros(orows.size, dtype=np.int64)
            np.cumsum(counts[:-1], out=offs[1:])
            e = np.arange(total, dtype=np.int64) \
                + np.repeat(starts - offs, counts)
            oi = np.repeat(orows, counts).astype(np.int32)
            oj = csr.indices[e].astype(np.int32)
            ok = degraded.reduce_owner[oi].astype(np.int32)
            miss = ~degraded.map_sets[ok, oj]
            kk = np.concatenate([kk, ok[miss]])
            ii = np.concatenate([ii, oi[miss]])
            jj = np.concatenate([jj, oj[miss]])

        # Healthy = still holds its Map shard (handles repair-of-repaired:
        # servers that died in an earlier epoch have all-False rows).
        alive = degraded.map_sets.any(axis=1)
        alive_mask = int(sum(1 << k for k in np.flatnonzero(alive)))
        plan = _compile_missing(ii, jj, kk, degraded, True,
                                survivors=alive_mask)
        natural_left = int((np.array(
            [len(s) for s in alloc.subsets])[alloc.batch_of[jj]]
            != self.r).sum())
        demoted = int(plan.left_k.size) - natural_left

        handover_bits = _patch_senders(plan, np.uint64(alive_mask))
        stats = RepairStats(failed=failed,
                            remapped_vertices=dstats.remapped_vertices,
                            handover_bits=handover_bits,
                            demoted_pairs=demoted)
        _stamp_plan(rsp, plan, int(csr.nnz))
        rsp.set(handover_bits=handover_bits, demoted_pairs=demoted,
                remapped_vertices=dstats.remapped_vertices)
        return plan, degraded, stats

    # ---- dynamic graphs: O(delta) incremental maintenance ----

    def apply_delta(self, csr: CSR, alloc: Allocation, delta, *,
                    csr_new: CSR | None = None):
        """Incrementally recompile this plan for one `EdgeDelta` batch.

        Returns ``(plan, stats)`` where `plan` is the schedule of the
        mutated graph and `stats` a `DeltaStats`. `csr` is the CSR this
        plan was compiled against (pre-mutation); pass the mutated view as
        `csr_new` (from `CSR.apply_delta`) to also carry the cached edge
        tables forward incrementally - the new plan then binds to `csr_new`
        without the O(nnz log nnz) `_locate_edges` rebuild.

        Cost is O(plan + delta) with **no sorting pass** over plan-sized
        arrays: the delta's missing triples are classified exactly as
        `_compile_missing` classifies them (covered / leftover, with the
        same survivors demotion when `alloc` is degraded), spliced into the
        already-sorted pair / leftover / delivery streams by sorted merge,
        and the column + slot tables are rebuilt from the merged pair
        stream in closed form (`_schedule_from_pairs`) - deleted edges
        drop their slots, inserted edges land where a fresh lexsort would
        have put them, so splice order is irrelevant by construction.

        Contract (locked by `tests/test_delta_plan.py`, the PR 7 rule):
        the result is array-identical to a fresh `compile_plan_csr` on the
        mutated graph - every field bitwise equal, `col_sender` included
        for a healthy allocation. For a degraded allocation the usual
        `repair` exception applies: `col_sender` is re-patched to healthy
        stand-ins (fresh compilation would still point at dead servers)
        and `stats.handover_bits` is the re-patched unicast total.
        Composes both ways with `repair` (delta-then-fail, fail-then-delta).
        """
        with get_tracer().span(
                "plan.apply_delta", inserts=delta.num_insert,
                deletes=delta.num_delete) as sp:
            return self._apply_delta(csr, alloc, delta, csr_new, sp)

    def _apply_delta(self, csr, alloc, delta, csr_new, sp):
        self.check_alloc(alloc)
        if csr.n != self.n:
            raise ValueError(
                f"CSR has n={csr.n}, plan was compiled for n={self.n}")
        if delta.n != self.n:
            raise ValueError(
                f"delta is bound to n={delta.n}, plan to n={self.n}")
        n = np.int64(self.n)
        K, r = self.K, self.r

        # Classify the delta's missing triples with the same rules (and the
        # same survivors demotion) a fresh compile on `alloc` would apply.
        alive = alloc.map_sets.any(axis=1)
        survivors = (None if bool(alive.all())
                     else int(sum(1 << k for k in np.flatnonzero(alive))))
        ins = _delta_stream(delta.insert, alloc, survivors)
        dels = _delta_stream(delta.delete, alloc, survivors)
        changed = bool(ins.ak.size or dels.ak.size)

        # Full delivery stream: one sorted merge, shared by both flavors.
        # The stream's (k, i, j) keys are cached on the plan and carried to
        # the result by splice, so repeated updates never rebuild them.
        M = self.all_k.size
        akey = self.__dict__.get("_delta_akey")
        if akey is None:
            akey = ((self.all_k.astype(np.int64) * n + self.all_i) * n
                    + self.all_j)
            self.__dict__["_delta_akey"] = akey
        ikey_a = (ins.ak.astype(np.int64) * n + ins.ai) * n + ins.aj
        dap = _splice_points(
            akey, (dels.ak.astype(np.int64) * n + dels.ai) * n + dels.aj,
            "delivery", expect_present=True)
        iap = _splice_points(akey, ikey_a, "delivery", expect_present=False)
        new_old_a, new_ins_a, M2 = merge_maps(M, dap, iap)
        tgt_a = new_old_a.copy()
        tgt_a[dap] = M2                  # deleted deliveries -> trash slot
        # The stream is (k, i, j)-sorted, so the k column stays a sorted
        # run-length encoding: bump the run bounds by the per-server
        # insert/delete counts and repeat - no splice, no index traffic.
        ptr2 = self.ptr + np.concatenate(
            [[0], np.cumsum(np.bincount(ins.ak, minlength=K)
                            - np.bincount(dels.ak, minlength=K))])
        all_k2 = np.repeat(np.arange(K, dtype=self.all_k.dtype),
                           np.diff(ptr2))
        all_i2 = _splice(self.all_i, tgt_a, ins.ai, new_ins_a, M2)
        all_j2 = _splice(self.all_j, tgt_a, ins.aj, new_ins_a, M2)

        if not self.has_schedule:
            # Missing-set-only plan: the delivery stream IS the plan.
            e64 = np.zeros(0, dtype=np.int64)
            pmaps = (e64, e64, 0, e64, e64, 0)
            empty = np.zeros(0, np.int32)
            plan2 = ShufflePlan(
                n=self.n, K=K, r=r,
                pair_k=empty, pair_i=empty, pair_j=empty,
                col_width=None, col_sender=empty,
                col_gm=np.zeros(0, np.uint64), col_rank=empty,
                slot_pair=np.zeros((0, r), np.int64),
                slot_shift=np.zeros((0, r), np.uint32),
                slot_mask=np.zeros((0, r), np.uint32),
                pair_col=np.zeros((0, r), np.int64),
                pair_slot=np.zeros((0, r), np.int64),
                seg_shift=segment_words(r)[0],
                left_k=empty, left_i=empty, left_j=empty,
                all_k=all_k2, all_i=all_i2, all_j=all_j2,
                pos_covered=np.zeros(0, np.int64),
                pos_left=np.arange(M2, dtype=np.int64), ptr=ptr2)
        else:
            plan2, pmaps = self._merge_scheduled(
                alloc, n, ins, dels, changed,
                all_k2, all_i2, all_j2, ptr2,
                new_old_a, new_ins_a)
        # The (k, i, j) key cache is rebuilt lazily by the next update
        # (same O(stream) cost as splicing it here, but deferred off this
        # call's critical path - single updates never pay it).

        handover = 0
        if changed and self.has_schedule and survivors is not None:
            handover = _patch_senders(plan2, np.uint64(survivors))
        stats = DeltaStats(
            inserted_edges=delta.num_insert, deleted_edges=delta.num_delete,
            inserted_values=int(ins.ak.size),
            deleted_values=int(dels.ak.size),
            demoted_pairs=ins.demoted, handover_bits=handover,
            schedule_changed=changed)

        # Carry the cached CSR binding forward without re-locating edges.
        if csr_new is not None:
            cached = self.__dict__.get("_edge_tables")
            if (cached is not None and cached[0] is csr
                    and cached[1] is alloc):
                tables2 = _delta_edge_tables(
                    cached[2], csr, csr_new, delta, ins,
                    self.has_schedule, *pmaps,
                    tgt_a, new_old_a, new_ins_a, M2)
                plan2.__dict__["_edge_tables"] = (csr_new, alloc, tables2)
        _stamp_plan(sp, plan2,
                    int((csr if csr_new is None else csr_new).nnz))
        sp.set(inserted_values=stats.inserted_values,
               deleted_values=stats.deleted_values,
               demoted_pairs=stats.demoted_pairs, handover_bits=handover)
        return plan2, stats

    def _merge_scheduled(self, alloc, n, ins, dels, changed,
                         all_k2, all_i2, all_j2, ptr2,
                         new_old_a, new_ins_a):
        """Covered-pair + leftover splice and the closed-form column
        rebuild, for plans that carry a coded schedule."""
        K, r = self.K, self.r
        P, L = self.pair_k.size, self.left_k.size
        # Group masks and (k, i, j) keys of the pair stream are cached on
        # the plan (masks per allocation - a degraded allocation regroups)
        # and carried to the result by splice.
        gm_cached = self.__dict__.get("_delta_pair_gm")
        if gm_cached is not None and gm_cached[0] is alloc:
            pair_gm = gm_cached[1]
        else:
            subset_mask = np.array(
                [sum(1 << s for s in S) for S in alloc.subsets],
                dtype=np.uint64)
            pair_gm = (subset_mask[alloc.batch_of[self.pair_j]]
                       | (np.uint64(1) << self.pair_k.astype(np.uint64)))
            self.__dict__["_delta_pair_gm"] = (alloc, pair_gm)
        pkey = self.__dict__.get("_delta_pkey")
        if pkey is None:
            pkey = ((self.pair_k.astype(np.int64) * n + self.pair_i) * n
                    + self.pair_j)
            self.__dict__["_delta_pkey"] = pkey
        ikey_p = (ins.ck.astype(np.int64) * n + ins.ci) * n + ins.cj
        dpp = _pair_splice_points(
            pair_gm, pkey, dels.cgm,
            (dels.ck.astype(np.int64) * n + dels.ci) * n + dels.cj,
            expect_present=True)
        ipp = _pair_splice_points(pair_gm, pkey, ins.cgm, ikey_p,
                                  expect_present=False)
        new_old_p, new_ins_p, P2 = merge_maps(P, dpp, ipp)
        tgt_p = new_old_p               # new_old_p unused beyond targeting
        tgt_p[dpp] = P2                 # deleted pairs -> trash slot
        pair_k2 = _splice(self.pair_k, tgt_p, ins.ck, new_ins_p, P2)
        pair_i2 = _splice(self.pair_i, tgt_p, ins.ci, new_ins_p, P2)
        pair_j2 = _splice(self.pair_j, tgt_p, ins.cj, new_ins_p, P2)
        pair_gm2 = _splice(pair_gm, tgt_p, ins.cgm, new_ins_p, P2)

        lkey = self.__dict__.get("_delta_lkey")
        if lkey is None:
            lkey = ((self.left_k.astype(np.int64) * n + self.left_i) * n
                    + self.left_j)
            self.__dict__["_delta_lkey"] = lkey
        ikey_l = (ins.lk.astype(np.int64) * n + ins.li) * n + ins.lj
        dlp = _splice_points(
            lkey, (dels.lk.astype(np.int64) * n + dels.li) * n + dels.lj,
            "leftover", expect_present=True)
        ilp = _splice_points(lkey, ikey_l, "leftover", expect_present=False)
        new_old_l, new_ins_l, L2 = merge_maps(L, dlp, ilp)
        tgt_l = new_old_l               # new_old_l unused beyond targeting
        tgt_l[dlp] = L2                 # deleted leftovers -> trash slot
        # (k, i, j)-sorted like the delivery stream: rebuild the k column
        # as a run-length repeat instead of splicing it.
        lptr = np.searchsorted(self.left_k, np.arange(K + 1))
        left_k2 = np.repeat(
            np.arange(K, dtype=self.left_k.dtype),
            np.diff(lptr) + np.bincount(ins.lk, minlength=K)
            - np.bincount(dels.lk, minlength=K))
        left_i2 = _splice(self.left_i, tgt_l, ins.li, new_ins_l, L2)
        left_j2 = _splice(self.left_j, tgt_l, ins.lj, new_ins_l, L2)

        # Deleted elements read garbage renumbers here; their trash-marked
        # targets discard the writes.
        pos_covered2 = _splice(new_old_a[self.pos_covered], tgt_p,
                               new_ins_a[ins.cpos_in_a], new_ins_p, P2)
        pos_left2 = _splice(new_old_a[self.pos_left], tgt_l,
                            new_ins_a[ins.lpos_in_a], new_ins_l, L2)

        if changed:
            (col_width, col_sender, col_gm, col_rank, slot_pair,
             slot_shift, slot_mask, pair_col, pair_slot) = \
                _schedule_from_pairs(pair_k2, pair_gm2, r)
        else:
            # Pair stream untouched: every column table is value-identical,
            # share the arrays (col_sender keeps any earlier repair patch).
            col_width, col_sender = self.col_width, self.col_sender
            col_gm, col_rank = self.col_gm, self.col_rank
            slot_pair, slot_shift = self.slot_pair, self.slot_shift
            slot_mask = self.slot_mask
            pair_col, pair_slot = self.pair_col, self.pair_slot
        plan2 = ShufflePlan(
            n=self.n, K=K, r=r,
            pair_k=pair_k2, pair_i=pair_i2, pair_j=pair_j2,
            col_width=col_width, col_sender=col_sender, col_gm=col_gm,
            col_rank=col_rank, slot_pair=slot_pair, slot_shift=slot_shift,
            slot_mask=slot_mask, pair_col=pair_col, pair_slot=pair_slot,
            seg_shift=segment_words(r)[0],
            left_k=left_k2, left_i=left_i2, left_j=left_j2,
            all_k=all_k2, all_i=all_i2, all_j=all_j2,
            pos_covered=pos_covered2, pos_left=pos_left2, ptr=ptr2)
        # pair_gm2 exists anyway (schedule input), so carrying it is free;
        # the pair/leftover key caches rebuild lazily on the next update.
        plan2.__dict__["_delta_pair_gm"] = (alloc, pair_gm2)
        return plan2, (tgt_p, new_ins_p, P2, tgt_l, new_ins_l, L2)


def _run_ranks(*keys: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Per-element run id and rank-within-run of already-sorted key arrays."""
    m = keys[0].size
    if m == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    new = np.zeros(m, dtype=bool)
    new[0] = True
    for key in keys:
        new[1:] |= key[1:] != key[:-1]
    run = np.cumsum(new) - 1
    starts = np.flatnonzero(new)
    counts = np.diff(np.append(starts, m))
    rank = np.arange(m) - np.repeat(starts, counts)
    return run, rank


def compile_plan(adj: np.ndarray, alloc: Allocation,
                 validate: bool = True,
                 schedule: bool = True) -> ShufflePlan:
    """Compile the full coded-Shuffle schedule of (adj, alloc); see module doc.

    One vectorized pass over the edges replaces the C(K, r+1) subset
    enumeration of the legacy reference; the result is bit-exact equivalent.

    `schedule=False` compiles only the missing set + per-server CSR (all the
    uncoded executor needs), skipping the column/slot table construction;
    the coded executors and load accounting then raise on use.

    Adjacency-free entry point: `compile_plan_csr` compiles the *identical*
    plan (same bits, same slot arrays) straight from a CSR view - the edge
    pass below only consumes (row, column) streams, and `np.nonzero(adj)`
    order is exactly the canonical CSR entry order.
    """
    with get_tracer().span("plan.compile", entry="dense", n=alloc.n,
                           K=alloc.K, r=alloc.r) as sp:
        ii, jj = np.nonzero(adj)
        plan = _compile_edges(ii, jj, alloc, schedule)
        if validate:
            _validate(plan, adj, alloc)
        _stamp_plan(sp, plan, int(ii.size))
    return plan


def compile_plan_csr(csr: CSR, alloc: Allocation,
                     validate: bool = True,
                     schedule: bool = True) -> ShufflePlan:
    """Compile the coded-Shuffle schedule from a CSR view, adjacency-free.

    Schedule-identical (every plan array bitwise equal) to
    `compile_plan(adj, alloc)` on the dense scatter of the same graph, but
    never touches an [n, n] buffer - O(edges) time and memory, the entry
    point the engine uses so CSR-native graphs at n >= 1e5 compile plans
    without the dense view ever existing.
    """
    if csr.n != alloc.n:
        raise ValueError(
            f"graph has n={csr.n} vertices but the allocation expects "
            f"n={alloc.n}; pad the graph with virtual isolated vertices "
            f"first (Graph.padded / er_allocation(..., pad=True))")
    with get_tracer().span("plan.compile", entry="csr", n=alloc.n,
                           K=alloc.K, r=alloc.r) as sp:
        plan = _compile_edges(csr.rows, csr.indices, alloc, schedule)
        if validate:
            _validate_csr(plan, csr, alloc)
        _stamp_plan(sp, plan, int(csr.nnz))
    return plan


def _stamp_plan(sp, plan: ShufflePlan, edges: int) -> None:
    """Attach plan-size attributes to a compile/repair span."""
    sp.set(edges=edges, deliveries=int(plan.all_k.size),
           pairs=int(plan.pair_k.size), leftovers=int(plan.left_k.size))
    if plan.has_schedule:
        sp.set(columns=int(plan.col_width.size), coded_bits=plan.coded_bits)


def _compile_edges(ii: np.ndarray, jj: np.ndarray, alloc: Allocation,
                   schedule: bool) -> ShufflePlan:
    """Shared compiler body: one vectorized pass over the (row, col) edge
    streams, which both the dense and the CSR entry points supply in the
    same canonical order."""
    # --- missing triples, edge-driven ---
    kk = alloc.reduce_owner[ii].astype(np.int32)
    miss = ~alloc.map_sets[kk, jj]
    return _compile_missing(ii[miss].astype(np.int32),
                            jj[miss].astype(np.int32), kk[miss],
                            alloc, schedule)


def _compile_missing(ii: np.ndarray, jj: np.ndarray, kk: np.ndarray,
                     alloc: Allocation, schedule: bool,
                     survivors: int | None = None) -> ShufflePlan:
    """Build a plan from an explicit missing-triple stream (any order).

    Everything downstream is lexsorted, so the output arrays depend only on
    the *set* of (receiver, i, j) triples - which is what lets
    `ShufflePlan.repair` splice kept entries and recomputed orphan-row
    entries together and still land bitwise on the fresh-compile schedule.

    `survivors` (a bitmask of servers still holding their Map shards)
    demotes every covered pair whose (r+1)-group retains fewer than two
    healthy members to a unicast leftover: with < 2 healthy senders the
    straggler hand-over rule has nobody to stand in, so those pairs are
    unrecoverable as coded multicast (only reachable when |failed| >= r).
    """
    K, r, n = alloc.K, alloc.r, alloc.n
    if K > 64:
        raise NotImplementedError("group bitmasks require K <= 64")
    seg_shift, seg_mask = segment_words(r)
    bb = alloc.batch_of[jj]

    if not schedule:                # missing-set-only plan (uncoded shuffle)
        order = np.lexsort((jj, ii, kk))
        all_k, all_i, all_j = kk[order], ii[order], jj[order]
        M = all_k.size
        empty = np.zeros(0, np.int32)
        return ShufflePlan(
            n=n, K=K, r=r,
            pair_k=empty, pair_i=empty, pair_j=empty,
            col_width=None, col_sender=empty,
            col_gm=np.zeros(0, np.uint64), col_rank=empty,
            slot_pair=np.zeros((0, r), np.int64),
            slot_shift=np.zeros((0, r), np.uint32),
            slot_mask=np.zeros((0, r), np.uint32),
            pair_col=np.zeros((0, r), np.int64),
            pair_slot=np.zeros((0, r), np.int64), seg_shift=seg_shift,
            left_k=empty, left_i=empty, left_j=empty,
            all_k=all_k, all_i=all_i, all_j=all_j,
            pos_covered=np.zeros(0, np.int64),
            pos_left=np.arange(M, dtype=np.int64),
            ptr=np.searchsorted(all_k, np.arange(K + 1)).astype(np.int64))

    subset_size = np.array([len(s) for s in alloc.subsets], dtype=np.int64)
    subset_mask = np.array([sum(1 << s for s in S) for S in alloc.subsets],
                           dtype=np.uint64)
    covered = subset_size[bb] == r
    gm = subset_mask[bb] | (np.uint64(1) << kk.astype(np.uint64))
    if survivors is not None:
        healthy = np.bitwise_count(gm & np.uint64(survivors))
        covered &= healthy >= 2

    # Leftovers: no (r+1)-group exists for these; unicast (phase-III spill).
    lsel = ~covered
    lorder = np.lexsort((jj[lsel], ii[lsel], kk[lsel]))
    left_k, left_i, left_j = (kk[lsel][lorder], ii[lsel][lorder],
                              jj[lsel][lorder])

    # Covered pairs, sorted by (group, receiver, i, j) = legacy Z^k order.
    corder = np.lexsort((jj[covered], ii[covered], kk[covered], gm[covered]))
    pair_k = kk[covered][corder]
    pair_i = ii[covered][corder]
    pair_j = jj[covered][corder]
    pair_b = bb[covered][corder]
    pair_gm = gm[covered][corder]
    P = pair_k.size
    _, rank = _run_ranks(pair_gm, pair_k)   # column index within (S, k)

    # --- entries: one per (pair, segment); sender t = t-th batch member ---
    members = np.zeros((len(alloc.subsets), r), dtype=np.int32)
    for b, S in enumerate(alloc.subsets):
        if len(S) == r:
            members[b] = S                   # ascending == others order
    e_sender = members[pair_b]               # [P, r]
    e_gm = np.repeat(pair_gm, r)
    e_c = np.repeat(rank, r)
    e_s = e_sender.ravel()
    e_t = np.tile(np.arange(r), P)
    seg_len = np.array([b - a for a, b in segment_bounds(r)], dtype=np.int64)
    e_len = seg_len[e_t]

    # --- columns: unique (group, sender, rank) ---
    eorder = np.lexsort((e_c, e_s, e_gm))
    col_sorted, slot_sorted = _run_ranks(e_gm[eorder], e_s[eorder],
                                         e_c[eorder])
    C = int(col_sorted[-1]) + 1 if col_sorted.size else 0
    if slot_sorted.size:
        assert int(slot_sorted.max()) < r, "column overfull: schedule bug"
    col_of_e = np.empty(P * r, dtype=np.int64)
    slot_of_e = np.empty(P * r, dtype=np.int64)
    col_of_e[eorder] = col_sorted
    slot_of_e[eorder] = slot_sorted

    col_width = np.zeros(C, dtype=np.int64)
    np.maximum.at(col_width, col_of_e, e_len)
    firsts = np.zeros(C, dtype=np.int64)
    firsts[col_sorted[::-1]] = eorder[::-1]  # first entry of each column
    col_sender = e_s[firsts].astype(np.int32)
    col_gm = e_gm[firsts]
    col_rank = e_c[firsts].astype(np.int32)

    slot_pair = np.full((C, r), P, dtype=np.int64)      # sentinel zero word
    slot_shift = np.zeros((C, r), dtype=np.uint32)
    slot_mask = np.zeros((C, r), dtype=np.uint32)
    e_p = np.repeat(np.arange(P, dtype=np.int64), r)
    slot_pair[col_of_e, slot_of_e] = e_p
    slot_shift[col_of_e, slot_of_e] = seg_shift[e_t]
    slot_mask[col_of_e, slot_of_e] = seg_mask[e_t]

    pair_col = col_of_e.reshape(P, r)        # entries are (pair, t)-major
    pair_slot = slot_of_e.reshape(P, r)

    # --- full missing set sorted by (k, i, j) + per-server CSR ---
    all_k = np.concatenate([pair_k, left_k])
    all_i = np.concatenate([pair_i, left_i])
    all_j = np.concatenate([pair_j, left_j])
    aorder = np.lexsort((all_j, all_i, all_k))
    inv = np.empty(all_k.size, dtype=np.int64)
    inv[aorder] = np.arange(all_k.size)
    all_k, all_i, all_j = all_k[aorder], all_i[aorder], all_j[aorder]
    ptr = np.searchsorted(all_k, np.arange(K + 1)).astype(np.int64)

    return ShufflePlan(
        n=n, K=K, r=r,
        pair_k=pair_k, pair_i=pair_i, pair_j=pair_j,
        col_width=col_width, col_sender=col_sender, col_gm=col_gm,
        col_rank=col_rank,
        slot_pair=slot_pair, slot_shift=slot_shift, slot_mask=slot_mask,
        pair_col=pair_col, pair_slot=pair_slot, seg_shift=seg_shift,
        left_k=left_k, left_i=left_i, left_j=left_j,
        all_k=all_k, all_i=all_i, all_j=all_j,
        pos_covered=inv[:P], pos_left=inv[P:], ptr=ptr)


def _patch_senders(plan: ShufflePlan, alive_mask: np.uint64) -> int:
    """Reassign dead senders' columns to healthy group members, in place.

    Implements the `straggler_coded_load` hand-over rule at the column
    level: the stand-in s' is the lowest healthy member of the column's
    (r+1)-group; it re-encodes the same coded words (it Mapped every batch
    in the column except its own receiver's), and the s'-destined segments
    it cannot XOR are unicast by a third healthy member. Returns those
    unicast overhead bits; the delivered words and the column widths (hence
    `coded_bits`) are untouched. Columns only reach here with >= 2 healthy
    members - `_compile_missing` demoted the rest to unicast leftovers.
    """
    if plan.col_sender.size == 0:
        return 0
    one = np.uint64(1)
    dead = ((np.uint64(alive_mask) >> plan.col_sender.astype(np.uint64))
            & one) == 0
    if not dead.any():
        return 0
    healthy = plan.col_gm[dead] & np.uint64(alive_mask)
    lsb = healthy & (np.uint64(0) - healthy)     # lowest healthy member
    stand = np.bitwise_count(lsb - one).astype(np.int32)
    # Overhead: the stand-in's own slot (if present) in each column it
    # takes over must travel as unicast - it cannot XOR what it is owed.
    slot_recv = np.append(plan.pair_k, np.int32(-1))[plan.slot_pair[dead]]
    widths = np.bitwise_count(plan.slot_mask[dead])
    bits = int(widths[slot_recv == stand[:, None]].sum())
    plan.col_sender[dead] = stand
    return bits


# ---- incremental (EdgeDelta) plan maintenance ----

@dataclasses.dataclass(frozen=True)
class DeltaStats:
    """Accounting of one `ShufflePlan.apply_delta` call.

    `inserted_values` / `deleted_values` count directed deliveries added
    to / removed from the missing set (0 on both = the delta touched only
    locally-Mapped edges, so `schedule_changed` is False and the plan
    arrays are value-identical to the input plan's). `demoted_pairs`
    counts inserted covered pairs demoted to unicast because their group
    kept < 2 healthy members (degraded allocations only). `handover_bits`
    is the re-patched `_patch_senders` unicast total of the NEW plan (0
    when the allocation is healthy or the schedule is untouched) - for a
    degraded session it replaces `RepairStats.handover_bits`.
    """

    inserted_edges: int
    deleted_edges: int
    inserted_values: int
    deleted_values: int
    demoted_pairs: int
    handover_bits: int
    schedule_changed: bool


@dataclasses.dataclass(frozen=True)
class _DeltaStream:
    """One side (insert or delete) of a delta, as classified triples.

    Missing triples of the delta's directed entries, pre-sorted into each
    plan stream's own order: covered pairs by (group, receiver, i, j),
    leftovers and the full stream by (receiver, i, j). `*pos_in_a` locate
    the covered/leftover elements inside the full stream; `src_a`/`csrc`/
    `lsrc` carry each element's directed-entry index (the
    `csr_delta_entries` order) for the incremental edge-table rebind.
    """

    ck: np.ndarray; ci: np.ndarray; cj: np.ndarray; cgm: np.ndarray
    lk: np.ndarray; li: np.ndarray; lj: np.ndarray
    ak: np.ndarray; ai: np.ndarray; aj: np.ndarray
    cpos_in_a: np.ndarray; lpos_in_a: np.ndarray
    src_a: np.ndarray; csrc: np.ndarray; lsrc: np.ndarray
    demoted: int


def _delta_stream(pairs: np.ndarray, alloc: Allocation,
                  survivors: int | None) -> _DeltaStream:
    """Classify one delta side exactly as `_compile_missing` would."""
    r = alloc.r
    u, v = pairs[:, 0], pairs[:, 1]
    di = np.concatenate([u, v])
    dj = np.concatenate([v, u])
    order = np.lexsort((dj, di))     # the csr_delta_entries directed order
    di, dj = di[order], dj[order]
    kk = alloc.reduce_owner[di].astype(np.int32)
    miss = ~alloc.map_sets[kk, dj]
    src = np.flatnonzero(miss).astype(np.int64)
    mi = di[miss].astype(np.int32)
    mj = dj[miss].astype(np.int32)
    mk = kk[miss]
    bb = alloc.batch_of[mj]
    subset_size = np.array([len(s) for s in alloc.subsets], dtype=np.int64)
    subset_mask = np.array([sum(1 << s for s in S) for S in alloc.subsets],
                           dtype=np.uint64)
    covered = subset_size[bb] == r
    gm = subset_mask[bb] | (np.uint64(1) << mk.astype(np.uint64))
    demoted = 0
    if survivors is not None:
        healthy = np.bitwise_count(gm & np.uint64(survivors))
        natural = covered.copy()
        covered &= healthy >= 2
        demoted = int((natural & ~covered).sum())
    # One lexsort gives the full (k, i, j) stream; the covered stream's
    # (gm, k, i, j) order is a stable re-sort of its a-stream subset by
    # group alone, and the leftover subset needs no re-sort at all.
    aorder = np.lexsort((mj, mi, mk))
    cov_a = covered[aorder]
    cpos_in_a = np.flatnonzero(cov_a)
    lpos_in_a = np.flatnonzero(~cov_a)
    cpos_in_a = cpos_in_a[np.argsort(gm[aorder[cpos_in_a]], kind="stable")]
    cidx = aorder[cpos_in_a]
    lidx = aorder[lpos_in_a]
    return _DeltaStream(
        ck=mk[cidx], ci=mi[cidx], cj=mj[cidx], cgm=gm[cidx],
        lk=mk[lidx], li=mi[lidx], lj=mj[lidx],
        ak=mk[aorder], ai=mi[aorder], aj=mj[aorder],
        cpos_in_a=cpos_in_a, lpos_in_a=lpos_in_a,
        src_a=src[aorder], csrc=src[cidx], lsrc=src[lidx],
        demoted=demoted)


def _splice(old: np.ndarray, tgt: np.ndarray, ins_vals: np.ndarray,
            new_ins: np.ndarray, size: int) -> np.ndarray:
    """Merged array from `merge_maps` bookkeeping (dtype follows `old`).

    `tgt` is `new_old` with every deleted position redirected to the trash
    slot `size` - a single full-speed scatter then replaces the boolean
    keep-mask compaction (two O(size) passes instead of four)."""
    out = np.empty(size + 1, dtype=old.dtype)
    out[tgt] = old
    out[new_ins] = ins_vals
    return out[:size]


def _splice_points(sorted_key: np.ndarray, keys: np.ndarray, what: str,
                   expect_present: bool) -> np.ndarray:
    """Positions of `keys` in a globally-sorted unique key stream; raises
    if a deletion is absent from (or an insertion already present in) the
    stream - that can only mean the plan and the CSR disagree."""
    pos = np.searchsorted(sorted_key, keys)
    if sorted_key.size:
        present = (pos < sorted_key.size) \
            & (sorted_key[np.minimum(pos, sorted_key.size - 1)] == keys)
    else:
        present = np.zeros(keys.size, dtype=bool)
    bad = ~present if expect_present else present
    if bad.any():
        raise RuntimeError(
            f"delta {'removes' if expect_present else 'adds'} a {what} the "
            f"plan {'does not schedule' if expect_present else 'already schedules'}"
            f" - the plan was not compiled against this CSR")
    return pos


def _pair_splice_points(pair_gm: np.ndarray, pair_key: np.ndarray,
                        gms: np.ndarray, keys: np.ndarray,
                        expect_present: bool) -> np.ndarray:
    """`_splice_points` for the covered-pair stream, which is sorted by
    (group, receiver, i, j): narrow to each delta group's run (groups are
    ascending) and binary-search the per-group (k, i, j)-sorted keys.
    Triples are globally unique, so the presence check stays global."""
    pos = np.empty(keys.size, dtype=np.int64)
    if keys.size == 0:
        return pos
    starts = np.flatnonzero(np.r_[True, gms[1:] != gms[:-1]])
    ends = np.append(starts[1:], gms.size)
    for a, b in zip(starts, ends):
        lo = np.searchsorted(pair_gm, gms[a], side="left")
        hi = np.searchsorted(pair_gm, gms[a], side="right")
        pos[a:b] = lo + np.searchsorted(pair_key[lo:hi], keys[a:b])
    if pair_key.size:
        present = (pos < pair_key.size) \
            & (pair_key[np.minimum(pos, pair_key.size - 1)] == keys)
    else:
        present = np.zeros(keys.size, dtype=bool)
    bad = ~present if expect_present else present
    if bad.any():
        raise RuntimeError(
            f"delta {'removes' if expect_present else 'adds'} a covered "
            f"pair the plan {'does not schedule' if expect_present else 'already schedules'}"
            f" - the plan was not compiled against this CSR")
    return pos


def _schedule_from_pairs(pair_k: np.ndarray, pair_gm: np.ndarray, r: int):
    """Column + slot tables of a (group, receiver, i, j)-sorted covered-pair
    stream, in closed form - no entry lexsort.

    Provably identical to the entry-stream section of `_compile_missing`
    (the hot lexsorts of a fresh compile), which is what makes
    `apply_delta` O(plan) instead of O(plan log plan):

      * every (r+1)-group g contributes, per member s, exactly
        ``R[g, s] = max(len of the other members' receiver runs)`` columns
        (the rank-c column exists iff some run k != s reaches rank c), and
        blocks ordered by (g asc, s asc, c asc) ARE the fresh
        ``lexsort((rank, sender, group))`` column order;
      * the slots of column (g, s, c) are the rank-c pairs of the group's
        other receiver runs in ascending-k order, which is exactly the
        fresh stable tie-break (entry index = pair-major);
      * a column's width is the max segment length over its receivers,
        i.e. ``max(seg_len[q-1] if c < max-run-below-s, seg_len[q] if
        c < max-run-above-s)`` where q is s's position among the members.
    """
    P = pair_k.size
    m = r + 1
    seg_shift, seg_mask = segment_words(r)
    seg_len = np.array([b - a for a, b in segment_bounds(r)], dtype=np.int64)
    if P == 0:
        z32 = np.zeros(0, np.int32)
        return (np.zeros(0, np.int64), z32, np.zeros(0, np.uint64), z32,
                np.zeros((0, r), np.int64), np.zeros((0, r), np.uint32),
                np.zeros((0, r), np.uint32), np.zeros((0, r), np.int64),
                np.zeros((0, r), np.int64))

    # Runs of (group, receiver) and groups; the stream is already sorted.
    newrun = np.empty(P, dtype=bool)
    newrun[0] = True
    newrun[1:] = (pair_gm[1:] != pair_gm[:-1]) | (pair_k[1:] != pair_k[:-1])
    rstart = np.flatnonzero(newrun)
    rlen = np.diff(np.append(rstart, P))
    run_gm = pair_gm[rstart]
    run_k = pair_k[rstart]
    nrun = rstart.size
    newg = np.empty(nrun, dtype=bool)
    newg[0] = True
    newg[1:] = run_gm[1:] != run_gm[:-1]
    gid_run = np.cumsum(newg) - 1
    gfirst = np.flatnonzero(newg)
    gvals = run_gm[gfirst]
    G = gvals.size

    # Member decode: every group mask has exactly r+1 bits.
    bits = ((gvals[:, None] >> np.arange(64, dtype=np.uint64)[None, :])
            & np.uint64(1)).astype(bool)
    mem = np.nonzero(bits)[1]
    assert mem.size == G * m, "group mask without exactly r+1 members"
    mem = mem.reshape(G, m).astype(np.int32)

    # Per-(group, member) receiver-run lengths and the exclusive
    # prefix/suffix maxima that bound each sender's column count.
    qrun = (mem[gid_run] < run_k[:, None]).sum(axis=1)
    Lmem = np.zeros((G, m), dtype=np.int64)
    Lmem[gid_run, qrun] = rlen
    Mlo = np.zeros((G, m), dtype=np.int64)
    np.maximum.accumulate(Lmem[:, :-1], axis=1, out=Mlo[:, 1:])
    Mhi = np.zeros((G, m), dtype=np.int64)
    Mhi[:, :-1] = np.maximum.accumulate(Lmem[:, ::-1], axis=1)[:, -2::-1]
    Rcols = np.maximum(Mlo, Mhi)
    Rflat = Rcols.ravel()
    colstart = np.zeros(G * m + 1, dtype=np.int64)
    np.cumsum(Rflat, out=colstart[1:])
    C = int(colstart[-1])

    # Per-column arrays, block by block (g-major, sender asc, rank asc).
    # A block's width profile is a two-step function of the column rank c
    # (max(wlo, whi) while c is under both run maxima, then the surviving
    # side alone), so the whole array is one repeat of 2 segments/block.
    cs32 = colstart.astype(np.int32) if C < 2**31 else colstart
    col_rank = (np.arange(C, dtype=cs32.dtype)
                - np.repeat(cs32[:-1], Rflat)).astype(np.int32, copy=False)
    col_sender = np.repeat(mem.ravel(), Rflat)
    col_gm = np.repeat(gvals, Rcols.sum(axis=1))
    q_blk = np.tile(np.arange(m), G)
    w_lo = seg_len[np.maximum(q_blk - 1, 0)]
    w_hi = seg_len[np.minimum(q_blk, r - 1)]
    Mlo_f, Mhi_f = Mlo.ravel(), Mhi.ravel()
    mn = np.minimum(Mlo_f, Mhi_f)
    wvals = np.empty(2 * G * m, dtype=np.int64)
    wvals[0::2] = np.maximum(w_lo, w_hi)
    wvals[1::2] = np.where(Mlo_f > Mhi_f, w_lo, w_hi)
    wlens = np.empty(2 * G * m, dtype=np.int64)
    wlens[0::2] = mn
    wlens[1::2] = Rflat - mn
    col_width = np.repeat(wvals, wlens)

    # Per-entry (pair, segment) columns and slots, all via per-run repeats
    # (the stream is run-sorted, so every per-entry quantity is either an
    # arithmetic ramp or a run-constant): segment t's sender is member
    # t+(t>=q) where q is the receiver's member position, and
    # cnt[p] = #{members k' < receiver(p) whose run outlasts rank(p)} is a
    # per-run step function of the rank with breakpoints at the sorted
    # earlier-run lengths.
    Lmat = Lmem[gid_run]                                       # [nrun, m]
    emask = np.arange(m)[None, :] < qrun[:, None]
    SL = np.sort(np.where(emask, Lmat, np.iinfo(np.int64).max), axis=1)
    bounds = np.minimum(SL, rlen[:, None])
    cum = np.concatenate(
        [np.zeros((nrun, 1), dtype=np.int64), bounds, rlen[:, None]], axis=1)
    step_vals = (qrun[:, None] - np.arange(m + 1)[None, :]).astype(np.int32)

    pair_colT = np.empty((r, P), dtype=np.int64)
    pair_slotT = np.empty((r, P), dtype=np.int64)
    slot_pair = np.full(C * r, P, dtype=np.int64)
    slot_shift = np.zeros(C * r, dtype=np.uint32)
    slot_mask = np.zeros(C * r, dtype=np.uint32)
    sp2 = slot_pair.reshape(C, r)
    ss2 = slot_shift.reshape(C, r)
    sm2 = slot_mask.reshape(C, r)
    arN = np.arange(nrun)
    if nrun * (m + 2) * 16 < P:
        # Few huge runs (small K): every per-entry quantity above is a ramp
        # or a constant over the <= nrun*(m+1) (run, cnt-step) segments -
        # the mask threshold is itself one of the `bounds` breakpoints - so
        # the whole scatter loop collapses to strided slice writes with no
        # index arrays (or index bandwidth) at all.
        cumL = cum.tolist()
        stepL = step_vals.tolist()
        rstartL = rstart.tolist()
        for t in range(r):
            eq_run = t + (t >= qrun)
            base_col = colstart[gid_run * m + eq_run]
            thr = np.where(t < qrun, Lmat[arN, eq_run], 0)
            baseL, thrL = base_col.tolist(), thr.tolist()
            sh, mk = seg_shift[t], seg_mask[t]
            colrow, slotrow = pair_colT[t], pair_slotT[t]
            for u in range(nrun):
                p0, c0, row = rstartL[u], baseL[u], cumL[u]
                for s_i in range(m + 1):
                    a, b = row[s_i], row[s_i + 1]
                    if a >= b:
                        continue
                    slot = stepL[u][s_i] - (1 if a < thrL[u] else 0)
                    sp2[c0 + a:c0 + b, slot] = np.arange(
                        p0 + a, p0 + b, dtype=np.int64)
                    ss2[c0 + a:c0 + b, slot] = sh
                    sm2[c0 + a:c0 + b, slot] = mk
                    colrow[p0 + a:p0 + b] = np.arange(
                        c0 + a, c0 + b, dtype=np.int64)
                    slotrow[p0 + a:p0 + b] = slot
    else:
        cnt = np.repeat(step_vals.ravel(), np.diff(cum, axis=1).ravel())
        idt = np.int32 if C * r < 2**31 and P < 2**31 else np.int64
        arP = np.arange(P, dtype=idt)
        flat = np.empty(P, dtype=np.intp)
        for t in range(r):
            eq_run = t + (t >= qrun)
            cs_run = (colstart[gid_run * m + eq_run] - rstart).astype(idt)
            col_t = np.repeat(cs_run, rlen)
            np.add(col_t, arP, out=col_t)              # colstart + rank
            thr_run = (np.where(t < qrun, Lmat[arN, eq_run], 0)
                       + rstart).astype(idt)
            # rank < L_sender, sender before receiver <=> arP < threshold
            slot_t = cnt - (arP < np.repeat(thr_run, rlen))
            # one intp index buffer; fancy assignment would otherwise
            # convert the int32 flat index once per scatter
            np.multiply(col_t, idt(r), out=flat, casting="unsafe")
            np.add(flat, slot_t, out=flat, casting="unsafe")
            slot_pair[flat] = arP
            slot_shift[flat] = seg_shift[t]
            slot_mask[flat] = seg_mask[t]
            pair_colT[t] = col_t
            pair_slotT[t] = slot_t
    return (col_width, col_sender.astype(np.int32, copy=False), col_gm,
            col_rank, sp2, ss2, sm2, pair_colT.T, pair_slotT.T)


def _delta_edge_tables(tables: PlanEdgeTables, csr: CSR, csr_new: CSR,
                       delta, ins: _DeltaStream, scheduled: bool,
                       tgt_p, new_ins_p, P2, tgt_l, new_ins_l, L2,
                       tgt_a, new_old_a, new_ins_a, M2) -> PlanEdgeTables:
    """Carry a plan's CSR binding through a delta in O(nnz + delta),
    without re-running `_locate_edges` / the gather searchsorted: kept
    entries and deliveries keep their identity and just renumber through
    the entry/delivery merge maps; new entries self-gather when local and
    point at their freshly-spliced delivery slot otherwise. `tgt_*` are
    the trash-marked scatter targets of `_apply_delta` (see `_splice`);
    deleted elements read garbage renumbers and write them to the trash
    slot, so no boolean keep pass over nnz-sized arrays is needed."""
    nnz, nnz2 = csr.nnz, csr_new.nnz
    del_pos, ins_pos, ins_rows, ins_cols = csr_delta_entries(csr, delta)
    new_old_e, new_ins_e, nnz2b = merge_maps(nnz, del_pos, ins_pos)
    assert nnz2b == nnz2, "entry merge disagrees with the mutated CSR"

    if scheduled:
        pair_e2 = _splice(new_old_e[tables.pair_e], tgt_p,
                          new_ins_e[ins.csrc], new_ins_p, P2)
        left_e2 = _splice(new_old_e[tables.left_e], tgt_l,
                          new_ins_e[ins.lsrc], new_ins_l, L2)
    else:                       # missing-set-only plan: no pair/left streams
        pair_e2 = left_e2 = np.zeros(0, dtype=np.int64)
    all_e2 = _splice(new_old_e[tables.all_e], tgt_a,
                     new_ins_e[ins.src_a], new_ins_a, M2)

    # Renumber the full gather column branch-free: both the local-entry
    # and the delivery-slot transforms are computed clamped, then selected.
    g = tables.gather
    gfull = np.where(
        g < nnz,
        new_old_e[np.minimum(g, nnz - 1)],
        nnz2 + new_old_a[np.maximum(g - nnz, 0)])
    tgt_e = new_old_e.copy()
    tgt_e[del_pos] = nnz2                    # deleted entries -> trash slot
    gather2 = np.empty(nnz2 + 1, dtype=np.int64)
    gather2[tgt_e] = gfull
    gnew = new_ins_e.copy()                  # local entries self-gather
    gnew[ins.src_a] = nnz2 + new_ins_a       # missing ones read deliveries
    gather2[new_ins_e] = gnew
    return PlanEdgeTables(pair_e2, left_e2, all_e2, gather2[:nnz2])


# ---- hierarchical (topology-aware) two-level plans ----


@dataclasses.dataclass(frozen=True)
class HierarchicalEdgeTables:
    """CSR bindings of a `HierarchicalPlan`: the server-level tables (reduce
    gather + per-delivery entries, identical to the flat plan's) plus the
    rack-level inter plan's own binding."""

    flat: PlanEdgeTables
    inter: PlanEdgeTables


@dataclasses.dataclass(frozen=True)
class HierarchicalPlan:
    """Two-level coded-Shuffle schedule of one (graph, allocation, topology).

    The flat K-server missing set is split per delivery by where the value
    lives relative to its Reducer's rack:

      * **intra-only** - some server in the Reducer's rack Mapped the column
        vertex; the value never crosses a rack boundary (one intra-rack word
        from its designated source, the lowest in-rack Mapper);
      * **inter-rack** - no in-rack copy exists; the value joins the
        rack-level missing set and is coded by `inter`, a `ShufflePlan`
        compiled with *racks as super-servers* over the union allocation
        (`rack_alloc`: a rack Maps a batch iff any member server does,
        redundancy = the dominant rack-multiplicity of the crossing
        batches).

    Locked contracts (tests/test_schedule_invariants.py, test_properties.py,
    tests/test_hierarchical_fused.py):

      * delivered words are **bitwise equal** to the flat
        `execute_coded_sparse` delivery - same (k, i, j)-sorted stream, same
        uint32 words (XOR coding is exact at both levels);
      * `Topology.flat(K)` degenerates to exactly today's plan: `inter` is
        array-bitwise-identical to `compile_plan_csr(csr, alloc)`, every
        delivery is inter-rack, and `intra_rack_bits == 0`.

    Bit accounting (per single-query Shuffle):

      * `inter_rack_bits` - the rack-level plan's multicast columns plus its
        unicast leftovers, exactly as the flat plan accounts its own;
      * `intra_rack_bits` - one word per *unique* (rack, value) that must
        move inside a rack: intra-only deliveries, slot values the sending
        rack's leader does not hold when encoding, strip values the
        receiving server does not hold when decoding, and leftover values
        the unicasting rack's leader is missing. Words whose designated
        source IS the consumer cost nothing, which is what drives the count
        to zero on `Topology.flat`.
    """

    topology: "object"            # launch.mesh.Topology
    flat: ShufflePlan             # server-level schedule (delivery stream)
    inter: ShufflePlan            # rack-level coded schedule
    rack_alloc: Allocation        # racks-as-super-servers union allocation
    rack_of: np.ndarray           # [K] int32 server -> rack
    inter_pos: np.ndarray         # [M] int64 into inter delivery stream (-1)
    intra_src: np.ndarray         # [M] int32 in-rack source server (-1)
    server_of_inter: np.ndarray   # [Mx] int32 receiving server per inter value
    intra_words: int              # unique intra-rack words per Shuffle

    @property
    def n(self) -> int:
        return self.flat.n

    @property
    def K(self) -> int:
        return self.flat.K

    @property
    def r(self) -> int:
        return self.flat.r

    @property
    def inter_rack_bits(self) -> int:
        """Bits crossing rack boundaries in one single-query Shuffle."""
        return self.inter.coded_bits + self.inter.leftover_bits

    @property
    def intra_rack_bits(self) -> int:
        """Bits moving inside racks in one single-query Shuffle."""
        return self.intra_words * T_BITS

    @property
    def total_bits(self) -> int:
        return self.inter_rack_bits + self.intra_rack_bits

    def check_alloc(self, alloc: Allocation) -> None:
        self.flat.check_alloc(alloc)

    def edge_tables(self, csr: CSR, alloc: Allocation) -> HierarchicalEdgeTables:
        """Bind both levels to a CSR view (cached, like the flat form)."""
        cached = self.__dict__.get("_h_edge_tables")
        if cached is not None:
            c_csr, c_alloc, tables = cached
            if c_csr is csr and c_alloc is alloc:
                return tables
        tables = HierarchicalEdgeTables(
            flat=self.flat.edge_tables(csr, alloc),
            inter=self.inter.edge_tables(csr, self.rack_alloc))
        self.__dict__["_h_edge_tables"] = (csr, alloc, tables)
        return tables

    def execute_coded_sparse(self, edge_vals: np.ndarray,
                             tables: HierarchicalEdgeTables, *,
                             backend: str = "numpy",
                             interpret: bool = True) -> PlanShuffleResult:
        """Two-level coded Shuffle from a [nnz] edge-value vector.

        Delivered `values` are bitwise equal to the flat plan's
        `execute_coded_sparse` (same stream, exact XOR recovery at the rack
        level, direct words at the intra level); `bits_sent` is the
        two-level total `inter_rack_bits + intra_rack_bits` (x B for
        batched [nnz, B] payloads). The exchange span and the metrics
        registry carry both per-level numbers.
        """
        from ..obs.metrics import get_registry

        res_x = self.inter.execute_coded_sparse(
            edge_vals, tables.inter, backend=backend, interpret=interpret)
        B = res_x.batch
        out = np.empty((self.flat.all_k.size,) + edge_vals.shape[1:],
                       dtype=np.float32)
        inter_m = self.inter_pos >= 0
        out[inter_m] = res_x.values[self.inter_pos[inter_m]]
        out[~inter_m] = edge_vals[tables.flat.all_e[~inter_m]]
        inter_bits = res_x.bits_sent
        intra_bits = self.intra_rack_bits * B
        with get_tracer().span("phase.exchange", level="intra_rack",
                               bits=intra_bits, B=B,
                               inter_rack_bits=inter_bits,
                               intra_rack_bits=intra_bits):
            pass
        reg = get_registry()
        reg.counter("shuffle_inter_rack_bits_total",
                    "coded-Shuffle bits crossing rack boundaries") \
            .inc(inter_bits)
        reg.counter("shuffle_intra_rack_bits_total",
                    "coded-Shuffle bits moving inside racks") \
            .inc(intra_bits)
        return PlanShuffleResult(self.flat.all_k, self.flat.all_i,
                                 self.flat.all_j, out, self.flat.ptr,
                                 inter_bits + intra_bits, self.flat.n)


def _rack_first_mapper(alloc: Allocation, R: int, S: int):
    """Designated in-rack sources: ``first[rho, j]`` is the offset within
    rack rho of its lowest server Mapping vertex j (0 if none Mapped it -
    guard with `has`)."""
    ms = alloc.map_sets.reshape(R, S, alloc.n)
    return ms.argmax(axis=1).astype(np.int32), ms.any(axis=1)


def compile_hierarchical(csr: CSR, alloc: Allocation, topology,
                         validate: bool = True) -> HierarchicalPlan:
    """Compile the two-level (racks x servers) coded-Shuffle schedule.

    One pass over the edges builds the flat per-server missing stream (the
    delivery contract), splits it by in-rack availability, and compiles the
    crossing remainder with racks as super-servers through the *same*
    `_compile_missing` body the flat compiler uses - the rack-level
    redundancy is the dominant rack-multiplicity among the crossing batches
    (pinned to `alloc.r` on a flat topology so `Topology.flat(K)`
    degenerates to the flat plan bitwise). See `HierarchicalPlan` for the
    locked contracts and the per-level bit accounting.
    """
    topology.check_K(alloc.K)
    if csr.n != alloc.n:
        raise ValueError(
            f"graph has n={csr.n} vertices but the allocation expects "
            f"n={alloc.n}; pad the graph with virtual isolated vertices "
            f"first (Graph.padded / er_allocation(..., pad=True))")
    R, S = topology.racks, topology.servers_per_rack
    with get_tracer().span("plan.compile", entry="hierarchical", n=alloc.n,
                           K=alloc.K, r=alloc.r, racks=R,
                           servers_per_rack=S) as sp:
        plan = _compile_hierarchical(csr, alloc, topology, R, S, validate)
        _stamp_plan(sp, plan.flat, int(csr.nnz))
        sp.set(inter_rack_bits=plan.inter_rack_bits,
               intra_rack_bits=plan.intra_rack_bits,
               rack_redundancy=plan.inter.r)
    return plan


def _compile_hierarchical(csr: CSR, alloc: Allocation, topology,
                          R: int, S: int,
                          validate: bool) -> HierarchicalPlan:
    n = alloc.n
    rack_of = topology.rack_of()
    first, has = _rack_first_mapper(alloc, R, S)

    # Flat server-level schedule: the delivery stream every level must honor
    # (bitwise-identical to `compile_plan_csr` - same stream, same body).
    kk = alloc.reduce_owner[csr.rows].astype(np.int32)
    miss = ~alloc.map_sets[kk, csr.indices]
    mi = csr.rows[miss].astype(np.int32)
    mj = csr.indices[miss].astype(np.int32)
    mk = kk[miss]
    flat = _compile_missing(mi, mj, mk, alloc, schedule=True)
    if validate:
        _validate_csr(flat, csr, alloc)

    # Rack-level union allocation: a rack Maps a batch iff any member does.
    rho = rack_of[mk]
    avail = has[rho, mj]                     # in-rack copy exists
    xi, xj, xr = mi[~avail], mj[~avail], rho[~avail]
    # Membership counts only servers that still hold their Map shard: a
    # degraded allocation (post-`fail`) zeroes dead servers' map rows while
    # keeping them in `subsets`, and a rack must never be scheduled to send
    # a batch only its dead members Mapped. Healthy allocations have no
    # empty rows, so this is the identity there (flat degeneracy intact).
    alive = alloc.map_sets.any(axis=1)
    rack_subsets = tuple(tuple(sorted({int(rack_of[s]) for s in T
                                       if alive[s]}))
                         for T in alloc.subsets)
    sizes = np.array([len(T) for T in rack_subsets], dtype=np.int64)
    if topology.is_flat:
        r_rack = alloc.r                     # exact flat degeneracy
    elif xj.size:
        w = np.bincount(sizes[alloc.batch_of[xj]])
        r_rack = int(np.flatnonzero(w == w.max()).max())
    elif sizes.size:
        w = np.zeros(int(sizes.max()) + 1, dtype=np.int64)
        np.add.at(w, sizes, np.bincount(alloc.batch_of,
                                        minlength=sizes.size))
        r_rack = int(np.flatnonzero(w == w.max()).max())
    else:
        r_rack = min(alloc.r, R)
    r_rack = max(r_rack, 1)
    rack_alloc = Allocation(
        n=n, K=R, r=r_rack, subsets=rack_subsets, batch_of=alloc.batch_of,
        map_sets=has, reduce_owner=rack_of[alloc.reduce_owner])
    inter = _compile_missing(xi, xj, xr, rack_alloc, schedule=True)
    if validate:
        _validate_slots(inter)

    # Per-delivery routing: position in the inter stream, or in-rack source.
    M = flat.all_k.size
    n64 = np.int64(n)
    d_rho = rack_of[flat.all_k]
    d_avail = has[d_rho, flat.all_j]
    inter_pos = np.full(M, -1, dtype=np.int64)
    xkey = ((inter.all_k.astype(np.int64) * n64 + inter.all_i) * n64
            + inter.all_j)
    need = ~d_avail
    dkey = ((d_rho[need].astype(np.int64) * n64 + flat.all_i[need]) * n64
            + flat.all_j[need])
    pos = np.searchsorted(xkey, dkey)
    if (pos.size != xkey.size or not (pos < max(xkey.size, 1)).all()
            or not np.array_equal(xkey[pos], dkey)):
        raise AssertionError(
            "rack-level delivery stream disagrees with the flat stream")
    inter_pos[need] = pos
    server_of_inter = np.empty(xkey.size, dtype=np.int32)
    server_of_inter[pos] = flat.all_k[need]
    intra_src = np.full(M, -1, dtype=np.int32)
    intra_src[d_avail] = (d_rho[d_avail] * S
                          + first[d_rho[d_avail], flat.all_j[d_avail]]) \
        .astype(np.int32)

    intra_words = _count_intra_words(
        alloc, inter, rack_of, first, has, S, n64,
        d_rho, d_avail, flat, intra_src, server_of_inter)

    return HierarchicalPlan(
        topology=topology, flat=flat, inter=inter, rack_alloc=rack_alloc,
        rack_of=rack_of, inter_pos=inter_pos, intra_src=intra_src,
        server_of_inter=server_of_inter, intra_words=intra_words)


def _count_intra_words(alloc, inter, rack_of, first, has, S, n64,
                       d_rho, d_avail, flat, intra_src,
                       server_of_inter) -> int:
    """Unique (rack, value) words that must move inside a rack; see
    `HierarchicalPlan.intra_rack_bits` for the four contributing streams.
    A word is free when its designated source is the consuming server."""
    keys = []

    def _need(rack, j_vertex, i_vertex, consumer):
        """Key the (rack, value) words whose source != consumer."""
        src_off = first[rack, j_vertex]
        if not has[rack, j_vertex].all():
            raise AssertionError("intra word scheduled in a rack that "
                                 "never Mapped its vertex")
        src = rack.astype(np.int64) * S + src_off
        sel = src != consumer
        if sel.any():
            keys.append((rack[sel].astype(np.int64) * (n64 * n64)
                         + i_vertex[sel].astype(np.int64) * n64
                         + j_vertex[sel]))

    # 1. intra-only deliveries (source != receiver always: the receiver is
    #    missing the value, the source Mapped it).
    if d_avail.any():
        _need(d_rho[d_avail], flat.all_j[d_avail], flat.all_i[d_avail],
              flat.all_k[d_avail].astype(np.int64))

    Px = inter.pair_k.size
    if Px:
        # 2. encode: slot values the sending rack's leader must be handed.
        cs, sl = np.nonzero(inter.slot_pair < Px)
        p = inter.slot_pair[cs, sl]
        send_rack = inter.col_sender[cs]
        _need(send_rack, inter.pair_j[p], inter.pair_i[p],
              send_rack.astype(np.int64) * S)
        # 3. decode strips: the other slots of each covered pair's columns,
        #    consumed by the pair's *server-level* receiver.
        r_rack = inter.r
        if r_rack > 1:
            recv = server_of_inter[inter.pos_covered]        # [Px]
            ar = np.broadcast_to(np.arange(r_rack)[None, None, :],
                                 (Px, r_rack, r_rack))
            others = ar[~(ar == inter.pair_slot[..., None])] \
                .reshape(Px, r_rack, r_rack - 1)
            c3 = np.broadcast_to(inter.pair_col[:, :, None],
                                 (Px, r_rack, r_rack - 1))
            sp = inter.slot_pair[c3, others]                  # [Px, rr, rr-1]
            valid = sp < Px
            if valid.any():
                spv = sp[valid]
                rrack = np.broadcast_to(
                    rack_of[recv][:, None, None], sp.shape)[valid]
                cons = np.broadcast_to(
                    recv[:, None, None], sp.shape)[valid].astype(np.int64)
                _need(rrack, inter.pair_j[spv], inter.pair_i[spv], cons)
    if inter.left_k.size:
        # 4. leftovers: the unicasting rack's leader must hold the value.
        lrack = np.argmax(has[:, inter.left_j], axis=0).astype(np.int32)
        if not has[lrack, inter.left_j].all():
            raise AssertionError("rack-level leftover has no Mapping rack")
        _need(lrack, inter.left_j, inter.left_i,
              lrack.astype(np.int64) * S)

    if not keys:
        return 0
    return int(np.unique(np.concatenate(keys)).size)


def _validate(plan: ShufflePlan, adj: np.ndarray, alloc: Allocation) -> None:
    """Compile-time schedule check (replaces the per-iteration engine scan):
    the plan's delivery set must be exactly what each Reducer is missing."""
    from .uncoded_shuffle import missing_pairs

    for k in range(alloc.K):
        need = missing_pairs(adj, alloc, k)          # (i, j)-sorted
        a, b = int(plan.ptr[k]), int(plan.ptr[k + 1])
        got = np.column_stack([plan.all_i[a:b], plan.all_j[a:b]])
        if got.shape != need.shape or not (got == need).all():
            raise AssertionError(
                f"server {k}: plan delivers {b - a} values, "
                f"Reducer misses {len(need)} (or sets differ)")
    _validate_slots(plan)


def _validate_csr(plan: ShufflePlan, csr: CSR, alloc: Allocation) -> None:
    """Compile-time schedule check for CSR-compiled plans, O(K * edges).

    Mirrors the dense `_validate` structure - one *per-server* re-derivation
    in the row-mask formulation of `uncoded_shuffle.missing_pairs` - rather
    than repeating the compiler's fused fancy-indexing pass, so an indexing
    bug in `_compile_edges` is not reproduced verbatim by its own check.
    Also verifies the covered/leftover partition and per-server offsets."""
    total = 0
    for k in range(alloc.K):
        owns = (alloc.reduce_owner == k)[csr.rows]
        need = owns & ~alloc.map_sets[k][csr.indices]
        ii, jj = csr.rows[need], csr.indices[need]   # canonical (i, j) order
        a, b = int(plan.ptr[k]), int(plan.ptr[k + 1])
        if not (b - a == ii.size
                and np.array_equal(plan.all_i[a:b], ii)
                and np.array_equal(plan.all_j[a:b], jj)
                and (plan.all_k[a:b] == k).all()):
            raise AssertionError(
                f"server {k}: plan delivers {b - a} values, "
                f"Reducer misses {ii.size} (or sets differ)")
        total += ii.size
    assert total == plan.all_k.size, "per-server offsets leak entries"
    pos = np.concatenate([plan.pos_covered, plan.pos_left])
    assert pos.size == plan.all_k.size and np.array_equal(
        np.sort(pos), np.arange(pos.size)), \
        "covered/leftover positions do not partition the delivery set"
    _validate_slots(plan)


def _validate_slots(plan: ShufflePlan) -> None:
    """Slot-table consistency of a scheduled plan (shared by both checks)."""
    if not plan.has_schedule or plan.pair_col.size == 0:
        return
    # Each covered pair owns exactly its r slots, and the recovered segments
    # must tile the full 32-bit value.
    P = plan.pair_k.size
    owner = plan.slot_pair[plan.pair_col, plan.pair_slot]
    assert (owner == np.arange(P, dtype=np.int64)[:, None]).all(), \
        "pair/slot cross-links are inconsistent"
    own = plan.slot_mask[plan.pair_col, plan.pair_slot] \
        >> plan.seg_shift[None, :]
    cover = np.bitwise_or.reduce(own, axis=1)
    assert (cover == np.uint32(0xFFFFFFFF)).all(), \
        "segments do not tile the 32-bit value"
