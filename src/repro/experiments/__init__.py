"""Real-dataset experiment harnesses (the paper's EC2-side methodology).

  * `registry`: SNAP dataset registry - name -> URL + checksum with a
    download-once cache, plus always-offline fixture and synthetic-stand-in
    entries (see `registry.DATASETS`).
  * `table2`: the Table II reproduction harness - measured uncoded/coded
    Definition-2 loads per (dataset, r) off one compiled CSR plan each,
    with the ER closed-form overlays, emitted as JSON + markdown.

Everything is dense-free: datasets ingest CSR-native and plans compile via
`compile_plan_csr`, so the pipeline runs at soc-Epinions1 scale (n ~ 76k)
and beyond with O(edges) peak memory.

CLI: ``python -m repro.experiments --list`` /
``python -m repro.experiments --datasets er-76k --K 6 --r 1 2 3``.
"""
from __future__ import annotations

from .registry import DATASETS, Dataset, DatasetUnavailable, fetch, load
from .table2 import run_table2, to_markdown

__all__ = ["DATASETS", "Dataset", "DatasetUnavailable", "fetch", "load",
           "run_table2", "to_markdown"]
