"""Mixture-of-Experts FFN with capacity-based einsum dispatch (MaxText-style).

Tokens are routed top-k; dispatch/combine are one-hot einsums so the compiled
FLOPs reflect active-expert compute only, and sharding the expert axis over
'model' yields expert parallelism (XLA inserts the all-to-alls).

`coded_dispatch` is the paper-bridge (DESIGN.md §4): the token->expert
dispatch is a bipartite-graph shuffle; replicating token shards r=2x across
adjacent EP groups enables the RB-model coded multicast. On TPU the win only
materializes when dispatch bytes dominate expert FLOPs; we expose the mode
for the benchmark harness to quantify, defaulting off.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, MoEConfig
from .layers import ParamSpec, geglu


def moe_spec(cfg: ModelConfig) -> dict:
    e: MoEConfig = cfg.moe
    d = cfg.d_model
    spec = {
        "router": ParamSpec((d, e.num_experts), ("embed", "expert")),
        "w_gate": ParamSpec((e.num_experts, d, e.d_ff_expert),
                            ("expert", "embed", "mlp")),
        "w_up": ParamSpec((e.num_experts, d, e.d_ff_expert),
                          ("expert", "embed", "mlp")),
        "w_down": ParamSpec((e.num_experts, e.d_ff_expert, d),
                            ("expert", "mlp", "embed")),
    }
    if e.num_shared:
        spec |= {
            "shared_gate": ParamSpec((d, cfg.d_ff), ("embed", "mlp")),
            "shared_up": ParamSpec((d, cfg.d_ff), ("embed", "mlp")),
            "shared_down": ParamSpec((cfg.d_ff, d), ("mlp", "embed")),
        }
    return spec


def _capacity(tokens: int, e: MoEConfig) -> int:
    cap = int(tokens * e.top_k * e.capacity_factor / e.num_experts)
    return max(8, (cap + 7) // 8 * 8)


def moe_ffn(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x [B, S, d] -> [B, S, d]."""
    e = cfg.moe
    if e.ep:
        from .moe_ep import moe_ffn_ep
        return moe_ffn_ep(p, cfg, x)
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)                     # [T, E]
    topv, topi = jax.lax.top_k(gates, e.top_k)                  # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    C = _capacity(T, e)
    # Position of each (token, k) inside its expert buffer.
    onehot = jax.nn.one_hot(topi, e.num_experts, dtype=jnp.int32)  # [T,k,E]
    flat = onehot.reshape(T * e.top_k, e.num_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - 1                     # [T*k, E]
    pos = pos.reshape(T, e.top_k, e.num_experts)
    keep = (pos < C) & (pos >= 0)
    # dispatch [T, E, C]: one-hot over the capacity slot.
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=x.dtype)[..., :C]                 # [T,k,E,C]
    dispatch = (slot * keep[..., None].astype(x.dtype)).sum(1)    # [T,E,C]
    combine = (slot * (topv[..., None] * keep.astype(jnp.float32))[..., None]
               ).sum(1).astype(jnp.float32)                       # [T,E,C]

    xe = jnp.einsum("td,tec->ecd", xt, dispatch)                  # [E,C,d]
    g = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])
    u = jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"])
    yt = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)

    out = yt.astype(x.dtype).reshape(B, S, d)
    if e.num_shared:
        # Shared-expert hidden width is cfg.d_ff (= num_shared * per-expert
        # width in the source configs), applied as one fused GeGLU.
        out = out + geglu(x, p["shared_gate"], p["shared_up"], p["shared_down"],
                          act=cfg.act)
    return out


def aux_load_balance_loss(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    e = cfg.moe
    xt = x.reshape(-1, x.shape[-1])
    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32), -1)
    top1 = jnp.argmax(gates, axis=-1)
    f = jnp.mean(jax.nn.one_hot(top1, e.num_experts, dtype=jnp.float32), 0)
    P = jnp.mean(gates, axis=0)
    return e.num_experts * jnp.sum(f * P)
