"""Cluster topology + mesh construction (one path for every mesh).

Defined as functions (never module-level constants) so importing this module
never touches jax device state - jax locks the device count on first init,
and only dryrun.py sets the 512-placeholder XLA flag.

`Topology` is the first-class description of the physical shuffle fabric:
`racks` super-nodes of `servers_per_rack` hosts each, with server k living
in rack ``k // servers_per_rack`` (contiguous blocks).  `Topology.flat(K)`
is the degenerate one-server-per-rack form - every level-dependent decision
in the shuffle stack (plan compilation, fused exchange, load accounting)
flows from a `Topology` and reduces to today's flat K-server behavior on
`Topology.flat(K)`.

Every mesh in the repo is built through `make_mesh` below: the coded-Shuffle
meshes (`make_servers_mesh`, `make_racks_mesh`) use the device-prefix form
(a host with 8 forced CPU devices can still run a K=4 plan), the
training/serving meshes (`make_production_mesh`, `make_local_mesh`) the
all-devices form.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# jax is imported inside the mesh-building functions, not at module scope:
# `Topology` is consumed by the numpy-only core (plan compiler, loads), and
# importing it must stay free of jax side effects.


@dataclasses.dataclass(frozen=True)
class Topology:
    """Two-level cluster shape: `racks` x `servers_per_rack` servers.

    Server k lives in rack ``k // servers_per_rack``; rack rho owns the
    contiguous server block ``[rho * servers_per_rack,
    (rho + 1) * servers_per_rack)``.  Intra-rack links are assumed cheap
    relative to inter-rack links, so the hierarchical coded Shuffle codes
    across racks and exchanges plainly within them
    (`core.shuffle_plan.compile_hierarchical`).
    """

    racks: int
    servers_per_rack: int

    def __post_init__(self):
        if self.racks < 1 or self.servers_per_rack < 1:
            raise ValueError(
                f"need racks >= 1 and servers_per_rack >= 1, got "
                f"racks={self.racks}, servers_per_rack={self.servers_per_rack}")

    @classmethod
    def flat(cls, K: int) -> "Topology":
        """The degenerate flat topology: every server its own rack."""
        return cls(racks=K, servers_per_rack=1)

    @property
    def K(self) -> int:
        """Total server count."""
        return self.racks * self.servers_per_rack

    @property
    def is_flat(self) -> bool:
        return self.servers_per_rack == 1

    def check_K(self, K: int) -> None:
        if self.K != K:
            raise ValueError(
                f"topology has {self.racks} x {self.servers_per_rack} = "
                f"{self.K} servers but the allocation expects K={K}")

    def rack_of(self) -> np.ndarray:
        """[K] int32: server index -> rack index."""
        return (np.arange(self.K, dtype=np.int32)
                // np.int32(self.servers_per_rack))

    def servers_in(self, rack: int) -> np.ndarray:
        """[S] int32: the servers of one rack (ascending)."""
        S = self.servers_per_rack
        return np.arange(rack * S, (rack + 1) * S, dtype=np.int32)

    def leader_of(self) -> np.ndarray:
        """[R] int32: the leader (lowest-index server) of each rack."""
        return (np.arange(self.racks, dtype=np.int32)
                * np.int32(self.servers_per_rack))


def shard_map_compat(f, *, mesh, in_specs, out_specs, check: bool = True):
    """shard_map across the jax versions we support.

    jax >= 0.6 exposes jax.shard_map with `check_vma`; 0.4.x has the
    experimental shard_map with the equivalent `check_rep`. `check=False`
    disables the output-replication check (needed when out_specs promise
    more replication than the checker can prove, e.g. psum-ed outputs).
    """
    import jax

    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check)
    from jax.experimental.shard_map import shard_map
    return shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                     check_rep=check)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...], *,
              prefix: bool = False):
    """The one mesh-construction path (every make_* helper routes here).

    `prefix=False` builds a mesh over *all* devices via `jax.make_mesh`
    (with Auto axis types on jax >= 0.5; on 0.4.x the argument does not
    exist and Auto is the only behavior, so omitting it is equivalent).

    `prefix=True` builds the Mesh explicitly from a device *prefix* of
    ``prod(shape)`` devices - `jax.make_mesh` wants the axis sizes to
    consume all devices, but the coded-Shuffle path maps one server per
    device and must run on hosts with spare forced CPU devices.
    """
    import jax

    if prefix:
        from jax.sharding import Mesh

        need = int(np.prod(shape))
        devs = jax.devices()
        if len(devs) < need:
            raise ValueError(
                f"need {need} devices for mesh shape {shape} but only "
                f"{len(devs)} devices exist; force host devices with "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
        return Mesh(np.asarray(devs[:need]).reshape(shape), axes)
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,)
                             * len(axes))
    return jax.make_mesh(shape, axes)


def make_mesh_auto(shape, axes):
    """Back-compat alias of the all-devices form of `make_mesh`."""
    return make_mesh(tuple(shape), tuple(axes))


def make_servers_mesh(K: int):
    """('servers',) mesh over the first K devices (devices = servers).

    The flat coded-Shuffle fused path maps one Shuffle server per device.
    """
    return make_mesh((K,), ("servers",), prefix=True)


def make_racks_mesh(topology: Topology):
    """('racks', 'servers') mesh over the first R x S devices.

    Device (rho, s) is server ``rho * S + s`` - the same contiguous-block
    rule as `Topology.rack_of`, so plan server indices and mesh coordinates
    agree by construction. The hierarchical fused exchange runs its coded
    XOR all_gather on the 'racks' axis and its plain gather/scatter on the
    'servers' axis.
    """
    return make_mesh((topology.racks, topology.servers_per_rack),
                     ("racks", "servers"), prefix=True)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (2 pods, 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    return make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 50e9                  # bytes/s per link
