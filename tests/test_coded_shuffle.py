"""Bit-exactness and load-accounting tests for the coded Shuffle (paper §IV)."""
import itertools

import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.bitcodec import (T_BITS, bits_to_floats, floats_to_bits,
                                 segment_bounds)
from repro.core.coded_shuffle import coded_load, run_coded
from repro.core.uncoded_shuffle import missing_pairs, run_uncoded, uncoded_load


def _values(g):
    """Deterministic distinct float32 values on the edges."""
    rng = np.random.default_rng(7)
    v = rng.standard_normal((g.n, g.n)).astype(np.float32)
    return np.where(g.adj, v, 0.0).astype(np.float32)


@pytest.mark.parametrize("K,r", [(4, 1), (4, 2), (4, 3), (5, 2), (5, 3), (5, 4), (6, 2)])
def test_coded_recovers_every_missing_value_bit_exact(K, r):
    n = divisible_n(50, K, r)
    g = gm.erdos_renyi(n, 0.25, seed=K * 10 + r)
    alloc = er_allocation(n, K, r)
    vals = _values(g)
    coded = run_coded(g.adj, vals, alloc)
    for k in range(K):
        for i, j in missing_pairs(g.adj, alloc, k):
            got = coded.delivered[k].get((int(i), int(j)))
            assert got is not None, f"({i},{j}) not delivered to {k}"
            # Bit-exact: float equality, not allclose.
            assert np.float32(got) == vals[i, j]


@pytest.mark.parametrize("K,r", [(5, 2), (5, 3), (6, 3)])
def test_coded_load_matches_bits_actually_sent(K, r):
    n = divisible_n(40, K, r)
    g = gm.erdos_renyi(n, 0.3, seed=1)
    alloc = er_allocation(n, K, r)
    coded = run_coded(g.adj, _values(g), alloc)
    # coded_load() is the schedule-only accounting; the executed shuffle plus
    # (empty here) leftovers must send exactly those bits.
    assert coded.bits_sent == round(coded_load(g.adj, alloc) * n * n * T_BITS)


@pytest.mark.parametrize("r", [2, 3, 4])
def test_inverse_linear_gain(r):
    """The heart of Theorem 1: coded load ~ uncoded load / r."""
    K = 5
    n = divisible_n(300, K, r)
    g = gm.erdos_renyi(n, 0.1, seed=42)
    alloc = er_allocation(n, K, r)
    lu = uncoded_load(g.adj, alloc)
    lc = coded_load(g.adj, alloc)
    gain = lu / lc
    # Finite-n: gain within 20% of r (paper Fig. 5 shows near-r at n=300).
    assert gain > 0.8 * r, f"gain {gain:.2f} vs r={r}"
    assert gain <= r * 1.05 + 1e-9


def test_r_equals_K_needs_no_communication():
    K = 4
    n = divisible_n(24, K, K)
    g = gm.erdos_renyi(n, 0.5, seed=0)
    alloc = er_allocation(n, K, K)
    assert uncoded_load(g.adj, alloc) == 0.0
    assert coded_load(g.adj, alloc) == 0.0


def test_uncoded_delivers_exactly_the_missing_set():
    n = divisible_n(40, 4, 2)
    g = gm.erdos_renyi(n, 0.3, seed=2)
    alloc = er_allocation(n, 4, 2)
    vals = _values(g)
    res = run_uncoded(g.adj, vals, alloc)
    for k in range(4):
        pairs = {tuple(map(int, p)) for p in missing_pairs(g.adj, alloc, k)}
        assert set(res.delivered[k].keys()) == pairs
    assert res.bits_sent == sum(
        len(missing_pairs(g.adj, alloc, k)) for k in range(4)) * T_BITS


def test_groups_partition_the_missing_set():
    """Every missing (i, j) is covered by exactly one (r+1)-group."""
    from repro.core.coded_shuffle import group_need

    K, r = 5, 2
    n = divisible_n(60, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=3)
    alloc = er_allocation(n, K, r)
    seen: dict = {}
    for S in itertools.combinations(range(K), r + 1):
        for k in S:
            for i, j in group_need(g.adj, alloc, S, k):
                key = (k, int(i), int(j))
                assert key not in seen, f"{key} covered twice ({seen[key]}, {S})"
                seen[key] = S
    want = {(k, int(i), int(j))
            for k in range(K) for i, j in missing_pairs(g.adj, alloc, k)}
    assert set(seen) == want


# ---- bitcodec ----

def test_bitcodec_roundtrip():
    x = np.array([0.0, -0.0, 1.5, -3.25e-12, np.inf, 7e37], dtype=np.float32)
    assert (bits_to_floats(floats_to_bits(x)).view(np.uint32)
            == x.view(np.uint32)).all()


@pytest.mark.parametrize("r", range(1, 9))
def test_segment_bounds_cover_exactly(r):
    bounds = segment_bounds(r)
    assert bounds[0][0] == 0 and bounds[-1][1] == T_BITS
    for (a, b), (c, d) in zip(bounds, bounds[1:]):
        assert b == c and b > a
    widths = [b - a for a, b in bounds]
    assert max(widths) - min(widths) <= 1
