"""Integration: the dry-run machinery end-to-end on small/fast cells.

Runs in subprocesses so the 512-placeholder-device XLA flag never leaks into
this test session (smoke tests must see 1 device). The full 80-cell sweep is
exercised by `launch/dryrun.py --all` (see dryrun_results_*.json); here we
pin one representative cell per step-kind.
"""
import json
import os
import subprocess
import sys

import pytest


def _run_cell(arch, shape, extra=()):
    cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
           "--shape", shape, *extra]
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=560,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    line = proc.stdout.strip().splitlines()[-1]
    return json.loads(line), proc


@pytest.mark.parametrize("arch,shape", [
    ("mamba2-370m", "long_500k"),      # decode / SSM / long-context
    ("internvl2-1b", "train_4k"),      # train / vlm frontend stub
])
def test_dryrun_cell_compiles_with_roofline(arch, shape):
    res, proc = _run_cell(arch, shape)
    assert res["status"] == "ok", proc.stderr[-1500:]
    assert res["chips"] == 256
    for key in ("t_compute_s", "t_memory_s", "t_collective_s", "bottleneck",
                "roofline_fraction", "coll_breakdown"):
        assert key in res
    assert res["flops_per_device"] > 0
    assert res["bytes_per_device"] > 0


def test_dryrun_multi_pod_mesh():
    res, proc = _run_cell("mamba2-370m", "decode_32k", ("--multi-pod",))
    assert res["status"] == "ok", proc.stderr[-1500:]
    assert res["chips"] == 512


def test_dryrun_skip_cells_report_reason():
    res, _ = _run_cell("gemma-7b", "long_500k")
    assert res["status"] == "skip"
    assert "sub-quadratic" in res["reason"]
    res, _ = _run_cell("hubert-xlarge", "decode_32k")
    assert res["status"] == "skip"
    assert "encoder-only" in res["reason"]
