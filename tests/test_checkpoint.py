"""Session checkpoint/restore: durability, bitwise resume, elastic restore.

The acceptance contract (ISSUE 7): checkpoint -> kill -> restore resumes
with a final state bitwise-identical to the uninterrupted run, both onto
the same K and elastically onto K' != K; and a crash injected at ANY point
of a save never corrupts the newest complete checkpoint (manifest-last
atomic publish).
"""
import json
import os

import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.checkpoint import (SessionCheckpointer, alloc_fingerprint,
                                   load_checkpoint)


@pytest.fixture
def setup():
    K, r = 5, 2
    n = divisible_n(60, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=3)
    return g, er_allocation(n, K, r), algo.pagerank()


def test_checkpoint_kill_restore_same_K_is_bitwise(setup, tmp_path):
    g, alloc, prog = setup
    full = engine.compile(prog, g, alloc, "coded").run(8)
    ck = SessionCheckpointer(str(tmp_path), keep=3)
    engine.compile(prog, g, alloc, "coded").run(5, checkpoint=ck,
                                                checkpoint_every=2)
    ck.wait()
    # "kill": the original session object is simply gone; a fresh process
    # rebuilds everything from (directory, program, graph).
    eng, ckpt = engine.restore(str(tmp_path), prog, g)
    assert ckpt.iteration == 5
    assert ckpt.fingerprint == alloc_fingerprint(alloc)
    res = eng.run(3, state=ckpt.state, start_iter=ckpt.iteration,
                  start_bits=ckpt.shuffle_bits)
    assert np.array_equal(res.state, full.state)
    assert res.shuffle_bits == full.shuffle_bits
    assert res.iters == full.iters


def test_elastic_restore_onto_different_K_is_bitwise(setup, tmp_path):
    g, alloc, prog = setup
    full = engine.compile(prog, g, alloc, "coded").run(8)
    ck = SessionCheckpointer(str(tmp_path))
    engine.compile(prog, g, alloc, "coded").run(4, checkpoint=ck,
                                                checkpoint_every=4)
    ck.wait()
    for K_new in (2, 4, 6):             # n=60 divides all of these at r=2
        eng, ckpt = engine.restore(str(tmp_path), prog, g, K=K_new)
        assert eng.alloc.K == K_new
        res = eng.run(4, state=ckpt.state, start_iter=ckpt.iteration)
        # State is bitwise-identical (canonical CSR reduce order); only the
        # schedule - hence the bits - changes with the membership.
        assert np.array_equal(res.state, full.state), K_new


def test_crash_mid_save_never_corrupts_latest(setup, tmp_path, monkeypatch):
    g, alloc, prog = setup
    ck = SessionCheckpointer(str(tmp_path), keep=5)
    ck.save(1, np.ones(4, np.float32), 100, alloc, blocking=True)
    good = load_checkpoint(str(tmp_path))

    # Crash at every byte boundary of the save sequence: array write,
    # manifest write, publish. Each must leave epoch_1 intact.
    real_save, real_dump, real_replace = np.save, json.dump, os.replace
    for fail in ("array", "manifest", "publish"):
        def boom(*a, **k):
            raise OSError(f"disk died during {fail}")
        if fail == "array":
            monkeypatch.setattr(np, "save", boom)
        elif fail == "manifest":
            monkeypatch.setattr(json, "dump", boom)
        else:
            monkeypatch.setattr(os, "replace", boom)
        ck.save(2, np.zeros(4, np.float32), 200, alloc)
        with pytest.raises(OSError, match="disk died"):
            ck.wait()                    # background failure surfaces here
        monkeypatch.setattr(np, "save", real_save)
        monkeypatch.setattr(json, "dump", real_dump)
        monkeypatch.setattr(os, "replace", real_replace)
        assert ck.epochs() == [1]
        again = load_checkpoint(str(tmp_path))
        assert again.iteration == good.iteration
        assert np.array_equal(again.state, good.state)

    # And after the disk "heals", the next save publishes normally.
    ck.save(2, np.zeros(4, np.float32), 200, alloc, blocking=True)
    assert ck.epochs() == [1, 2]


def test_manifest_last_partial_dirs_are_invisible(setup, tmp_path):
    g, alloc, prog = setup
    ck = SessionCheckpointer(str(tmp_path))
    ck.save(3, np.arange(4, dtype=np.float32), 7, None, blocking=True)
    # A torn copy (no manifest) and a scratch dir must both be ignored.
    os.makedirs(tmp_path / "epoch_9")
    np.save(tmp_path / "epoch_9" / "state.npy", np.zeros(4))
    os.makedirs(tmp_path / ".tmp_epoch_11")
    assert ck.epochs() == [3]
    assert load_checkpoint(str(tmp_path)).iteration == 3


def test_retention_keeps_newest_n(setup, tmp_path):
    _, alloc, _ = setup
    ck = SessionCheckpointer(str(tmp_path), keep=2)
    for it in range(1, 6):
        ck.save(it, np.full(3, it, np.float32), it * 10, None, blocking=True)
    assert ck.epochs() == [4, 5]
    assert ck.latest() == 5
    assert load_checkpoint(str(tmp_path), epoch=4).shuffle_bits == 40
    with pytest.raises(FileNotFoundError, match="epoch 1"):
        load_checkpoint(str(tmp_path), epoch=1)


def test_corruption_is_detected(setup, tmp_path):
    _, alloc, _ = setup
    ck = SessionCheckpointer(str(tmp_path))
    ck.save(1, np.ones(8, np.float32), 1, alloc, blocking=True)
    p = tmp_path / "epoch_1" / "state.npy"
    arr = np.load(p)
    arr[0] = -1.0
    np.save(p, arr)
    with pytest.raises(ValueError, match="digest mismatch"):
        load_checkpoint(str(tmp_path))


def test_restore_validation(setup, tmp_path):
    g, alloc, prog = setup
    with pytest.raises(FileNotFoundError, match="no checkpoints"):
        engine.restore(str(tmp_path), prog, g)
    ck = SessionCheckpointer(str(tmp_path))
    ck.save(1, np.ones(7, np.float32), 1, alloc, blocking=True)
    g_small = gm.erdos_renyi(10, 0.3, seed=0)
    with pytest.raises(ValueError, match="n="):
        engine.restore(str(tmp_path), prog, g_small)


def test_checkpoint_through_failure_epoch(setup, tmp_path):
    """Checkpoints taken while degraded record the degraded allocation, so
    a restore resumes on the post-failure membership."""
    g, alloc, prog = setup
    sched = faults.FaultSchedule([(1, "crash", (2,))])
    ck = SessionCheckpointer(str(tmp_path))
    res = engine.compile(prog, g, alloc, "coded").run(
        4, checkpoint=ck, checkpoint_every=1, fault_schedule=sched)
    ck.wait()
    eng, ckpt = engine.restore(str(tmp_path), prog, g)
    assert not ckpt.alloc.map_sets[2].any()      # degraded alloc persisted
    assert np.array_equal(ckpt.state, res.state)
    more = eng.run(2, state=ckpt.state, start_iter=ckpt.iteration)
    ref = algo.reference_run(prog, g, 6)
    assert np.array_equal(more.state, ref)
