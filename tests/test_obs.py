"""Telemetry subsystem (PR 8): spans, metrics, and bench helpers.

Contracts under test:

* a **disabled** tracer is a hard no-op — `CompiledEngine.run` under it is
  bitwise identical to an untraced run, `span()` hands back one shared
  null-span singleton, and nothing is collected;
* an **enabled** tracer produces the deterministic pinned span tree for a
  seeded 3-iteration coded run — compile + all five Theorem-1 phases per
  iteration — including fault events and checkpoint spans, with the summed
  exchange-span bits equal to the run's `shuffle_bits` (the Definition-2
  numerator, denormalized via `loads()`);
* Chrome-trace export round-trips through JSON with the span structure;
* counters / gauges / histograms behave, quantiles interpolate, and the
  registry exports parseable Prometheus text;
* the shared bench helpers (`measure` / `timeit` / `stopwatch`) obey their
  warmup/reps/reduction semantics.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.core.checkpoint import SessionCheckpointer
from repro.core.faults import FaultSchedule
from repro.core.bitcodec import T_BITS


def _case(n=48, K=4, r=2, p=0.2, seed=11):
    from repro import graphs
    n = divisible_n(n, K, r)
    return graphs.erdos_renyi(n, p, seed=seed), er_allocation(n, K, r)


@pytest.fixture
def tracer():
    """Fresh enabled tracer installed as the process tracer for one test."""
    t = obs.Tracer(enabled=True)
    prev = obs.set_tracer(t)
    yield t
    obs.set_tracer(prev)


# ---- disabled path: hard no-op ------------------------------------------

def test_disabled_tracer_returns_null_span_singleton():
    t = obs.Tracer(enabled=False)
    a = t.span("phase.map", nnz=10)
    b = t.span("phase.reduce")
    assert a is b                          # one shared singleton, no alloc
    with a as sp:
        sp.set(bits=1)                     # all no-ops
    t.event("fault.crash", at=0)
    assert t.roots == []
    assert t.tree() == ()


def test_disabled_tracer_run_is_bitwise_noop():
    g, alloc = _case()
    prog = algo.pagerank()

    ref = engine.compile(prog, g, alloc, "coded", path="sparse").run(3)

    off = obs.Tracer(enabled=False)
    prev = obs.set_tracer(off)
    try:
        res = engine.compile(prog, g, alloc, "coded", path="sparse").run(3)
    finally:
        obs.set_tracer(prev)

    assert np.array_equal(res.state, ref.state)
    assert res.shuffle_bits == ref.shuffle_bits
    assert off.roots == []


# ---- enabled path: the pinned span tree ---------------------------------

PHASES = ("phase.map", "phase.encode", "phase.exchange", "phase.decode",
          "phase.reduce")


def test_pinned_span_tree_coded_run(tracer):
    g, alloc = _case()
    sess = engine.compile(algo.pagerank(), g, alloc, "coded", path="sparse")
    res = sess.run(3)

    iteration = ("engine.iteration", tuple((p, ()) for p in PHASES))
    assert tracer.tree() == (
        ("engine.compile", (("plan.compile", ()),)),
        ("engine.run", (iteration,) * 3),
    )

    # Span-attributed bits must equal the engine's own load accounting:
    # the exchange spans carry the Definition-2 numerator exactly.
    span_bits = sum(s.attrs["bits"] for s in tracer.find("phase.exchange"))
    assert span_bits == res.shuffle_bits
    assert res.normalized_load == span_bits / (g.n * g.n * T_BITS * res.iters)

    run_sp, = tracer.find("engine.run")
    assert run_sp.attrs["shuffle_bits"] == res.shuffle_bits
    for it, sp in enumerate(tracer.find("engine.iteration")):
        assert sp.attrs["iteration"] == it
        assert sp.duration_s > 0


def test_span_tree_with_faults_and_checkpoints(tracer, tmp_path):
    """Crash/recover boundaries and checkpoint epochs land in the tree."""
    g, alloc = _case(K=4, r=2)
    ck = SessionCheckpointer(str(tmp_path))
    sched = FaultSchedule([(1, "crash", (1,)), (2, "recover", (1,))])
    sess = engine.compile(algo.pagerank(), g, alloc, "coded", path="sparse")
    res = sess.run(3, checkpoint=ck, checkpoint_every=1, fault_schedule=sched)
    ck.wait()

    phases = tuple((p, ()) for p in PHASES)
    save = ("checkpoint.save", ())
    run_tree = next(r.tree() for r in tracer.roots if r.name == "engine.run")
    assert run_tree == ("engine.run", (
        ("engine.iteration", phases + (save,)),
        # crash boundary: the fault event then the in-place plan surgery
        ("engine.iteration",
         (("fault.crash", ()), ("plan.repair", ())) + phases + (save,)),
        # recovery boundary: back on the original compiled session
        ("engine.iteration", (("fault.recover", ()),) + phases + (save,)),
    ))

    # The actual writes happen on the checkpoint writer thread, so they are
    # separate roots (one per epoch), not children of checkpoint.save.
    writes = [r for r in tracer.roots if r.name == "checkpoint.write"]
    assert sorted(w.attrs["iteration"] for w in writes) == [1, 2, 3]
    assert all(w.thread != threading.current_thread().name for w in writes)

    crash, = tracer.find("fault.crash")
    assert crash.instant and crash.attrs["servers"] == "1"
    repair, = tracer.find("plan.repair")
    assert repair.attrs["failed"] == "1"
    assert repair.attrs["handover_bits"] > 0
    assert res.faults.crashes == 1 and res.faults.recoveries == 1


def test_chrome_trace_export_roundtrip(tracer, tmp_path):
    with tracer.span("engine.run", iters=1):
        with tracer.span("phase.exchange", bits=np.int64(96)):
            pass
        tracer.event("fault.crash", at=0)
    path = tracer.dump_chrome_trace(str(tmp_path / "trace.json"))
    doc = json.loads(open(path).read())
    by_name = {e["name"]: e for e in doc["traceEvents"]}
    assert by_name["engine.run"]["ph"] == "X"
    assert by_name["engine.run"]["dur"] >= by_name["phase.exchange"]["dur"]
    assert by_name["phase.exchange"]["args"]["bits"] == 96   # json-safe int
    assert by_name["fault.crash"]["ph"] == "i"
    assert by_name["thread_name"]["ph"] == "M"


def test_span_records_error_and_thread_nesting(tracer):
    with pytest.raises(ValueError):
        with tracer.span("engine.run"):
            raise ValueError("boom")
    sp, = tracer.find("engine.run")
    assert sp.attrs["error"] == "ValueError"

    # Spans opened on another thread nest on that thread's own stack.
    def worker():
        with tracer.span("other"):
            pass

    th = threading.Thread(target=worker, name="obs-worker")
    th.start()
    th.join()
    other, = tracer.find("other")
    assert other.thread == "obs-worker"
    assert other in tracer.roots           # not a child of the main thread


# ---- metrics ------------------------------------------------------------

def test_counter_and_gauge():
    reg = obs.MetricsRegistry()
    c = reg.counter("queries_total", help="admitted queries")
    c.inc()
    c.inc(2)
    assert c.value == 3
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("inflight")
    g.set(5)
    g.dec(2)
    assert g.value == 3
    assert reg.counter("queries_total") is c   # created once, fetched after
    with pytest.raises(ValueError):
        reg.gauge("queries_total")             # type clash is an error


def test_histogram_quantiles_interpolate():
    h = obs.Histogram("lat", buckets=(0.1, 0.2, 0.4, 0.8))
    for v in (0.05, 0.15, 0.15, 0.3):
        h.observe(v)
    assert h.count == 4
    assert h.sum == pytest.approx(0.65)
    # p50 = rank 2 of 4 -> second bucket (0.1, 0.2], both its obs covered
    assert 0.1 <= h.quantile(0.5) <= 0.2
    assert h.quantile(1.0) == pytest.approx(0.4)
    assert h.quantile(0.0) == 0.0
    ps = h.percentiles((50, 99))
    assert set(ps) == {"p50", "p99"}
    assert ps["p50"] <= ps["p99"]
    with pytest.raises(ValueError):
        h.quantile(1.5)


def test_prometheus_text_format():
    reg = obs.MetricsRegistry()
    reg.counter("serve_queries_total", help="admitted").inc(7)
    h = reg.histogram("serve_query_latency_seconds", buckets=(0.5, 1.0))
    h.observe(0.3)
    h.observe(0.7)
    text = reg.to_prometheus_text()
    assert "# TYPE serve_queries_total counter" in text
    assert "serve_queries_total 7" in text
    assert 'serve_query_latency_seconds_bucket{le="0.5"} 1' in text
    assert 'serve_query_latency_seconds_bucket{le="+Inf"} 2' in text
    assert "serve_query_latency_seconds_count 2" in text
    assert text.endswith("\n")


# ---- bench helpers ------------------------------------------------------

def test_measure_warmup_and_reps():
    calls = []

    def fn():
        calls.append(1)
        return len(calls)

    m = obs.measure(fn, reps=3, warmup=2)
    assert len(calls) == 5                 # 2 warmup + 3 timed
    assert m.result == 5                   # last rep's return value
    assert len(m.times_s) == 3
    assert m.best_s <= m.mean_s <= m.worst_s
    assert m.reduced_s("max") == m.worst_s
    with pytest.raises(ValueError):
        m.reduced_s("median")
    with pytest.raises(ValueError):
        obs.measure(fn, reps=0)


def test_measure_sync_and_memory():
    synced = []
    m = obs.measure(lambda: np.zeros(1 << 16), reps=2, warmup=0,
                    sync=synced.append, trace_memory=True)
    assert len(synced) == 2                # applied to every timed rep
    assert m.peak_bytes >= (1 << 16) * 8   # the float64 buffer was counted


def test_timeit_and_stopwatch():
    assert obs.timeit(lambda: None, reps=2, warmup=0) >= 0.0
    with obs.stopwatch() as sw:
        sum(range(1000))
    assert sw.s > 0
    assert sw.us == pytest.approx(sw.s * 1e6)
