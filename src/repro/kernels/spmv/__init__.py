"""Pallas kernel package."""
