"""CSR-native graph subsystem: samplers, ingestion, CSR-primary Graph.

Covers the `repro.graphs` package contract:
  * streaming samplers are statistically equivalent to the legacy dense
    reference samplers (edge-count concentration, power-law degree tail,
    structural zeros for RB) while never allocating [n, n];
  * the edge-list loader's normalization invariants (dedup, symmetrize,
    self-loop strip, contiguous relabel, largest-CC) on the committed
    karate fixture, plus write/load round-trips;
  * the CSR-primary `Graph`: lazy guarded dense views, representation-
    agnostic cached `degrees()`/`num_edges`/`density`, isolated-vertex
    padding, and the vectorized allocation satellites.
"""
import math

import numpy as np
import pytest

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import (divisible_n, er_allocation,
                                   random_allocation)
from repro.core.graph_models import CSR, Graph, csr_from_undirected

# ---- samplers: statistical sanity + dense-sampler equivalence ----

SEEDS = range(6)


def _edge_stats(sampler, seeds, **kw):
    return np.array([sampler(seed=s, **kw).num_edges for s in seeds],
                    dtype=float)


def test_er_edge_count_concentration():
    n, p = 300, 0.06
    N = n * (n - 1) // 2
    sigma = math.sqrt(N * p * (1 - p))
    counts = _edge_stats(graphs.erdos_renyi, SEEDS, n=n, p=p)
    # Pooled mean within 5 pooled-sigma of the binomial expectation.
    assert abs(counts.mean() - N * p) < 5 * sigma / math.sqrt(len(counts))


@pytest.mark.parametrize("model,kw", [
    ("er", dict(n=300, p=0.06)),
    ("rb", dict(n1=150, n2=100, q=0.08)),
    ("sbm", dict(n1=150, n2=100, p=0.15, q=0.05)),
    ("pl", dict(n=400, gamma=2.5)),
])
def test_csr_sampler_statistically_matches_dense(model, kw):
    """Same edge-probability law as the legacy dense sampler: mean edge
    counts over seeds agree within 5 sigma of their pooled spread."""
    a = _edge_stats(lambda seed: graphs.sample(model, seed=seed, **kw), SEEDS)
    b = _edge_stats(lambda seed: gm.sample(model, seed=seed, **kw), SEEDS)
    spread = max(a.std(), b.std(), 1.0) / math.sqrt(len(SEEDS))
    assert abs(a.mean() - b.mean()) < 5 * math.sqrt(2) * spread, (a, b)


def test_sbm_block_concentration():
    n1, n2, p, q = 150, 100, 0.2, 0.05
    g = graphs.stochastic_block(n1, n2, p, q, seed=3)
    adj = g.adj
    intra1 = adj[:n1, :n1].sum() // 2
    intra2 = adj[n1:, n1:].sum() // 2
    cross = adj[:n1, n1:].sum()
    for count, trials, prob in [(intra1, n1 * (n1 - 1) // 2, p),
                                (intra2, n2 * (n2 - 1) // 2, p),
                                (cross, n1 * n2, q)]:
        sigma = math.sqrt(trials * prob * (1 - prob))
        assert abs(count - trials * prob) < 5 * sigma, (count, trials * prob)


def test_rb_has_zero_intra_cluster_edges():
    n1, n2 = 80, 50
    g = graphs.random_bipartite(n1, n2, 0.2, seed=1)
    csr = g.csr
    side = csr.rows < n1
    # Every edge crosses the cluster boundary - structural zeros intra.
    assert (csr.indices[side] >= n1).all()
    assert (csr.indices[~side] < n1).all()


def test_power_law_degree_tail():
    g = graphs.power_law(2000, 2.5, seed=2)
    deg = g.degrees()
    mean = deg.mean()
    # Heavy tail: the max degree dwarfs the mean, but the tail mass is thin.
    assert deg.max() > 8 * mean
    assert (deg > 10 * mean).mean() < 0.02
    assert mean > 1.0            # E[d] = (gamma-1)/(gamma-2) = 3 pre-clip


@pytest.mark.parametrize("model,kw", [
    ("er", dict(n=120, p=0.1)),
    ("rb", dict(n1=60, n2=40, q=0.15)),
    ("sbm", dict(n1=60, n2=40, p=0.2, q=0.05)),
    ("pl", dict(n=150, gamma=2.5)),
])
def test_csr_samplers_are_simple_undirected(model, kw):
    g = graphs.sample(model, seed=4, **kw)
    adj = g.adj
    assert (adj == adj.T).all()
    assert not adj.diagonal().any()
    assert g.csr.nnz == 2 * g.num_edges
    # Canonical entry order: rows nondecreasing, columns ascending per row.
    csr = g.csr
    np.testing.assert_array_equal(
        csr.rows, np.repeat(np.arange(g.n), np.diff(csr.indptr)))
    for i in np.flatnonzero(np.diff(csr.indptr) > 1)[:10]:
        seg = csr.indices[csr.indptr[i]:csr.indptr[i + 1]]
        assert (np.diff(seg) > 0).all()


# ---- edge-list ingestion ----


def test_fixture_normalization_invariants():
    """Raw fixture: 78 karate edges + comment noise, duplicate lines, one
    self-loop, and a detached 3-vertex component with gapped labels."""
    g = graphs.load_fixture(largest_cc=False)
    assert g.n == 37 and g.num_edges == 80          # dedup + self-loop strip
    labels = g.params["labels"]
    np.testing.assert_array_equal(labels[:34], np.arange(1, 35))
    np.testing.assert_array_equal(labels[34:], [101, 102, 105])
    csr = g.csr
    assert (csr.rows != csr.indices).all()          # no self-loops survive
    adj = g.adj
    assert (adj == adj.T).all()                      # symmetrized


def test_fixture_largest_cc():
    g = graphs.load_fixture()                        # largest_cc=True default
    assert g.n == 34 and g.num_edges == 78
    np.testing.assert_array_equal(g.params["labels"], np.arange(1, 35))
    # Known karate degrees: hub 1 has 16 neighbors, hub 34 has 17.
    assert g.degrees()[0] == 16 and g.degrees()[33] == 17
    from repro.graphs.io import _components
    csr = g.csr
    assert (_components(csr.rows.astype(np.int64),
                        csr.indices.astype(np.int64), g.n) == 0).all()


def test_normalize_edges_dedup_symmetrize_relabel():
    u = np.array([7, 3, 3, 9, 9, 7])
    v = np.array([3, 7, 3, 7, 7, 9])                 # dups, reverse, loop
    lo, hi, labels = graphs.normalize_edges(u, v)
    np.testing.assert_array_equal(labels, [3, 7, 9])
    got = set(zip(lo.tolist(), hi.tolist()))
    assert got == {(0, 1), (1, 2)}                   # (3,7) and (7,9)


def test_edge_list_round_trip(tmp_path):
    g = graphs.erdos_renyi(90, 0.08, seed=13)
    path = tmp_path / "er.edges"
    graphs.write_edge_list(g, path, header="round-trip fixture")
    g2 = graphs.load_graph(path)
    np.testing.assert_array_equal(g.csr.indptr, g2.csr.indptr)
    np.testing.assert_array_equal(g.csr.indices, g2.csr.indices)


def test_read_edge_list_formats():
    u, v = graphs.read_edge_list(["# c", "% c", "1 2", "3,4", "5 6 0.25"])
    np.testing.assert_array_equal(u, [1, 3, 5])
    np.testing.assert_array_equal(v, [2, 4, 6])
    with pytest.raises(ValueError, match="two fields"):
        graphs.read_edge_list(["7"])


def test_degenerate_edge_lists():
    g = graphs.load_graph(["# only comments", "5 5"])   # self-loop only
    assert g.n == 0 and g.num_edges == 0
    with pytest.raises(ValueError, match="no edges"):
        graphs.load_graph(["# only comments", "5 5"], largest_cc=True)


# ---- streaming vectorized edge-list parser (PR 5) ----


def _parse_both(tmp_path, raw: bytes, **kw):
    """(fast-path-from-file, reference-from-lines) for byte-parity checks."""
    from repro.graphs import io as gio
    p = tmp_path / "t.edges"
    p.write_bytes(raw)
    got = graphs.read_edge_list(p, **kw)
    want = gio._parse_lines(raw.decode().splitlines(), ("#", "%"), 0)
    return got, want


def test_read_edge_list_separator_and_noise_zoo(tmp_path):
    raw = (b"# comment 12 34\n% other style\n1 2\n3,4\r\n5\t6\t0.25\n"
           b" 7 8 garbage trailing\n\n   \n9 10 1423931633\n")
    (u, v), (uw, vw) = _parse_both(tmp_path, raw)
    np.testing.assert_array_equal(u, [1, 3, 5, 7, 9])
    np.testing.assert_array_equal(v, [2, 4, 6, 8, 10])
    np.testing.assert_array_equal(u, uw)
    np.testing.assert_array_equal(v, vw)


def test_read_edge_list_karate_byte_parity_across_chunks(tmp_path):
    """Path fast path == line-by-line reference on the committed fixture,
    for chunk sizes that split lines, tokens, and comments everywhere."""
    from repro.graphs import io as gio
    with open(graphs.fixture_path()) as f:
        uw, vw = gio._parse_lines(list(f), ("#", "%"), 0)
    for chunk_bytes in (1, 3, 7, 64, 1 << 22):
        u, v = graphs.read_edge_list(graphs.fixture_path(),
                                     chunk_bytes=chunk_bytes)
        np.testing.assert_array_equal(u, uw)
        np.testing.assert_array_equal(v, vw)


def test_read_edge_list_empty_variants(tmp_path):
    for raw in (b"", b"\n\n", b"# only\n% comments\n", b"   \n\t\n"):
        (u, v), (uw, vw) = _parse_both(tmp_path, raw)
        assert u.size == 0 and v.size == 0 and uw.size == 0
        assert u.dtype == np.int64


def test_read_edge_list_no_trailing_newline(tmp_path):
    (u, v), _ = _parse_both(tmp_path, b"1 2\n3 4")
    np.testing.assert_array_equal(u, [1, 3])
    np.testing.assert_array_equal(v, [2, 4])


def test_read_edge_list_fallback_matches_reference(tmp_path):
    """Blocks the vectorized pass cannot certify re-parse through the
    reference: negative labels parse, malformed fields raise identically."""
    (u, v), (uw, vw) = _parse_both(tmp_path, b"-1 2\n3 4\n")
    np.testing.assert_array_equal(u, [-1, 3])
    np.testing.assert_array_equal(u, uw)
    np.testing.assert_array_equal(v, vw)
    for raw, match in [(b"1 2\n7\n", "line 2: need at least two fields"),
                       (b"1.5 2\n", "invalid literal"),
                       (b"x 1 2\n", "invalid literal"),
                       (b",,,\n", "line 1: need at least two fields")]:
        with pytest.raises(ValueError, match=match):
            _parse_both(tmp_path, raw)


def test_read_edge_list_bare_cr_line_endings(tmp_path):
    """Universal-newline parity: bare '\\r' terminates a line (classic-Mac
    files), it must not collapse records into one line's ignored tail."""
    (u, v), (uw, vw) = _parse_both(tmp_path, b"1 2\r3 4\n")
    np.testing.assert_array_equal(u, [1, 3])
    np.testing.assert_array_equal(v, [2, 4])
    np.testing.assert_array_equal(u, uw)
    np.testing.assert_array_equal(v, vw)
    # Wholly CR-terminated file (no '\n' at all), small chunks included.
    raw = b"# cr file\r1 2\r3 4\r5 6\r"
    p = tmp_path / "cr.edges"
    p.write_bytes(raw)
    for chunk_bytes in (4, 1 << 22):
        u, v = graphs.read_edge_list(p, chunk_bytes=chunk_bytes)
        np.testing.assert_array_equal(u, [1, 3, 5])
        np.testing.assert_array_equal(v, [2, 4, 6])
    # CRLF stays on the vectorized path and agrees too.
    (u, v), _ = _parse_both(tmp_path, b"1 2\r\n3 4\r\n")
    np.testing.assert_array_equal(u, [1, 3])


def test_read_edge_list_linenos_are_global_across_chunks(tmp_path):
    raw = b"1 2\n" * 100 + b"7\n"
    p = tmp_path / "t.edges"
    p.write_bytes(raw)
    with pytest.raises(ValueError, match="line 101"):
        graphs.read_edge_list(p, chunk_bytes=16)
    # Bare-CR terminators count as lines too, at any chunk size.
    p.write_bytes(b"1 2\r3 4\n7\n")
    for chunk_bytes in (8, 1 << 22):
        with pytest.raises(ValueError, match="line 3"):
            graphs.read_edge_list(p, chunk_bytes=chunk_bytes)


def test_read_edge_list_large_synthetic_parity(tmp_path):
    """SNAP-shaped file (~20k lines, tab-separated, comment header):
    vectorized fast path is byte-identical to the reference parser."""
    rng = np.random.default_rng(5)
    e = rng.integers(0, 10_000, size=(20_000, 2))
    body = b"".join(b"%d\t%d\n" % (a, b) for a, b in e)
    raw = b"# Directed graph (each unordered pair once)\n" + body
    (u, v), (uw, vw) = _parse_both(tmp_path, raw, chunk_bytes=1 << 14)
    np.testing.assert_array_equal(u, uw)
    np.testing.assert_array_equal(v, vw)
    np.testing.assert_array_equal(u, e[:, 0])
    np.testing.assert_array_equal(v, e[:, 1])


# ---- CSR-primary Graph ----


def test_csr_native_matches_dense_built():
    gc = graphs.erdos_renyi(100, 0.1, seed=6)
    gd = Graph(gc.adj, gc.model, gc.params)          # small n: guard allows
    np.testing.assert_array_equal(gc.csr.indptr, gd.csr.indptr)
    np.testing.assert_array_equal(gc.csr.indices, gd.csr.indices)
    np.testing.assert_array_equal(gc.degrees(), gd.degrees())
    assert gc.num_edges == gd.num_edges
    np.testing.assert_array_equal(gc.edge_weights(), gd.edge_weights())


def test_dense_guard_raises_and_override():
    g = graphs.erdos_renyi(64, 0.2, seed=1)
    g_small_limit = Graph(model=g.model, params=g.params, csr=g.csr,
                          dense_limit=10)
    with pytest.raises(ValueError, match="dense_limit"):
        g_small_limit.adj
    with pytest.raises(ValueError, match="dense_limit"):
        g_small_limit.weights()
    a = g_small_limit.to_dense(limit=100)            # explicit override
    np.testing.assert_array_equal(a, g.adj)
    # One to_dense override must not open the (8x larger) float64
    # weights() view on a CSR-native graph.
    with pytest.raises(ValueError, match="dense_limit"):
        g_small_limit.weights()
    # Dense-*built* graphs already paid for [n, n]: the guard must not
    # block their dense views (legacy oracle path above the limit).
    g_dense = Graph(g.adj, g.model, g.params, dense_limit=10)
    np.testing.assert_array_equal(g_dense.adj, g.adj)
    assert np.isfinite(g_dense.weights()[g.adj]).all()


def test_num_edges_and_density_no_csr_side_effect_on_dense_path():
    g = gm.erdos_renyi(80, 0.15, seed=2)
    m = g.num_edges
    assert "csr" not in g.__dict__                   # counted via adj row-sums
    assert g.density == g.adj.mean()
    assert m == int(g.adj.sum()) // 2
    # CSR built later must agree with the degree cache.
    np.testing.assert_array_equal(g.degrees(), np.diff(g.csr.indptr))


def test_graph_constructor_validation():
    with pytest.raises(ValueError, match="exactly one"):
        Graph()
    with pytest.raises(ValueError, match="exactly one"):
        Graph(np.zeros((2, 2), bool), csr=CSR(np.zeros(3, np.int64),
                                              np.zeros(0, np.int32),
                                              np.zeros(0, np.int32)))


def test_csr_from_undirected_canonical_order():
    csr = csr_from_undirected([2, 0], [1, 1], 3)
    np.testing.assert_array_equal(csr.indptr, [0, 1, 3, 4])
    np.testing.assert_array_equal(csr.indices, [1, 0, 2, 1])


# ---- padding + allocation satellites ----


def test_padded_graph_adds_isolated_vertices():
    g = graphs.erdos_renyi(50, 0.1, seed=3)
    g2 = g.padded(60)
    assert g2.n == 60 and g2.num_edges == g.num_edges
    np.testing.assert_array_equal(g2.degrees()[:50], g.degrees())
    assert (g2.degrees()[50:] == 0).all()
    assert g2.params["padded_from"] == 50
    with pytest.raises(ValueError, match="pad"):
        g.padded(49)
    assert g.padded(50) is g


def test_allocate_pads_awkward_n_end_to_end():
    """Arbitrary real-graph n drops into the coded engine via padding."""
    g = graphs.erdos_renyi(101, 0.1, seed=9)        # 101 divides nothing
    g2, alloc = graphs.allocate(g, 4, 2)
    assert alloc.n == divisible_n(101, 4, 2) == g2.n
    prog = algo.pagerank()
    ref = algo.reference_run(prog, g2, 3, path="sparse")
    for mode in ("uncoded", "coded"):
        res = engine.run(prog, g2, alloc, 3, mode=mode, path="sparse")
        np.testing.assert_array_equal(res.state, ref)


def test_er_allocation_pad_flag():
    alloc = er_allocation(101, 4, 2, pad=True)
    assert alloc.n == divisible_n(101, 4, 2)
    with pytest.raises(ValueError, match="divisible"):
        er_allocation(101, 4, 2)


def test_random_allocation_vectorized_consistency():
    alloc = random_allocation(60, 5, 3, seed=4)
    assert (alloc.map_sets.sum(axis=0) == 3).all()   # r replicas per vertex
    for v in range(0, 60, 7):
        expect = alloc.subsets[alloc.batch_of[v]]
        np.testing.assert_array_equal(np.flatnonzero(alloc.map_sets[:, v]),
                                      expect)


def test_batch_vertices_dict_lookup():
    alloc = er_allocation(divisible_n(40, 4, 2), 4, 2)
    for b, subset in enumerate(alloc.subsets):
        np.testing.assert_array_equal(alloc.batch_vertices(subset),
                                      np.flatnonzero(alloc.batch_of == b))
    # Unsorted input resolves; unknown subsets raise like tuple.index did.
    np.testing.assert_array_equal(alloc.batch_vertices((1, 0)),
                                  alloc.batch_vertices((0, 1)))
    with pytest.raises(ValueError, match="not a batch subset"):
        alloc.batch_vertices((0, 99))
