"""Fused (single-collective) coded Shuffle == literal scheme, on a real
multi-device mesh. Runs in a subprocess so the 6-device host-platform flag
never leaks into other tests."""
import json
import os
import subprocess
import sys

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
import json
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import algorithms as algo
from repro.core import graph_models as gm
from repro.core.allocation import divisible_n, er_allocation
from repro.core.fused_shuffle import run_fused
from repro.core.uncoded_shuffle import missing_pairs

K, r = 6, 2
n = divisible_n(60, K, r)
g = gm.erdos_renyi(n, 0.25, seed=5)
alloc = er_allocation(n, K, r)
prog = algo.pagerank()
values = np.asarray(prog.map_values(g, prog.init(g)), np.float32)
values = np.where(g.adj, values, 0.0).astype(np.float32)

mesh = jax.make_mesh((K,), ("servers",))
rec = np.asarray(run_fused(g, values, alloc, mesh))

ok, total = 0, 0
for k in range(K):
    for i, j in missing_pairs(g.adj, alloc, k):
        total += 1
        ok += rec[i, j].view(np.uint32) == values[i, j].view(np.uint32)
print(json.dumps({"ok": int(ok), "total": int(total)}))
"""


def test_build_schedule_is_adjacency_free_beyond_dense_limit():
    """Regression: `build_schedule` used to take a dense adjacency (and
    `run_fused` read `g.adj`), which trips the dense-materialization guard
    at scale. It now compiles via `compile_plan_csr` off the Graph, so
    schedule construction works on a CSR-native graph at n > dense_limit -
    and the guard proves the dense view never existed."""
    import pytest

    from repro import graphs
    from repro.core import graph_models as gm
    from repro.core.allocation import divisible_n, er_allocation
    from repro.core.fused_shuffle import build_schedule

    K, r = 8, 2
    n = divisible_n(21000, K, r)
    assert n > gm.DENSE_LIMIT
    g = graphs.erdos_renyi(n, 4.0 / n, seed=3)
    alloc = er_allocation(n, K, r)
    enc_idx, dec_src, dec_tgt, dec_strip = build_schedule(g, alloc)
    assert enc_idx.shape[0] == K and enc_idx.shape[2] == r
    assert dec_src.shape[0] == dec_tgt.shape[0] == dec_strip.shape[0] == K
    # Schedule tensors are plan-sized, not [n, n]-shaped.
    for a in (enc_idx, dec_src, dec_tgt, dec_strip):
        assert a.size < n * n // 8
    with pytest.raises(ValueError, match="dense_limit"):
        g.adj


def test_fused_shuffle_bit_exact_on_6_devices():
    # HOME must survive (jax device init blocks without a resolvable home
    # dir), and the CPU platform must be pinned so jax does not probe for an
    # accelerator the sandbox cannot initialize.
    proc = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                          text=True, timeout=300,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-2000:]
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    assert res["total"] > 100          # non-trivial demand
    assert res["ok"] == res["total"]   # every missing value recovered exactly
