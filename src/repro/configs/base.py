"""Model/config system for the assigned architectures.

Every architecture is expressed as one ModelConfig; `reduced()` yields the
small-family smoke-test variant; `input_specs()` yields ShapeDtypeStruct
stand-ins for the dry-run (never allocates).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_ff_expert: int = 0          # expert hidden dim (may differ from dense d_ff)
    capacity_factor: float = 1.25
    ep: bool = False              # shard_map expert parallelism (moe_ep.py)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 64

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 -> d_model // n_heads
    # attention flavor
    attn_pattern: tuple[str, ...] = ("global",)   # cycled over layers
    window: int = 4096            # local-attention window
    attn_softcap: Optional[float] = None
    logit_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    mla: Optional[MLAConfig] = None
    # ffn / moe
    moe: Optional[MoEConfig] = None
    moe_every: int = 1            # 2 -> dense/MoE layer interleave (llama4)
    # ssm / hybrid
    ssm: Optional[SSMConfig] = None
    attn_every: int = 0           # hybrid: shared attn block every k ssm layers
    # task shape
    encoder_only: bool = False
    frontend: Optional[str] = None   # None | 'audio' | 'vision'
    num_patches: int = 256           # vlm: vision tokens per image
    act: str = "silu"                # geglu activation (gemma: gelu)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- bookkeeping ----

    @property
    def is_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer attention flavor (cycled attn_pattern)."""
        p = self.attn_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def param_count(self) -> int:
        """Approximate total parameters (embedding + blocks)."""
        d, L = self.d_model, self.n_layers
        total = self.vocab * d                       # tied embedding
        if self.family in ("ssm", "hybrid"):
            s = self.ssm
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per = d * (2 * di + 2 * s.d_state + nh) + di * d \
                + s.conv_width * (di + 2 * s.d_state)
            total += L * per
            if self.family == "hybrid" and self.attn_every:
                hd = self.head_dim
                total += d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d + 3 * d * self.d_ff
        if self.family in ("dense", "moe", "audio", "vlm"):
            hd = self.head_dim
            if self.mla:
                m = self.mla
                attn = (d * m.q_lora_rank
                        + m.q_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                        + self.n_heads * m.v_head_dim * d)
            else:
                attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) \
                    + self.n_heads * hd * d
            if self.moe:
                e = self.moe
                moe_ffn = d * e.num_experts \
                    + e.num_experts * 3 * d * e.d_ff_expert \
                    + (3 * d * self.d_ff if e.num_shared else 0)
                n_moe = L // self.moe_every
                ffn_total = n_moe * moe_ffn + (L - n_moe) * 3 * d * self.d_ff
            else:
                ffn_total = L * 3 * d * self.d_ff
            total += L * attn + ffn_total
        return total

    def active_param_count(self) -> int:
        """Parameters touched per token (= param_count for dense)."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        n_moe = self.n_layers // self.moe_every
        inactive = n_moe * (e.num_experts - e.top_k) * 3 * self.d_model * e.d_ff_expert
        return self.param_count() - inactive

    def reduced(self) -> "ModelConfig":
        """Same family, toy size: smoke tests run one step on CPU."""
        kw = dict(
            name=self.name + "-smoke",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 6),
            d_model=64, n_heads=4, head_dim=16,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=128, vocab=512, window=8, num_patches=4)
        if self.moe:
            kw["moe"] = dataclasses.replace(
                self.moe, num_experts=4, top_k=min(self.moe.top_k, 2),
                d_ff_expert=64)
        if self.mla:
            kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=48,
                                  qk_nope_head_dim=16, qk_rope_head_dim=8,
                                  v_head_dim=16)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=16, head_dim=16,
                                            chunk=8)
        if self.attn_every:
            kw["attn_every"] = 2
        return dataclasses.replace(self, **kw)


# ---- assigned input shapes (LM family) ----

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def cell_supported(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason if skipped."""
    if cfg.encoder_only and shape.kind == "decode":
        return False, "encoder-only arch has no decode step"
    if shape.name == "long_500k" and not cfg.is_ssm:
        return False, "524k decode needs sub-quadratic attention (DESIGN.md §4)"
    return True, ""


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct stand-ins for every model input (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.kind == "train":
        if cfg.frontend == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                    "labels": jax.ShapeDtypeStruct((B, S), i32)}
        if cfg.frontend == "vision":
            st = S - cfg.num_patches
            return {"patches": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, st), i32),
                    "labels": jax.ShapeDtypeStruct((B, st), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32),
                "labels": jax.ShapeDtypeStruct((B, S), i32)}
    if shape.kind == "prefill":
        if cfg.frontend == "audio":
            return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)}
        if cfg.frontend == "vision":
            st = S - cfg.num_patches
            return {"patches": jax.ShapeDtypeStruct((B, cfg.num_patches, cfg.d_model), jnp.bfloat16),
                    "tokens": jax.ShapeDtypeStruct((B, st), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}
    # decode: one new token against a seq_len-sized cache
    return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
