"""gemma3-27b [dense] - 5:1 local:global, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b", family="dense",
    n_layers=62, d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab=262144, act="gelu", rope_theta=1_000_000.0,
    attn_pattern=("local",) * 5 + ("global",), window=1024,
)
