"""Distributed MapReduce-on-graph engine (paper §II-B execution model).

Simulates K servers bit-faithfully: each server Maps its subgraph M_k, the
Shuffle phase moves exactly the bits the chosen scheme prescribes, and each
server Reduces R_k using *only* locally-Mapped plus delivered values. Any
divergence from the single-machine oracle is therefore a real bug in the
allocation or coding logic, not a modeling artifact.

The multicast schedule depends only on (graph, allocation), so `run` compiles
a `ShufflePlan` once and replays it every iteration (compile-once /
execute-many); the schedule-completeness check that used to run per iteration
now runs once at compile time inside `compile_plan`.

Modes:
  single      - oracle, no distribution.
  uncoded     - baseline unicast shuffle   (load ~ p(1 - r/K)).
  coded       - paper's XOR multicast      (load ~ p(1 - r/K)/r), bit-exact.
  coded-fast  - same schedule/loads via the compiled plan, values moved
                directly (skips the XOR simulation; used for large sweeps).
  coded-ref   - the literal per-group reference (`coded_shuffle.run_coded`),
                kept for A/B validation and benchmarking against the plan.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import VertexProgram
from .allocation import Allocation
from .bitcodec import T_BITS
from .coded_shuffle import run_coded
from .graph_models import Graph
from .shuffle_plan import PlanShuffleResult, ShufflePlan, compile_plan
from .uncoded_shuffle import missing_pairs

PLAN_MODES = ("uncoded", "coded", "coded-fast")


@dataclasses.dataclass
class EngineResult:
    state: np.ndarray
    iters: int
    shuffle_bits: int            # total over all iterations
    mode: str

    @property
    def normalized_load(self) -> float:
        """Average per-iteration Definition-2 load."""
        n = self.state.shape[0]
        return self.shuffle_bits / max(self.iters, 1) / (n * n * T_BITS)


def _reduce_distributed(program: VertexProgram, g: Graph, alloc: Allocation,
                        values: np.ndarray,
                        delivered: dict[int, dict[tuple[int, int], float]],
                        state: np.ndarray) -> np.ndarray:
    """Dict-delivery Reduce (reference path; `faults.py` and coded-ref)."""
    new_state = np.empty_like(state)
    for k in range(alloc.K):
        vk = np.full((g.n, g.n), program.identity, dtype=np.float32)
        cols = alloc.map_sets[k]
        vk[:, cols] = values[:, cols]                  # locally Mapped
        for (i, j), v in delivered[k].items():
            vk[i, j] = v
        rk = alloc.reduce_owner == k
        # Verify the server really has everything it needs (catches schedule bugs).
        need = g.adj & rk[:, None]
        have = cols[None, :] | np.zeros((g.n, g.n), dtype=bool)
        for (i, j) in delivered[k]:
            have[i, j] = True
        if (need & ~have).any():
            miss = np.argwhere(need & ~have)[:5]
            raise RuntimeError(f"server {k} missing values, e.g. {miss.tolist()}")
        reduced = program.reduce(vk, g.adj, state, g)
        new_state[rk] = reduced[rk]
    return new_state


def _reduce_plan(program: VertexProgram, g: Graph, alloc: Allocation,
                 values: np.ndarray, res: PlanShuffleResult,
                 state: np.ndarray) -> np.ndarray:
    """Array-delivery Reduce: scatter each server's CSR slice, no dicts.

    Schedule completeness was verified once at plan-compile time, so the
    per-iteration missing-value scan of the dict path is not repeated here.
    """
    new_state = np.empty_like(state)
    for k in range(alloc.K):
        vk = np.full((g.n, g.n), program.identity, dtype=np.float32)
        cols = alloc.map_sets[k]
        vk[:, cols] = values[:, cols]                  # locally Mapped
        a, b = int(res.ptr[k]), int(res.ptr[k + 1])
        vk[res.i[a:b], res.j[a:b]] = res.values[a:b]   # delivered
        rk = alloc.reduce_owner == k
        reduced = program.reduce(vk, g.adj, state, g)
        new_state[rk] = reduced[rk]
    return new_state


def run(program: VertexProgram, g: Graph, alloc: Allocation | None,
        iters: int, mode: str = "coded",
        plan: ShufflePlan | None = None) -> EngineResult:
    """Execute `iters` rounds; plan modes compile the Shuffle schedule once
    and replay it (pass a pre-compiled `plan` to amortize across runs)."""
    state = program.init(g)
    total_bits = 0
    distributed = mode != "single" and alloc is not None
    if distributed and mode in PLAN_MODES and plan is None:
        # Uncoded only consumes the missing set; skip the column tables.
        plan = compile_plan(g.adj, alloc, schedule=mode != "uncoded")
    for _ in range(iters):
        values = program.map_values(g, state).astype(np.float32)
        if not distributed:
            state = program.reduce(values, g.adj, state, g)
            continue
        if mode in PLAN_MODES:
            res = plan.execute(values, mode)
            total_bits += res.bits_sent
            state = _reduce_plan(program, g, alloc, values, res, state)
        elif mode == "coded-ref":
            ref = run_coded(g.adj, values, alloc)
            delivered, bits = ref.delivered, ref.bits_sent
            bits += _unicast_leftovers(g, alloc, values, delivered)
            total_bits += bits
            state = _reduce_distributed(program, g, alloc, values, delivered,
                                        state)
        else:
            raise ValueError(f"unknown mode {mode!r}")
    return EngineResult(state, iters, total_bits, mode)


def _unicast_leftovers(g: Graph, alloc: Allocation, values: np.ndarray,
                       delivered: dict[int, dict[tuple[int, int], float]]) -> int:
    """Unicast whatever the coded groups did not cover (e.g. the phase-III
    spill Reducers of the bi-partite allocation, Appendix A)."""
    bits = 0
    for k in range(alloc.K):
        for i, j in missing_pairs(g.adj, alloc, k):
            if (int(i), int(j)) not in delivered[k]:
                delivered[k][(int(i), int(j))] = float(values[i, j])
                bits += T_BITS
    return bits
