"""Jitted public wrappers around the SpMV kernel (auto-padding + PageRank)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .ref import spmv as spmv_ref
from .spmv import spmv_pallas


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def spmv(adj: jnp.ndarray, x: jnp.ndarray, *, bm: int = 128, bk: int = 128,
         use_kernel: bool = True, interpret: bool = True) -> jnp.ndarray:
    """y = adj @ x, padding ragged shapes up to the tile grid."""
    if not use_kernel:
        return spmv_ref(adj, x)
    m, n = adj.shape
    a = _pad_to(_pad_to(adj.astype(jnp.float32), bm, 0), bk, 1)
    xp = _pad_to(x.astype(jnp.float32), bk, 0)
    return spmv_pallas(a, xp, bm=bm, bk=bk, interpret=interpret)[:m]


def pagerank_step(adj: jnp.ndarray, rank: jnp.ndarray, damping: float = 0.15,
                  **kw) -> jnp.ndarray:
    deg = jnp.maximum(adj.sum(axis=0), 1.0)
    acc = spmv(adj, rank / deg, **kw)
    return (1.0 - damping) * acc + damping / adj.shape[0]


def spmv_csr_rows(indptr: np.ndarray, indices: np.ndarray, c: np.ndarray,
                  n: int, *, rows: np.ndarray | None = None, bm: int = 128,
                  bk: int = 128, use_kernel: bool = True,
                  interpret: bool = True) -> np.ndarray:
    """acc[i] = sum_{j in row i} c[j] from a CSR adjacency, via the Pallas
    kernel in blocked [bm, n] row strips.

    The dense strip is densified from the CSR slice per block, so peak
    memory is O(bm * n) regardless of the row count - the sparse engine's
    `backend="spmv"` Reduce route. Every strip shares one compiled kernel
    (fixed [bm, n_pad] shape; the trailing partial strip is zero-padded).
    Pass the cached per-entry `rows` array (e.g. `Graph.csr.rows`) to avoid
    rebuilding it per call.
    """
    n_pad = n + (-n) % bk
    cj = jnp.asarray(np.pad(np.asarray(c, np.float32), (0, n_pad - n)))
    acc = np.empty(n, dtype=np.float32)
    if rows is None:
        rows = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    for start in range(0, n, bm):
        stop = min(start + bm, n)
        strip = np.zeros((bm, n_pad), dtype=np.float32)
        a, b = int(indptr[start]), int(indptr[stop])
        strip[rows[a:b] - start, indices[a:b]] = 1.0
        if use_kernel:
            y = spmv_pallas(jnp.asarray(strip), cj, bm=bm, bk=bk,
                            interpret=interpret)
        else:
            y = spmv_ref(jnp.asarray(strip), cj)
        acc[start:stop] = np.asarray(y)[:stop - start]
    return acc
