"""Paper Fig. 5: average communication load vs computation load r.

ER(n=300, p=0.1), K=5, averaged over graph realizations; overlays the
uncoded baseline, the coded scheme, and the information-theoretic lower
bound (Theorem 1 converse)."""
import time

import numpy as np

from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import divisible_n, er_allocation
from repro.core.coded_shuffle import coded_load
from repro.core.uncoded_shuffle import uncoded_load

K, P, SAMPLES = 5, 0.1, 5


def run(report):
    n = divisible_n(300, K, 2)
    rows = []
    for r in range(1, K + 1):
        alloc = er_allocation(n, K, r)
        lu, lc = [], []
        t0 = time.perf_counter()
        for s in range(SAMPLES):
            g = gm.erdos_renyi(n, P, seed=1000 + s)
            lu.append(uncoded_load(g.adj, alloc))
            lc.append(coded_load(g.adj, alloc))
        us = (time.perf_counter() - t0) / SAMPLES / (2 * K) * 1e6
        row = {
            "r": r,
            "uncoded": float(np.mean(lu)),
            "coded": float(np.mean(lc)),
            "lower_bound": loads.lower_bound_er(P, r, K),
            "uncoded_theory": loads.uncoded_load_er(P, r, K),
            "gain": float(np.mean(lu) / np.mean(lc)) if np.mean(lc) else float("nan"),
        }
        rows.append(row)
        report(f"fig5_r{r}", us, f"coded={row['coded']:.4f} "
               f"lb={row['lower_bound']:.4f} gain={row['gain']:.2f}")
    # Optimality gap at finite n (paper: "small optimality gap").
    gaps = [row["coded"] / row["lower_bound"]
            for row in rows if row["lower_bound"] > 0]
    report("fig5_optimality_gap", 0.0, f"max_coded/lb={max(gaps):.3f}")
    return rows
