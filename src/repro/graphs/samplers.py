"""O(edges) streaming samplers for the paper's four graph models.

Each sampler here is the CSR-native counterpart of the dense reference
sampler in `core.graph_models`: same model, same edge-probability law, but
the realization is drawn edge-by-edge instead of thresholding an [n, n]
uniform matrix, so time and memory are O(edges) and n >= 1e5 is routine.
The two samplers draw from *different RNG streams*, so realizations differ;
`tests/test_graphs.py` pins their statistical equivalence (edge-count
concentration, degree-tail shape) at small n.

Techniques:
  * ER / RB / SBM blocks: geometric edge-skipping. The candidate pairs of a
    block form a linear index space (upper triangle or rectangle); the
    sorted positions of Bernoulli(p) successes are recovered by cumulating
    Geometric(p) gaps - O(hits) draws, never O(candidates).
  * Power-law: Chung-Lu expected-degree sampling without the dense
    `np.outer` (Miller-Hagberg): vertices sorted by weight descending, one
    skipping pass per row with the bound probability updated as the row
    advances, accepted by thinning. O(n + edges) expected work.
"""
from __future__ import annotations

import math

import numpy as np

from ..core.graph_models import Graph

__all__ = ["erdos_renyi", "random_bipartite", "stochastic_block",
           "power_law", "sample"]


def _bernoulli_positions(total: int, p: float, rng) -> np.ndarray:
    """Sorted positions of successes among `total` Bernoulli(p) trials.

    Geometric edge-skipping: cumulate Geometric(p) gaps until the position
    stream passes `total`. O(total * p) time and memory in expectation.
    """
    if total <= 0 or p <= 0.0:
        return np.empty(0, dtype=np.int64)
    if p >= 1.0:
        return np.arange(total, dtype=np.int64)
    chunks: list[np.ndarray] = []
    pos = -1
    mean = total * p
    size = int(mean + 6.0 * math.sqrt(mean + 1.0) + 16)
    while True:
        gaps = rng.geometric(p, size=size).astype(np.int64)
        s = pos + np.cumsum(gaps)
        if s.size == 0 or s[-1] >= total:
            chunks.append(s[s < total])
            break
        chunks.append(s)
        pos = int(s[-1])
        size = max(16, int((total - pos) * p * 1.2 + 16))
    return np.concatenate(chunks)


def _triangle_pairs(pos: np.ndarray, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Map linear upper-triangle positions to (i, j), i < j, exactly.

    Row i owns positions [off_i, off_{i+1}) with off_i = i(n-1) - i(i-1)/2;
    the inverse is one integer searchsorted - no float sqrt, so it stays
    exact at n ~ 3e5 (offsets near 2^45).
    """
    i_arr = np.arange(n, dtype=np.int64)
    off = i_arr * (n - 1) - i_arr * (i_arr - 1) // 2
    i = np.searchsorted(off, pos, side="right") - 1
    j = i + 1 + (pos - off[i])
    return i, j


def _rect_pairs(pos: np.ndarray, n2: int) -> tuple[np.ndarray, np.ndarray]:
    """Map linear positions of an [n1, n2] rectangle to (row, col)."""
    return pos // n2, pos % n2


def erdos_renyi(n: int, p: float, seed: int = 0) -> Graph:
    """ER(n, p) drawn by geometric skipping over the n(n-1)/2 upper-triangle
    pairs; CSR-native, O(edges)."""
    rng = np.random.default_rng(seed)
    pos = _bernoulli_positions(n * (n - 1) // 2, p, rng)
    u, v = _triangle_pairs(pos, n)
    return Graph.from_edges(u, v, n, "er",
                            {"n": n, "p": p, "seed": seed, "sampler": "csr"})


def random_bipartite(n1: int, n2: int, q: float, seed: int = 0) -> Graph:
    """RB(n1, n2, q): per-block ER over the n1 x n2 cross rectangle only.

    Vertices [0, n1) form cluster 1 and [n1, n1+n2) cluster 2.
    """
    rng = np.random.default_rng(seed)
    pos = _bernoulli_positions(n1 * n2, q, rng)
    i, j = _rect_pairs(pos, n2)
    return Graph.from_edges(i, n1 + j, n1 + n2, "rb",
                            {"n1": n1, "n2": n2, "q": q, "seed": seed,
                             "sampler": "csr"})


def stochastic_block(n1: int, n2: int, p: float, q: float,
                     seed: int = 0) -> Graph:
    """SBM(n1, n2, p, q): three independent ER blocks - two intra-cluster
    triangles at p, one cross rectangle at q."""
    rng = np.random.default_rng(seed)
    u1, v1 = _triangle_pairs(_bernoulli_positions(n1 * (n1 - 1) // 2, p, rng),
                             n1)
    u2, v2 = _triangle_pairs(_bernoulli_positions(n2 * (n2 - 1) // 2, p, rng),
                             n2)
    ic, jc = _rect_pairs(_bernoulli_positions(n1 * n2, q, rng), n2)
    u = np.concatenate([u1, n1 + u2, ic])
    v = np.concatenate([v1, n1 + v2, n1 + jc])
    return Graph.from_edges(u, v, n1 + n2, "sbm",
                            {"n1": n1, "n2": n2, "p": p, "q": q, "seed": seed,
                             "sampler": "csr"})


def power_law(n: int, gamma: float, rho: float | None = None, seed: int = 0,
              d_min: float = 1.0) -> Graph:
    """PL(n, gamma, rho): Chung-Lu with P[(i,j) in E] = min(1, rho d_i d_j),
    sampled without the dense `np.outer` (Miller-Hagberg skipping).

    Expected degrees are iid power-law(gamma) inverse-CDF samples exactly as
    in the dense reference; if rho is None it is set to 1 / vol.
    """
    rng = np.random.default_rng(seed)
    u = rng.random(n)
    degrees = d_min * (1.0 - u) ** (-1.0 / (gamma - 1.0))
    if rho is None:
        rho = 1.0 / degrees.sum()
    perm = np.argsort(-degrees, kind="stable")     # heavy vertices first
    w = degrees[perm]
    us: list[int] = []
    vs: list[int] = []
    geometric, random = rng.geometric, rng.random  # scalar-draw fast path
    for i in range(n - 1):
        wi_rho = rho * w[i]
        j = i + 1
        p = min(1.0, wi_rho * w[j])
        while j < n and p > 0.0:
            if p < 1.0:
                j += int(geometric(p)) - 1         # skip to next candidate
            if j < n:
                q = min(1.0, wi_rho * w[j])
                if random() < q / p:               # thin the bound down to q
                    us.append(i)
                    vs.append(j)
                p = q
                j += 1
    uu = perm[np.asarray(us, dtype=np.int64)]
    vv = perm[np.asarray(vs, dtype=np.int64)]
    return Graph.from_edges(uu, vv, n, "pl",
                            {"n": n, "gamma": gamma, "rho": rho, "seed": seed,
                             "sampler": "csr"})


def sample(model: str, seed: int = 0, **kw) -> Graph:
    return {
        "er": erdos_renyi,
        "rb": random_bipartite,
        "sbm": stochastic_block,
        "pl": power_law,
    }[model](seed=seed, **kw)
