"""CI gate: fail if smoke benchmark wall-clock regresses vs the committed
baseline.

    python benchmarks/check_regression.py bench-smoke.json BENCH_scale.json

Compares every baseline record whose name starts with --prefix (default
``scale_``) against the fresh smoke run; a per-record wall-clock ratio above
--tol (default 2.0, override with $BENCH_TOL for noisy runners) or a missing
record fails the job. Derived metrics (loads, speedups) are informational.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _records(path: str, prefix: str) -> dict[str, float]:
    with open(path) as f:
        data = json.load(f)
    return {rec["name"]: float(rec["us_per_call"])
            for rec in data["records"]
            if rec["name"].startswith(prefix) and rec["us_per_call"] > 0}


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("current", help="fresh run.py --smoke --json output")
    ap.add_argument("baseline", help="committed baseline (BENCH_scale.json)")
    ap.add_argument("--prefix", default="scale_")
    ap.add_argument("--tol", type=float,
                    default=float(os.environ.get("BENCH_TOL", "2.0")))
    args = ap.parse_args(argv)

    cur = _records(args.current, args.prefix)
    base = _records(args.baseline, args.prefix)
    if not base:
        print(f"no baseline records with prefix {args.prefix!r} in "
              f"{args.baseline}", file=sys.stderr)
        return 1
    failed = []
    print(f"{'name':<40} {'base_us':>12} {'now_us':>12} {'ratio':>7}")
    for name, want in sorted(base.items()):
        got = cur.get(name)
        if got is None:
            print(f"{name:<40} {want:>12.1f} {'MISSING':>12} {'-':>7}")
            failed.append(name)
            continue
        ratio = got / want
        flag = " FAIL" if ratio > args.tol else ""
        print(f"{name:<40} {want:>12.1f} {got:>12.1f} {ratio:>6.2f}x{flag}")
        if ratio > args.tol:
            failed.append(name)
    if failed:
        print(f"\nwall-clock regression >{args.tol:.1f}x (or missing record) "
              f"in: {', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"\nall {len(base)} records within {args.tol:.1f}x of baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
