"""hubert-xlarge [audio] - encoder-only; frame embeddings are a stub frontend
[arXiv:2106.07447; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    encoder_only=True, frontend="audio",
)
