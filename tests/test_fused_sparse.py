"""Sparse multi-device coded Shuffle parity (shard_map, 8 forced host devices).

The fused sparse path (`fused_shuffle.FusedSparseShuffle`) must deliver
*bitwise-identical* uint32 words to the NumPy plan executor
(`ShufflePlan.execute_coded_sparse`) - across all four graph models x
{pagerank, sssp}, all three encode routes (batched xor_code jnp oracle,
Pallas kernel, plain jnp), the unicast-leftover spill, and the full
`engine.run(path="sparse", backend="fused")` loop - while constructing no
[n, n]-shaped array anywhere (schedule shape-guard + dense-materialization
guard + tracemalloc enforced, including at n > dense_limit).

Runs in subprocesses so the 8-device host-platform flag never leaks into
other tests; HOME and JAX_PLATFORMS=cpu are passed through per the ROADMAP
note (jax device probing hangs without them).
"""
import json
import os
import subprocess
import sys

PREAMBLE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import tracemalloc
import numpy as np

from repro import graphs
from repro.core import algorithms as algo
from repro.core import engine, faults
from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.bitcodec import floats_to_words
from repro.core.fused_shuffle import FusedSparseShuffle
from repro.core.shuffle_plan import compile_plan_csr

out = {}


def case(model):
    # CSR-native graphs (repro.graphs streaming samplers) - the fused path
    # never needs a dense view.
    if model == "er":
        n = divisible_n(48, 4, 2)
        return graphs.erdos_renyi(n, 0.2, seed=11), er_allocation(n, 4, 2)
    if model == "pl":
        n = divisible_n(60, 4, 2)
        return graphs.power_law(n, 2.5, seed=9), er_allocation(n, 4, 2)
    if model == "rb":
        return (graphs.random_bipartite(48, 24, 0.3, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    if model == "sbm":
        return (graphs.stochastic_block(48, 24, 0.25, 0.1, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    raise ValueError(model)


def parity(g, alloc, prog, iters=2, **kw):
    # Two iterations replay the same jitted exchange on fresh values - the
    # compile-once/execute-many contract, checked word-for-word per round.
    plan = compile_plan_csr(g.csr, alloc)
    tables = plan.edge_tables(g.csr, alloc)
    fx = FusedSparseShuffle(plan, g.csr, alloc, **kw)
    state = prog.init(g)
    ok = True
    for _ in range(iters):
        ev = prog.map_edge_values(g, state).astype(np.float32)
        ref = plan.execute_coded_sparse(ev, tables)
        res = fx.execute(ev)
        ok = ok and np.array_equal(floats_to_words(ref.values),
                                   floats_to_words(res.values))
        ok = ok and ref.bits_sent == res.bits_sent
        buf = np.concatenate([ev, ref.values])
        state = prog.reduce_edges(buf[tables.gather], g.csr.indptr, state, g)
    return bool(ok)
"""

SCRIPT_PARITY = PREAMBLE + r"""
for model in ("er", "rb", "sbm", "pl"):
    g, alloc = case(model)
    for prog in (algo.pagerank(), algo.sssp(0)):
        out[f"{model}_{prog.name}"] = parity(g, alloc, prog)

# Unicast-leftover spill (bipartite r > K2: cluster-2 batches uncovered).
g, alloc = (graphs.random_bipartite(48, 24, 0.3, seed=5),
            bipartite_allocation(48, 24, 6, 3))
plan = compile_plan_csr(g.csr, alloc)
out["spill_has_leftovers"] = bool(plan.left_k.size > 0)
out["spill_pagerank"] = parity(g, alloc, algo.pagerank())
out["spill_sssp"] = parity(g, alloc, algo.sssp(0))

# Encode routes: Pallas kernel (interpret) and plain jnp vs the default.
g, alloc = case("er")
out["encode_xor_kernel"] = parity(g, alloc, algo.pagerank(), iters=1,
                                  encode="xor-kernel")
out["encode_jnp"] = parity(g, alloc, algo.pagerank(), iters=1, encode="jnp")

# Mid-run failure recovery rides the same CSR plans on this 8-device host.
g, alloc = case("er")
res_f, stats = faults.run_with_failure(algo.pagerank(), g, alloc, 3,
                                       failed=(1,), fail_at_iter=1)
out["faults_bitwise"] = bool(np.array_equal(
    res_f.state, algo.reference_run(algo.pagerank(), g, 3, path="sparse")))
out["faults_recovery_bits"] = int(stats.recovery_bits)
print(json.dumps(out))
"""

SCRIPT_ENGINE = PREAMBLE + r"""
# --- acceptance: 10-iteration coded PageRank, fused == numpy, K = 8 ---
K, r = 8, 3
n = divisible_n(280, K, r)
g0 = graphs.erdos_renyi(n, 0.15, seed=3)
# dense_limit=1: ANY [n, n] materialization anywhere on the path raises.
g = gm.Graph(model=g0.model, params=g0.params, csr=g0.csr, dense_limit=1)
alloc = er_allocation(n, K, r)
prog = algo.pagerank()
plan = compile_plan_csr(g.csr, alloc)
rn = engine.run(prog, g, alloc, 10, mode="coded", plan=plan, path="sparse")
rf = engine.run(prog, g, alloc, 10, mode="coded", plan=plan, path="sparse",
                backend="fused")
out["engine_10it_bitwise"] = bool(np.array_equal(
    floats_to_words(rn.state), floats_to_words(rf.state)))
out["engine_bits_equal"] = bool(rn.shuffle_bits == rf.shuffle_bits)
out["guard_held"] = True
try:
    g.adj
    out["guard_held"] = False
except ValueError:
    pass

# --- n > dense_limit: the path that used to be capped at toy n ---
K, r = 8, 2
n = divisible_n(21000, K, r)
assert n > gm.DENSE_LIMIT
g = graphs.erdos_renyi(n, 6.0 / n, seed=7)     # default guard active (n>2e4)
alloc = er_allocation(n, K, r)
tracemalloc.start()
plan = compile_plan_csr(g.csr, alloc)
tables = plan.edge_tables(g.csr, alloc)
fx = FusedSparseShuffle(plan, g.csr, alloc)
ev = prog.map_edge_values(g, prog.init(g)).astype(np.float32)
ref = plan.execute_coded_sparse(ev, tables)
res = fx.execute(ev)
_, peak = tracemalloc.get_traced_memory()
tracemalloc.stop()
out["scale_words_bitwise"] = bool(np.array_equal(
    floats_to_words(ref.values), floats_to_words(res.values)))
nnz, M = g.csr.nnz, int(plan.all_k.size)
out["scale_peak_mb"] = peak / 1e6
out["scale_peak_o_edges"] = bool(peak < 1500 * nnz)   # O(nnz+plan), not O(n^2)
out["scale_peak_below_dense"] = bool(peak < n * n)    # any [n,n] f32 would trip

# Shape guard: every partitioned table is [nnz]/[plan]-sized and the
# per-device rows are 1/K slices (+ padding slack) - nothing O(n^2)-shaped.
s = fx.sched
arrays = [s.loc_e, s.enc_l, s.enc_shift, s.enc_mask, s.dec_s, s.dec_w,
          s.dec_mask, s.dec_shift, s.strip_l, s.strip_shift, s.strip_mask]
out["tables_not_dense"] = bool(all(a.size < n * n // 8 for a in arrays))
C = int(plan.col_sender.size) + int(plan.left_k.size)
out["per_device_loc"] = bool(s.Lmax <= 2 * r * nnz // K + 8)
out["per_device_cols"] = bool(s.W <= 2 * C // K + 8)
out["per_device_deliveries"] = bool(s.Dmax <= 2 * M // K + 8)
print(json.dumps(out))
"""


def _run(script, timeout=900):
    # HOME must survive (jax device init blocks without a resolvable home
    # dir), and the CPU platform must be pinned so jax does not probe for
    # an accelerator the sandbox cannot initialize.
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout,
                          env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                               "HOME": os.environ.get("HOME", "/tmp"),
                               "JAX_PLATFORMS": "cpu"})
    assert proc.returncode == 0, proc.stderr[-4000:]
    return json.loads(proc.stdout.strip().splitlines()[-1])


def test_fused_sparse_word_parity_models_programs_and_spill():
    res = _run(SCRIPT_PARITY)
    for model in ("er", "rb", "sbm", "pl"):
        for prog in ("pagerank", "sssp"):
            assert res[f"{model}_{prog}"], (model, prog)
    assert res["spill_has_leftovers"]          # the case really spills
    assert res["spill_pagerank"] and res["spill_sssp"]
    assert res["encode_xor_kernel"] and res["encode_jnp"]
    assert res["faults_bitwise"]
    assert res["faults_recovery_bits"] > 0


def test_fused_engine_acceptance_and_beyond_dense_limit():
    res = _run(SCRIPT_ENGINE)
    assert res["engine_10it_bitwise"]          # acceptance criterion
    assert res["engine_bits_equal"]
    assert res["guard_held"]                   # no [n, n] ever materialized
    assert res["scale_words_bitwise"]          # n > dense_limit, bit-exact
    assert res["scale_peak_o_edges"], res["scale_peak_mb"]
    assert res["scale_peak_below_dense"]
    assert res["tables_not_dense"]
    assert res["per_device_loc"]
    assert res["per_device_cols"]
    assert res["per_device_deliveries"]
