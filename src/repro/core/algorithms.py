"""Vertex programs expressed as MapReduce pairs (paper §II-A, Examples 1-2).

An algorithm supplies two interchangeable forms of the same Map/Reduce pair:

Dense form (the paper-literal oracle, O(n^2) per iteration):
  map_values(graph, state)  -> V [n, n] float32 where V[i, j] = g_{i,j}(w_j)
                               for (i, j) in E (garbage elsewhere; the engine
                               masks with the adjacency),
  reduce(vals, mask, state) -> new state from each vertex's neighbor values,
  identity                  -> the padding value that is absorbing for reduce.

Edge-value form (the O(edges) execution path; all four built-ins supply it):
  map_edge_values(graph, state)        -> [nnz] float32, one value per CSR
                                          entry e = (i, j), equal bitwise to
                                          map_values(...)[i, j],
  reduce_edges(vals, indptr, state, g) -> new state via a segment reduction
                                          over the CSR rows (np.add.reduceat /
                                          np.minimum.reduceat).

Contract: each execution path must match the *same-form* single-machine
oracle (`reference_run(path=...)`) bitwise - the sparse engine accumulates
every row in canonical CSR entry order, so distributed == oracle exactly.
Across forms, min-reductions (sssp, cc) and integer sums (degree) are also
bitwise equal; pagerank's float sum legitimately differs by reduction order
(dense row-sum vs sequential reduceat), within a few ulp.

Programs whose Map value depends only on the source vertex and whose Reduce
is a plain sum (pagerank, degree) additionally expose `map_source` ([n]
per-source values) and `finalize` (elementwise epilogue), which lets the
engine route the blocked row reduction through the kernels/spmv Pallas tiles
(`backend="spmv"`).

Batched (multi-query) form: every edge-value form here is
*batch-polymorphic* - state may be [n] (one query) or [n, B] (B concurrent
queries), in which case `map_edge_values` returns [nnz, B] and
`reduce_edges` segment-reduces each column independently (reduceat over
axis 0 accumulates every column in the same canonical CSR entry order, so
column b of a batched run is the same reduction sequence as a standalone
run of that query - bitwise for min/integer programs, the contract the
batched engine path relies on). `multi_sssp` and `personalized_pagerank`
construct natively-batched programs (B roots / B preference vectors); the
coded Shuffle schedule is value-agnostic, so one exchange carries all B
columns (see `engine.CompiledEngine.run_batch`).

The dense-matrix form is the blocked-dense TPU adaptation (DESIGN.md §3): a
PageRank Map over a vertex block is one column-scaled adjacency tile, and the
Reduce is a masked row reduction - both MXU/VPU friendly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from .graph_models import Graph


@dataclasses.dataclass(frozen=True)
class VertexProgram:
    name: str
    identity: float
    init: Callable[[Graph], np.ndarray]
    map_values: Callable[[Graph, np.ndarray], np.ndarray]
    reduce: Callable[[np.ndarray, np.ndarray, np.ndarray, Graph], np.ndarray]
    # Edge-value (sparse) form; None => program only supports the dense path.
    map_edge_values: Callable[[Graph, np.ndarray], np.ndarray] | None = None
    reduce_edges: Callable[[np.ndarray, np.ndarray, np.ndarray, Graph],
                           np.ndarray] | None = None
    # Linear-program extras for the blocked spmv backend (sum-reduce programs
    # whose v_{i,j} depends only on source j): v_e = map_source(g, state)[j].
    map_source: Callable[[Graph, np.ndarray], np.ndarray] | None = None
    finalize: Callable[[np.ndarray, np.ndarray, Graph], np.ndarray] | None = None

    @property
    def supports_sparse(self) -> bool:
        return (self.map_edge_values is not None
                and self.reduce_edges is not None)


def segment_reduce(ufunc, vals: np.ndarray, indptr: np.ndarray,
                   identity: float) -> np.ndarray:
    """`ufunc.reduceat` over CSR row segments; empty rows -> identity.

    reduceat accumulates sequentially within a segment, so the reduction
    order is the canonical CSR entry order - the bitwise contract shared by
    the single-machine sparse oracle and the distributed sparse engine.
    Batched vals [nnz, B] reduce each column independently (reduceat over
    axis 0), in the same per-column order as a standalone [nnz] run.
    """
    out = np.full((indptr.size - 1,) + vals.shape[1:], identity,
                  dtype=np.float32)
    starts = indptr[:-1]
    nonempty = indptr[1:] > starts
    if vals.size:
        out[nonempty] = ufunc.reduceat(vals, starts[nonempty], axis=0)
    return out


def _per_edge(w: np.ndarray, state: np.ndarray) -> np.ndarray:
    """Broadcast a per-edge/per-vertex vector against a possibly-batched
    state: [m] for state [n], [m, 1] for state [n, B]."""
    return w if state.ndim == 1 else w[:, None]


def pagerank(damping: float = 0.15) -> VertexProgram:
    """Example 1. state = rank vector Pi; v_{i,j} = Pi(j) / deg(j)."""

    def init(g: Graph) -> np.ndarray:
        return np.full(g.n, 1.0 / g.n, dtype=np.float32)

    def map_source(g: Graph, state: np.ndarray) -> np.ndarray:
        deg = np.maximum(g.degrees(), 1)
        return (state / _per_edge(deg, state)).astype(np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.broadcast_to(map_source(g, state)[None, :], (g.n, g.n))

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return map_source(g, state)[g.csr.indices]

    def finalize(acc: np.ndarray, state: np.ndarray, g: Graph) -> np.ndarray:
        return ((1.0 - damping) * acc + damping / g.n).astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        return finalize(np.where(mask, vals, 0.0).sum(axis=1), state, g)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        return finalize(segment_reduce(np.add, vals, indptr, 0.0), state, g)

    return VertexProgram("pagerank", 0.0, init, map_values, reduce,
                         map_edge_values, reduce_edges, map_source, finalize)


def sssp(source: int = 0) -> VertexProgram:
    """Example 2. state = distance vector D; v_{i,j} = D(j) + t(j, i)."""

    def init(g: Graph) -> np.ndarray:
        d = np.full(g.n, np.inf, dtype=np.float32)
        d[source] = 0.0
        return d

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        w = g.weights()
        return (state[None, :] + w.T).astype(np.float32)   # t(j, i) = w[j, i]

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        # w is symmetric and edge_weights() shares one draw per undirected
        # edge, so state[j] + w_e == the dense (i, j) entry bitwise.
        w = g.edge_weights()
        return (state[g.csr.indices] + _per_edge(w, state)).astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        m = segment_reduce(np.minimum, vals, indptr, np.inf)
        return np.minimum(state, m).astype(np.float32)

    return VertexProgram("sssp", np.inf, init, map_values, reduce,
                         map_edge_values, reduce_edges)


def connected_components() -> VertexProgram:
    """Min-label propagation; converges to per-component min vertex id."""

    def init(g: Graph) -> np.ndarray:
        return np.arange(g.n, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.broadcast_to(state[None, :], (g.n, g.n)).astype(np.float32)

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return state[g.csr.indices].astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        vals = np.where(mask, vals, np.inf)
        return np.minimum(state, vals.min(axis=1, initial=np.inf)).astype(np.float32)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        m = segment_reduce(np.minimum, vals, indptr, np.inf)
        return np.minimum(state, m).astype(np.float32)

    return VertexProgram("cc", np.inf, init, map_values, reduce,
                         map_edge_values, reduce_edges)


def degree_count() -> VertexProgram:
    """Trivial one-shot program: each vertex counts its neighbors."""

    def init(g: Graph) -> np.ndarray:
        return np.zeros(g.n, dtype=np.float32)

    def map_source(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones(state.shape, dtype=np.float32)

    def map_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones((g.n, g.n), dtype=np.float32)

    def map_edge_values(g: Graph, state: np.ndarray) -> np.ndarray:
        return np.ones((g.csr.nnz,) + state.shape[1:], dtype=np.float32)

    def finalize(acc: np.ndarray, state: np.ndarray, g: Graph) -> np.ndarray:
        return acc.astype(np.float32)

    def reduce(vals, mask, state, g: Graph) -> np.ndarray:
        return finalize(np.where(mask, vals, 0.0).sum(axis=1), state, g)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        return finalize(segment_reduce(np.add, vals, indptr, 0.0), state, g)

    return VertexProgram("degree", 0.0, init, map_values, reduce,
                         map_edge_values, reduce_edges, map_source, finalize)


def _no_dense(name: str):
    """Dense-form stub for natively-batched programs (sparse path only)."""

    def stub(*_a, **_k):
        raise ValueError(
            f"{name} is a batched program: it has no dense [n, n] form; "
            "run it on path='sparse' (the engine default)")
    return stub


def multi_sssp(sources) -> VertexProgram:
    """B-query SSSP: state [n, B], column b is the distance vector from
    ``sources[b]``.

    The Map/Reduce forms are the batch-polymorphic sssp forms, so one coded
    Shuffle exchange carries all B queries and column b is *bitwise* equal
    to a standalone ``sssp(sources[b])`` run (min-reductions accumulate in
    the same canonical CSR entry order per column).
    """
    sources = tuple(int(s) for s in np.atleast_1d(sources))
    if not sources:
        raise ValueError("multi_sssp needs at least one source")
    single = sssp(sources[0])

    def init(g: Graph) -> np.ndarray:
        bad = [s for s in sources if not 0 <= s < g.n]
        if bad:
            raise ValueError(f"sources {bad} out of range [0, {g.n})")
        d = np.full((g.n, len(sources)), np.inf, dtype=np.float32)
        d[sources, np.arange(len(sources))] = 0.0
        return d

    return VertexProgram("multi_sssp", np.inf, init,
                         _no_dense("multi_sssp"), _no_dense("multi_sssp"),
                         single.map_edge_values, single.reduce_edges)


def personalized_pagerank(prefs: np.ndarray,
                          damping: float = 0.15) -> VertexProgram:
    """B-query personalized PageRank: state [n, B], column b converges to
    the PPR vector of preference (teleport) distribution ``prefs[:, b]``.

    Iteration: state <- (1 - damping) * A_hat state + damping * prefs. The
    Map and row-sum Reduce are the batch-polymorphic pagerank forms, so one
    coded Shuffle exchange carries all B queries; per column the float-sum
    reduction order equals the standalone order (within-ulp contract of
    float sums, exactly as the single-query pagerank path). With a uniform
    column prefs[:, b] = 1/n this is ordinary PageRank up to the rounding
    of ``damping * float32(1/n)`` vs ``damping / n``.
    """
    prefs = np.asarray(prefs, dtype=np.float32)
    if prefs.ndim == 1:
        prefs = prefs[:, None]
    if prefs.ndim != 2 or not prefs.size:
        raise ValueError(f"prefs must be [n] or [n, B], got {prefs.shape}")
    single = pagerank(damping)

    def init(g: Graph) -> np.ndarray:
        if prefs.shape[0] != g.n:
            raise ValueError(
                f"prefs are for n={prefs.shape[0]} vertices, graph has "
                f"n={g.n}")
        return prefs.copy()

    def finalize(acc: np.ndarray, state: np.ndarray, g: Graph) -> np.ndarray:
        return ((1.0 - damping) * acc + damping * prefs).astype(np.float32)

    def reduce_edges(vals, indptr, state, g: Graph) -> np.ndarray:
        return finalize(segment_reduce(np.add, vals, indptr, 0.0), state, g)

    return VertexProgram("ppr", 0.0, init,
                         _no_dense("personalized_pagerank"),
                         _no_dense("personalized_pagerank"),
                         single.map_edge_values, reduce_edges,
                         single.map_source, finalize)


def uniform_prefs(n: int, B: int = 1) -> np.ndarray:
    """[n, B] uniform preference columns (ordinary-PageRank teleport)."""
    return np.full((n, B), 1.0 / n, dtype=np.float32)


def reference_run(program: VertexProgram, g: Graph, iters: int,
                  path: str = "auto") -> np.ndarray:
    """Single-machine oracle: the engine (any mode) must match this exactly.

    path="sparse" (or "auto" when the program has an edge-value form) runs
    the O(edges) form; path="dense" runs the paper-literal [n, n] form. Each
    engine path is bit-exact against the *same-path* oracle; see the module
    docstring for the cross-path contract.
    """
    if path not in ("auto", "sparse", "dense"):
        raise ValueError(f"unknown path {path!r}")
    if path == "sparse" and not program.supports_sparse:
        raise ValueError(f"{program.name} has no edge-value (sparse) form")
    sparse = path != "dense" and program.supports_sparse
    state = program.init(g)
    if sparse:
        indptr = g.csr.indptr
        for _ in range(iters):
            vals = program.map_edge_values(g, state).astype(np.float32)
            state = program.reduce_edges(vals, indptr, state, g)
    else:
        for _ in range(iters):
            vals = program.map_values(g, state)
            state = program.reduce(vals, g.adj, state, g)
    return state
