"""Hypothesis property tests on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property tests are skipped, not ERRORs")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from test_delta_plan import check_delta_vs_fresh, mk_delta
from test_schedule_invariants import (check_flat_degeneracy,
                                      check_hierarchical_levels,
                                      check_plan_csr_identity,
                                      check_schedule_complete,
                                      check_sparse_dense_delivery_equal,
                                      check_word_conservation)

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import (divisible_n, er_allocation,
                                   random_allocation)
from repro.core.bitcodec import bits_to_floats, floats_to_bits, split_segments
from repro.core.coded_shuffle import coded_load
from repro.core.uncoded_shuffle import uncoded_load
from repro.launch.mesh import Topology

kr = st.tuples(st.integers(3, 6), st.integers(1, 4)).filter(lambda t: t[1] <= t[0])


@st.composite
def graph_allocs(draw):
    """Random small (graph, allocation, topology) draws for the invariants.

    Covers all three allocation families (block ER, interleaved ER, random
    placement - the last has no multicast structure by design, which is
    exactly why the invariants must still hold on it) over ER and power-law
    realizations, including r = 1 (no coding) and r = K (full replication).
    The topology dimension draws any rack shape R x S = K - from the flat
    S=1 form to the one-rack R=1 form - driving the two-level invariants
    over the same random pair space.
    """
    K = draw(st.integers(3, 6))
    r = draw(st.integers(1, min(K, 4)))
    n = divisible_n(draw(st.integers(20, 70)), K, r)
    seed = draw(st.integers(0, 10_000))
    if draw(st.booleans()):
        g = gm.erdos_renyi(n, draw(st.floats(0.05, 0.5)), seed=seed)
    else:
        g = gm.power_law(n, draw(st.floats(2.2, 3.0)), seed=seed)
    kind = draw(st.sampled_from(["er", "er-interleave", "random"]))
    if kind == "random":
        alloc = random_allocation(n, K, r, seed=seed)
    else:
        alloc = er_allocation(n, K, r, interleave=kind == "er-interleave")
    S = draw(st.sampled_from([s for s in range(1, K + 1) if K % s == 0]))
    return g, alloc, Topology(K // S, S)


@given(graph_allocs())
@settings(max_examples=25, deadline=None)
def test_schedule_completeness_property(case):
    check_schedule_complete(*case[:2])


@given(graph_allocs())
@settings(max_examples=25, deadline=None)
def test_xor_word_conservation_property(case):
    check_word_conservation(*case[:2])


@given(graph_allocs())
@settings(max_examples=25, deadline=None)
def test_compile_plan_csr_bitwise_identity_property(case):
    check_plan_csr_identity(*case[:2])


@given(graph_allocs())
@settings(max_examples=25, deadline=None)
def test_sparse_dense_delivery_equality_property(case):
    check_sparse_dense_delivery_equal(*case[:2])


@given(graph_allocs())
@settings(max_examples=20, deadline=None)
def test_hierarchical_flat_degeneracy_property(case):
    """Tentpole contract as a property: `Topology.flat(K)` compiles to
    arrays bitwise identical to `compile_plan_csr` on random pairs."""
    g, alloc, _ = case
    check_flat_degeneracy(g, alloc)


@given(graph_allocs())
@settings(max_examples=20, deadline=None)
def test_hierarchical_per_level_property(case):
    """Per-level completeness + word conservation + bitwise delivery
    equality for the drawn topology (flat draws degenerate gracefully)."""
    g, alloc, topo = case
    check_hierarchical_levels(g, alloc, topo)


@given(kr, st.integers(0, 10_000))
@settings(max_examples=25, deadline=None)
def test_allocation_invariants(kr_pair, seed):
    K, r = kr_pair
    n = divisible_n(30 + seed % 40, K, r)
    alloc = er_allocation(n, K, r)
    # Definition 1: computation load is exactly r.
    assert alloc.computation_load() == r
    # Every server Maps exactly r n/K vertices (Remark 1).
    assert (alloc.map_sets.sum(axis=1) == r * n // K).all()
    # Reduce partition: disjoint, complete, n/K each.
    counts = np.bincount(alloc.reduce_owner, minlength=K)
    assert (counts == n // K).all()
    # Each vertex Mapped at exactly the r servers of its batch subset.
    assert (alloc.map_sets.sum(axis=0) == r).all()


@given(kr, st.floats(0.05, 0.6), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_coded_load_never_exceeds_uncoded(kr_pair, p, seed):
    K, r = kr_pair
    n = divisible_n(40, K, r)
    g = gm.erdos_renyi(n, p, seed=seed)
    alloc = er_allocation(n, K, r)
    assert coded_load(g.adj, alloc) <= uncoded_load(g.adj, alloc) + 1e-12


@given(st.lists(st.floats(allow_nan=False, width=32), min_size=1, max_size=64),
       st.integers(1, 8))
@settings(max_examples=50, deadline=None)
def test_bitcodec_split_reassemble(xs, r):
    x = np.array(xs, dtype=np.float32)
    bits = floats_to_bits(x)
    segs = split_segments(bits, r)
    reassembled = np.concatenate(segs, axis=1)
    assert (bits_to_floats(reassembled).view(np.uint32)
            == x.view(np.uint32)).all()


@given(st.integers(0, 1000), st.floats(0.1, 0.5))
@settings(max_examples=10, deadline=None)
def test_distributed_pagerank_equals_oracle(seed, p):
    K, r = 4, 2
    n = divisible_n(36, K, r)
    g = gm.erdos_renyi(n, p, seed=seed)
    alloc = er_allocation(n, K, r)
    prog = algo.pagerank()
    ref = algo.reference_run(prog, g, 2)
    res = engine.run(prog, g, alloc, 2, mode="coded")
    np.testing.assert_array_equal(res.state, ref)


@given(st.integers(2, 8), st.integers(2, 8), st.floats(0.01, 1.0))
@settings(max_examples=30, deadline=None)
def test_time_model_optimum(tm_int, ts_int, scale):
    """r* = sqrt(T_shuffle/T_map) minimizes the continuous Remark-10 model."""
    from repro.core.loads import optimal_r, total_time_model
    t_map, t_shuffle = tm_int * scale, ts_int * scale * 10
    r_star = optimal_r(t_map, t_shuffle)
    t_opt = total_time_model(r_star, t_map, t_shuffle, 0.0)
    for r in np.linspace(max(0.2, r_star / 3), r_star * 3, 17):
        assert total_time_model(float(r), t_map, t_shuffle, 0.0) >= t_opt - 1e-9


@given(st.sampled_from(["er", "rb", "sbm", "pl"]), st.integers(0, 50))
@settings(max_examples=16, deadline=None)
def test_graph_models_are_simple_undirected(model, seed):
    kw = {
        "er": dict(n=40, p=0.3),
        "rb": dict(n1=24, n2=16, q=0.3),
        "sbm": dict(n1=24, n2=16, p=0.4, q=0.1),
        "pl": dict(n=40, gamma=2.5),
    }[model]
    g = gm.sample(model, seed=seed, **kw)
    assert (g.adj == g.adj.T).all()
    assert not g.adj.diagonal().any()
    if model == "rb":
        assert not g.adj[:24, :24].any() and not g.adj[24:, 24:].any()


@st.composite
def graph_alloc_deltas(draw):
    """(graph, allocation, EdgeDelta) draws for the incremental-maintenance
    contract: random insert/delete batches (including empty and one-sided
    ones) over the same allocation families as `graph_allocs`."""
    g, alloc, _ = draw(graph_allocs())
    rng = np.random.default_rng(draw(st.integers(0, 10_000)))
    nins = draw(st.integers(0, 6))
    ndel = draw(st.integers(0, 6))
    return g, alloc, mk_delta(g, rng, nins, ndel)


@given(graph_alloc_deltas(), st.booleans())
@settings(max_examples=20, deadline=None)
def test_apply_delta_bitwise_identity_property(case, sched):
    """PR 9 tentpole gate: for random (graph, alloc, delta) draws,
    `ShufflePlan.apply_delta` is bitwise-identical to a fresh
    `compile_plan_csr` of the mutated graph - every plan field and the
    carried edge tables."""
    g, alloc, delta = case
    check_delta_vs_fresh(g, alloc, delta, schedule=sched, ctx="property")


@st.composite
def alloc_failures(draw):
    """(graph, allocation, failed-set) draws for the degradation invariants,
    spanning |failed| from 1 to K-1 (so both the repair regime and the
    re-Map regime are exercised)."""
    g, alloc, _ = draw(graph_allocs())
    m = draw(st.integers(1, alloc.K - 1))
    failed = draw(st.sets(st.integers(0, alloc.K - 1),
                          min_size=m, max_size=m))
    return g, alloc, tuple(sorted(failed))


@given(alloc_failures())
@settings(max_examples=20, deadline=None)
def test_degrade_allocation_invariants_property(case):
    """PR 7 satellite: for random (alloc, failed) draws the degraded
    allocation keeps every vertex Mapped somewhere, hands Reduce ownership
    only to survivors, re-Maps nothing while |failed| < r, and
    `run_with_failure` (the coded repair path) stays bitwise-equal to the
    single-machine oracle."""
    from repro.core import faults

    g, alloc, failed = case
    degraded, stats = faults.degrade_allocation(alloc, failed)
    assert degraded.map_sets.any(axis=0).all()        # no vertex lost
    assert not np.isin(degraded.reduce_owner, failed).any()
    assert not degraded.map_sets[list(failed)].any()
    if len(failed) < alloc.r:
        assert stats.remapped_vertices == 0
    prog = algo.pagerank()
    res, rstats = faults.run_with_failure(prog, g, alloc, 2, failed,
                                          fail_at_iter=1)
    np.testing.assert_array_equal(res.state,
                                  algo.reference_run(prog, g, 2))
    assert rstats.remapped_vertices == stats.remapped_vertices
