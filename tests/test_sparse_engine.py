"""Sparse O(edges) engine path: oracle parity, memory, backends, caches.

Contract under test (see algorithms.py / engine.py docstrings):
  * every plan mode on the sparse path is *bitwise* equal to the sparse
    single-machine oracle, for all four vertex programs on all four graph
    models (the distributed gather reduces each row in canonical CSR order);
  * the sparse oracle matches the dense oracle bitwise for min-reduce and
    integer-sum programs (sssp, cc, degree) and to float-reduction-order
    tolerance for pagerank;
  * one sparse iteration never materializes a dense [n, n] buffer, and beats
    the dense `_reduce_plan` path outright at n ~ 1000+.
"""
import dataclasses
import time
import tracemalloc

import numpy as np
import pytest

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core.allocation import (bipartite_allocation, divisible_n,
                                   er_allocation)
from repro.core.shuffle_plan import compile_plan

PROGRAMS = [algo.pagerank(), algo.sssp(0), algo.connected_components(),
            algo.degree_count()]
PLAN_MODES = ["uncoded", "coded", "coded-fast"]


def _case(model):
    """(graph, allocation) per graph model, cached at module scope."""
    if model == "er":
        n = divisible_n(48, 4, 2)
        return gm.erdos_renyi(n, 0.2, seed=11), er_allocation(n, 4, 2)
    if model == "pl":
        n = divisible_n(60, 4, 2)
        return gm.power_law(n, 2.5, seed=9), er_allocation(n, 4, 2)
    if model == "rb":
        return (gm.random_bipartite(48, 24, 0.3, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    if model == "sbm":
        return (gm.stochastic_block(48, 24, 0.25, 0.1, seed=5),
                bipartite_allocation(48, 24, 6, 2))
    raise ValueError(model)


_CASES = {m: _case(m) for m in ("er", "rb", "sbm", "pl")}


@pytest.mark.parametrize("prog", PROGRAMS, ids=lambda p: p.name)
@pytest.mark.parametrize("model", ["er", "rb", "sbm", "pl"])
@pytest.mark.parametrize("mode", PLAN_MODES)
def test_sparse_engine_bitwise_matches_sparse_oracle(prog, model, mode):
    g, alloc = _CASES[model]
    ref = algo.reference_run(prog, g, 3, path="sparse")
    res = engine.run(prog, g, alloc, 3, mode=mode, path="sparse")
    np.testing.assert_array_equal(res.state, ref)


@pytest.mark.parametrize("prog", PROGRAMS, ids=lambda p: p.name)
@pytest.mark.parametrize("model", ["er", "rb", "sbm", "pl"])
def test_sparse_oracle_vs_dense_oracle(prog, model):
    g, _ = _CASES[model]
    ref_s = algo.reference_run(prog, g, 3, path="sparse")
    ref_d = algo.reference_run(prog, g, 3, path="dense")
    if prog.name == "pagerank":
        # Float sums legitimately differ by reduction order (dense row-sum
        # vs sequential reduceat): documented tolerance, not bitwise.
        np.testing.assert_allclose(ref_s, ref_d, rtol=1e-6, atol=1e-12)
    else:
        # min-reductions (sssp, cc) and integer sums (degree) are
        # order-independent, hence bitwise equal across paths.
        np.testing.assert_array_equal(ref_s, ref_d)


@pytest.mark.parametrize("mode", PLAN_MODES)
def test_sparse_and_dense_engine_agree_on_bits(mode):
    g, alloc = _CASES["er"]
    prog = algo.pagerank()
    a = engine.run(prog, g, alloc, 2, mode=mode, path="sparse")
    b = engine.run(prog, g, alloc, 2, mode=mode, path="dense")
    assert a.shuffle_bits == b.shuffle_bits
    np.testing.assert_allclose(a.state, b.state, rtol=1e-6, atol=1e-12)


def test_sparse_path_never_materializes_dense_buffer():
    """At n ~ 2k one [n, n] float32 is ~17 MB; the whole sparse iteration
    (Map + coded Shuffle + Reduce) must stay well under that."""
    K, r = 4, 2
    n = divisible_n(2048, K, r)
    g = gm.erdos_renyi(n, 0.01, seed=7)
    alloc = er_allocation(n, K, r)
    plan = compile_plan(g.adj, alloc)
    plan.edge_tables(g.csr, alloc)                  # bind CSR (compile side)
    prog = algo.pagerank()
    prog.map_edge_values(g, prog.init(g))           # warm degree/CSR caches
    tracemalloc.start()
    res = engine.run(prog, g, alloc, 2, mode="coded", plan=plan,
                     path="sparse")
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < n * n * 4, f"peak {peak / 1e6:.1f}MB reached dense size"
    np.testing.assert_array_equal(res.state, algo.reference_run(prog, g, 2))


def test_sparse_path_faster_than_dense_reduce():
    """Timing sanity (loose: the dense path does O(K n^2) work per iteration
    vs O(edges); at n ~ 1000 that is a >100x gap, so 2x is never flaky)."""
    K, r, iters = 4, 2, 3
    n = divisible_n(1024, K, r)
    g = gm.erdos_renyi(n, 0.05, seed=3)
    alloc = er_allocation(n, K, r)
    plan = compile_plan(g.adj, alloc)
    prog = algo.pagerank()
    for path in ("sparse", "dense"):                # warm both paths
        engine.run(prog, g, alloc, 1, mode="coded", plan=plan, path=path)
    t0 = time.perf_counter()
    engine.run(prog, g, alloc, iters, mode="coded", plan=plan, path="sparse")
    t_sparse = time.perf_counter() - t0
    t0 = time.perf_counter()
    engine.run(prog, g, alloc, iters, mode="coded", plan=plan, path="dense")
    t_dense = time.perf_counter() - t0
    assert t_dense > 2 * t_sparse, (t_sparse, t_dense)


@pytest.mark.parametrize("prog", [algo.pagerank(), algo.degree_count()],
                         ids=lambda p: p.name)
@pytest.mark.parametrize("mode", ["single", "coded-fast"])
def test_spmv_backend_matches_numpy_reduce(prog, mode):
    """Blocked Pallas spmv Reduce: tolerance-exact (MXU accumulation order
    differs from reduceat) and same bits on the wire."""
    K, r = 4, 2
    n = divisible_n(100, K, r)
    g = gm.erdos_renyi(n, 0.2, seed=3)
    alloc = er_allocation(n, K, r)
    a = engine.run(prog, g, alloc, 2, mode=mode)
    b = engine.run(prog, g, alloc, 2, mode=mode, backend="spmv")
    np.testing.assert_allclose(a.state, b.state, rtol=1e-5, atol=1e-8)
    assert a.shuffle_bits == b.shuffle_bits


def test_spmv_backend_rejects_nonlinear_programs():
    g, alloc = _CASES["er"]
    with pytest.raises(ValueError, match="not linear"):
        engine.run(algo.sssp(0), g, alloc, 1, backend="spmv")
    with pytest.raises(ValueError, match="sparse"):
        engine.run(algo.pagerank(), g, alloc, 1, path="dense",
                   backend="spmv")


def test_dense_only_program_falls_back_and_sparse_is_refused():
    g, alloc = _CASES["er"]
    dense_only = dataclasses.replace(algo.pagerank(), map_edge_values=None,
                                     reduce_edges=None)
    res = engine.run(dense_only, g, alloc, 2, mode="coded")   # auto -> dense
    np.testing.assert_array_equal(
        res.state, algo.reference_run(dense_only, g, 2, path="dense"))
    with pytest.raises(ValueError, match="edge-value"):
        engine.run(dense_only, g, alloc, 1, path="sparse")
    with pytest.raises(ValueError, match="coded-ref"):
        engine.run(algo.pagerank(), g, alloc, 1, mode="coded-ref",
                   path="sparse")


def test_faults_sparse_path_matches_dense_fallback():
    """run_with_failure must deliver the same bits and (order-independent
    program) bitwise state on both its sparse path and its dict fallback."""
    from repro.core import faults

    g, alloc = _CASES["er"]
    prog = algo.degree_count()
    dense_only = dataclasses.replace(prog, map_edge_values=None,
                                     reduce_edges=None)
    a, sa = faults.run_with_failure(prog, g, alloc, 3, failed=(1,),
                                    fail_at_iter=1)
    b, sb = faults.run_with_failure(dense_only, g, alloc, 3, failed=(1,),
                                    fail_at_iter=1)
    np.testing.assert_array_equal(a.state, b.state)
    assert a.shuffle_bits == b.shuffle_bits
    assert sa.recovery_bits == sb.recovery_bits


def test_plan_delivered_dict_is_cached():
    g, alloc = _CASES["er"]
    plan = compile_plan(g.adj, alloc)
    vals = np.where(g.adj, 1.5, 0.0).astype(np.float32)
    res = plan.execute_coded(vals)
    assert res.delivered is res.delivered           # built once, reused


def test_graph_csr_and_caches():
    g, _ = _CASES["er"]
    assert g.csr is g.csr
    assert g.degrees() is g.degrees()
    assert g.weights() is g.weights()
    csr = g.csr
    np.testing.assert_array_equal(np.diff(csr.indptr),
                                  g.adj.sum(axis=1))
    np.testing.assert_array_equal(g.adj[csr.rows, csr.indices],
                                  np.ones(csr.nnz, bool))
    assert csr.nnz == 2 * g.num_edges


def test_edge_weights_bitwise_consistent_with_dense():
    g, _ = _CASES["er"]
    w = g.weights()
    ew = g.edge_weights()
    # Dense scatter of the edge weights, symmetric, +inf off-edges.
    np.testing.assert_array_equal(w[g.csr.rows, g.csr.indices], ew)
    np.testing.assert_array_equal(w, w.T)
    assert np.isinf(w[~g.adj]).all()
    assert ((ew > 0.5) & (ew < 1.5)).all()


def test_sparse_map_values_bitwise_match_dense_entries():
    """map_edge_values must equal the dense map on every edge, bitwise."""
    for prog in PROGRAMS:
        for model in ("er", "sbm"):
            g, _ = _CASES[model]
            state = prog.init(g)
            dense = np.asarray(prog.map_values(g, state), np.float32)
            sparse = prog.map_edge_values(g, state).astype(np.float32)
            np.testing.assert_array_equal(
                dense[g.csr.rows, g.csr.indices].view(np.uint32),
                sparse.view(np.uint32), err_msg=f"{prog.name}/{model}")
