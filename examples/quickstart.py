"""Quickstart: the paper's coded scheme on a small ER graph, end to end.

Runs one distributed PageRank with the uncoded baseline and the coded scheme,
verifies both match the single-machine oracle bit-exactly, and prints the
communication loads against the paper's theory curves (Theorem 1). Uses the
compile-once session API: `engine.compile(...)` returns a `CompiledEngine`
whose plan is built once per (graph, allocation) and shared across modes.
Ends with a batched multi-query run - B SSSP queries on ONE Shuffle.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import algorithms as algo
from repro.core import engine
from repro.core import graph_models as gm
from repro.core import loads
from repro.core.allocation import divisible_n, er_allocation
from repro.core.shuffle_plan import compile_plan_csr

K, p = 5, 0.1
n = divisible_n(300, K, 2)
print(f"ER(n={n}, p={p}) on K={K} servers\n")

g = gm.erdos_renyi(n, p, seed=0)
prog = algo.pagerank()
oracle = algo.reference_run(prog, g, iters=3)

print(f"{'r':>2} {'L_uncoded':>10} {'L_coded':>10} {'gain':>6} "
      f"{'theory_uc':>10} {'theory_c':>9}")
for r in range(1, K + 1):
    alloc = er_allocation(n, K, r)
    # One plan per (graph, allocation); both mode sessions share it.
    plan = compile_plan_csr(g.csr, alloc)
    sess_uc = engine.compile(prog, g, alloc, "uncoded", plan=plan)
    sess_c = engine.compile(prog, g, alloc, "coded", plan=plan)
    res_uc, res_c = sess_uc.run(3), sess_c.run(3)
    # Bit-exact distributed execution: both must equal the oracle.
    np.testing.assert_array_equal(res_uc.state, oracle)
    np.testing.assert_array_equal(res_c.state, oracle)
    lu, lc = res_uc.normalized_load, res_c.normalized_load
    gain = lu / lc if lc else float("inf")
    print(f"{r:2d} {lu:10.4f} {lc:10.4f} {gain:6.2f} "
          f"{loads.uncoded_load_er(p, r, K):10.4f} "
          f"{loads.coded_load_er_asymptotic(p, r, K):9.4f}")

print("\nAll runs matched the single-machine oracle bit-exactly.")
print("Coded shuffle achieves ~1/r of the uncoded load (Theorem 1).")

# ---- batched multi-query serving (one Shuffle, B payload columns) ----
roots = [0, 17, 42, 99]
alloc = er_allocation(n, K, 2)
sess = engine.compile(algo.multi_sssp(roots), g, alloc, "coded")
batched = sess.run(8)
single_bits = engine.compile(algo.sssp(roots[0]), g, alloc, "coded",
                             plan=sess.plan).run(8).shuffle_bits
print(f"\nbatched SSSP from {len(roots)} roots: state {batched.state.shape}, "
      f"bits = {batched.shuffle_bits} = {len(roots)} x {single_bits} "
      f"(schedule paid once, payload widened)")
