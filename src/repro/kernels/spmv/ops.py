"""Jitted public wrappers around the SpMV kernel (auto-padding + PageRank)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import spmv as spmv_ref
from .spmv import spmv_pallas


def _pad_to(x: jnp.ndarray, mult: int, axis: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def spmv(adj: jnp.ndarray, x: jnp.ndarray, *, bm: int = 128, bk: int = 128,
         use_kernel: bool = True, interpret: bool = True) -> jnp.ndarray:
    """y = adj @ x, padding ragged shapes up to the tile grid."""
    if not use_kernel:
        return spmv_ref(adj, x)
    m, n = adj.shape
    a = _pad_to(_pad_to(adj.astype(jnp.float32), bm, 0), bk, 1)
    xp = _pad_to(x.astype(jnp.float32), bk, 0)
    return spmv_pallas(a, xp, bm=bm, bk=bk, interpret=interpret)[:m]


def pagerank_step(adj: jnp.ndarray, rank: jnp.ndarray, damping: float = 0.15,
                  **kw) -> jnp.ndarray:
    deg = jnp.maximum(adj.sum(axis=0), 1.0)
    acc = spmv(adj, rank / deg, **kw)
    return (1.0 - damping) * acc + damping / adj.shape[0]
