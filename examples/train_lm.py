"""End-to-end driver: train a reduced-config LM for a few hundred steps on
CPU, checkpoint mid-run, kill, restore, and show bit-identical continuation.

    PYTHONPATH=src python examples/train_lm.py [--arch gemma-7b] [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro import configs
from repro.configs.base import ShapeSpec
from repro.launch.train import train
from repro.train.optimizer import AdamWConfig

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="gemma-7b")
ap.add_argument("--steps", type=int, default=200)
args = ap.parse_args()

cfg = configs.get(args.arch).reduced()
shape = ShapeSpec("example", seq_len=64, global_batch=8, kind="train")
opt = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps)
ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")

print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params) "
      f"for {args.steps} steps\n")
half = args.steps // 2
r1 = train(cfg, shape, half, opt=opt, ckpt_dir=ckpt, ckpt_every=25, chunk=64)
print(f"\n-- simulated preemption at step {half}; restarting from ckpt --\n")
r2 = train(cfg, shape, args.steps, opt=opt, ckpt_dir=ckpt, ckpt_every=50,
           chunk=64)

first = r1.losses[0][1]
last = r2.losses[-1][1]
print(f"\nloss: {first:.3f} -> {last:.3f} "
      f"({'OK: learning' if last < first - 0.5 else 'WARN: check hyperparams'})")
print(f"restart resumed from step {r2.restored_from} (fault-tolerant).")
shutil.rmtree(ckpt, ignore_errors=True)
