"""Coded Shuffle for the ER allocation (paper §IV-A 'Coded Shuffle', Fig. 6).

For every (r+1)-subset S of servers:
  * Z^k (k in S) is the set of intermediate values Reducer k needs that are
    Mapped exactly by the batch B_{S\\{k}} (hence available at every other
    member of S and at no one else relevant).
  * Each value is split into r bit-segments, one per server in S\\{k}.
  * Each sender s in S builds the alignment table: r rows, one per k in
    S\\{s}; row k holds (left-aligned) the segments of Z^k assigned to s.
  * s multicasts the XOR of each non-empty column.
Every receiver k in S\\{s} strips the other rows' segments (it Mapped those
batches, so it can recompute them locally) and recovers its own segment.

This module is the *literal*, bit-exact reference; the batched TPU execution
path lives in engine.py / kernels/xor_code.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from .allocation import Allocation
from .bitcodec import T_BITS, floats_to_bits, segment_bounds
from .uncoded_shuffle import ShuffleResult


def group_need(adj: np.ndarray, alloc: Allocation, S: tuple[int, ...],
               k: int) -> np.ndarray:
    """Z^k_{S\\{k}} as ordered [(i, j)] pairs: i in R_k, j in B_{S\\{k}},
    (i, j) in E. Deterministic (i, j)-sorted order shared by all servers."""
    others = tuple(sorted(set(S) - {k}))
    if others not in alloc.subsets:
        return np.empty((0, 2), dtype=int)
    batch = alloc.batch_of == alloc.subsets.index(others)
    rk = alloc.reduce_owner == k
    need = adj & rk[:, None] & batch[None, :]
    return np.argwhere(need)          # argwhere is already (i, j) sorted


@dataclasses.dataclass
class CodedMessages:
    """All multicasts of one group S: sender -> list of coded columns."""

    S: tuple[int, ...]
    columns: dict[int, list[np.ndarray]]  # sender -> [column_bits ...]
    bits: int


def _segment_of(value_bits: np.ndarray, r: int, seg_idx: int) -> np.ndarray:
    a, b = segment_bounds(r)[seg_idx]
    return value_bits[a:b]


def encode_group(adj: np.ndarray, values: np.ndarray, alloc: Allocation,
                 S: tuple[int, ...]) -> CodedMessages:
    r = alloc.r
    S = tuple(sorted(S))
    # Pre-compute Z^k and the bit matrices of their values.
    Z = {k: group_need(adj, alloc, S, k) for k in S}
    Zbits = {k: floats_to_bits(values[Z[k][:, 0], Z[k][:, 1]])
             if len(Z[k]) else np.zeros((0, T_BITS), np.uint8) for k in S}
    columns: dict[int, list[np.ndarray]] = {}
    total_bits = 0
    for s in S:
        rows = []
        for k in S:
            if k == s:
                continue
            others = tuple(sorted(set(S) - {k}))
            seg_idx = others.index(s)       # segment of v assigned to sender s
            a, b = segment_bounds(r)[seg_idx]
            rows.append(Zbits[k][:, a:b])   # [|Z^k|, seg_len]
        ncols = max((row.shape[0] for row in rows), default=0)
        cols = []
        for c in range(ncols):
            entries = [row[c] for row in rows if c < row.shape[0]]
            width = max(e.shape[0] for e in entries)
            acc = np.zeros(width, dtype=np.uint8)
            for e in entries:
                acc[:e.shape[0]] ^= e
            cols.append(acc)
            total_bits += width
        columns[s] = cols
    return CodedMessages(S, columns, total_bits)


def decode_group(adj: np.ndarray, values: np.ndarray, alloc: Allocation,
                 msgs: CodedMessages,
                 delivered_bits: dict[int, dict[tuple[int, int], dict[int, np.ndarray]]]):
    """Each receiver k strips locally-known segments from each coded column.

    `values` is used only to reconstruct the segments the receiver *already
    Mapped itself* (legitimate local knowledge); the receiver's own missing
    segments come exclusively from the coded columns.
    """
    r = alloc.r
    S = msgs.S
    Z = {k: group_need(adj, alloc, S, k) for k in S}
    Zbits = {k: floats_to_bits(values[Z[k][:, 0], Z[k][:, 1]])
             if len(Z[k]) else np.zeros((0, T_BITS), np.uint8) for k in S}
    for s in S:
        cols = msgs.columns[s]
        receivers = [k for k in S if k != s]
        for k in receivers:
            others_k = tuple(sorted(set(S) - {k}))
            seg_idx_k = others_k.index(s)
            a_k, b_k = segment_bounds(r)[seg_idx_k]
            for c, col in enumerate(cols):
                if c >= len(Z[k]):
                    continue
                # Strip every other receiver's segment (locally recomputable:
                # k Mapped batch B_{S\{k'}} because k is in S\{k'}).
                seg = col.copy()
                for k2 in receivers:
                    if k2 == k or c >= len(Z[k2]):
                        continue
                    others2 = tuple(sorted(set(S) - {k2}))
                    i2 = others2.index(s)
                    a2, b2 = segment_bounds(r)[i2]
                    other_seg = Zbits[k2][c, a2:b2]
                    seg[:other_seg.shape[0]] ^= other_seg
                i, j = map(int, Z[k][c])
                delivered_bits[k].setdefault((i, j), {})[seg_idx_k] = seg[:b_k - a_k]


def run_coded(adj: np.ndarray, values: np.ndarray,
              alloc: Allocation) -> ShuffleResult:
    """Execute the full coded Shuffle; returns recovered values + exact load."""
    from .bitcodec import bits_to_floats

    K, r = alloc.K, alloc.r
    delivered_bits: dict[int, dict[tuple[int, int], dict[int, np.ndarray]]] = {
        k: {} for k in range(K)}
    total_bits = 0
    for S in itertools.combinations(range(K), r + 1):
        msgs = encode_group(adj, values, alloc, S)
        total_bits += msgs.bits
        decode_group(adj, values, alloc, msgs, delivered_bits)
    delivered: dict[int, dict[tuple[int, int], float]] = {k: {} for k in range(K)}
    for k, per_pair in delivered_bits.items():
        for (i, j), segs in per_pair.items():
            assert len(segs) == r, f"missing segments for ({i},{j}) at server {k}"
            bits = np.concatenate([segs[s] for s in range(r)])
            delivered[k][(i, j)] = float(bits_to_floats(bits[None, :])[0])
    return ShuffleResult(delivered, total_bits, alloc.n)


def coded_load(adj: np.ndarray, alloc: Allocation) -> float:
    """Exact normalized coded load of a realization (schedule only, no data).

    Reads the size off the compiled ShufflePlan - bits-on-the-wire depend
    only on the schedule, so this is a compile-time constant. Bit-identical
    to the subset-enumeration accounting (`coded_load_reference`).
    """
    from .shuffle_plan import compile_plan

    return compile_plan(adj, alloc, validate=False).coded_load()


def coded_load_reference(adj: np.ndarray, alloc: Allocation) -> float:
    """Legacy subset-enumeration load accounting (reference for the plan).

    Per group S and sender s, the number of coded columns is
    max_{k in S\\{s}} |Z^k|, each of ~T/r bits (exact per-segment widths).
    """
    K, r = alloc.K, alloc.r
    bounds = segment_bounds(r)
    total_bits = 0
    for S in itertools.combinations(range(K), r + 1):
        sizes = {k: len(group_need(adj, alloc, S, k)) for k in S}
        for s in S:
            rows = []
            for k in S:
                if k == s:
                    continue
                others = tuple(sorted(set(S) - {k}))
                a, b = bounds[others.index(s)]
                rows.append((sizes[k], b - a))
            ncols = max((sz for sz, _ in rows), default=0)
            for c in range(ncols):
                total_bits += max((w for sz, w in rows if c < sz), default=0)
    return total_bits / (alloc.n * alloc.n * T_BITS)
