"""jit-able train_step: loss + grad + AdamW update, with optional gradient
accumulation over microbatches (lax.scan so HLO stays O(1) in accum steps)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import transformer as tfm
from .optimizer import AdamWConfig, apply_updates


def train_step(params, opt_state, batch, *, cfg: ModelConfig,
               opt: AdamWConfig, accum: int = 1, chunk: int = 1024):
    """batch leaves have leading [global_batch, ...]; accum splits it."""

    def loss_of(p, b):
        return tfm.loss_fn(p, cfg, b, remat=True, chunk=chunk)

    if accum == 1:
        loss, grads = jax.value_and_grad(loss_of)(params, batch)
    else:
        def resh(x):
            return x.reshape((accum, x.shape[0] // accum) + x.shape[1:])
        micro = jax.tree.map(resh, batch)

        def body(acc, mb):
            l, g = jax.value_and_grad(loss_of)(params, mb)
            return (acc[0] + l, jax.tree.map(jnp.add, acc[1], g)), None

        zero = (jnp.zeros((), jnp.float32),
                jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params))
        (loss, grads), _ = jax.lax.scan(body, zero, micro)
        loss = loss / accum
        grads = jax.tree.map(lambda g: g / accum, grads)

    new_params, new_state = apply_updates(opt, params, grads, opt_state)
    return new_params, new_state, loss


def make_train_step(cfg: ModelConfig, opt: AdamWConfig, accum: int = 1,
                    chunk: int = 1024, donate: bool = True):
    f = functools.partial(train_step, cfg=cfg, opt=opt, accum=accum, chunk=chunk)
    return jax.jit(f, donate_argnums=(0, 1) if donate else ())
