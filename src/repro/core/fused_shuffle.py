"""Multi-device coded Shuffle under shard_map (devices = servers).

The literal scheme multicasts per (r+1)-group columns one at a time - fine on
an Ethernet bus, wrong on an ICI torus. Here every server packs ALL its coded
columns (across all groups it serves) into one dense uint32 buffer and a
single jax.lax.all_gather moves every buffer to every server in one fused
collective; receivers slice their groups and XOR-strip locally (kernels/
xor_code). Bit volume on the wire equals the literal schedule's (padding
aside); latency collapses from O(#groups * #columns) transmissions to one
collective phase - this is the hardware adaptation of the paper's shared-bus
assumption.

Two executors share that design:

  * **Sparse (production path)** - `partition_plan` splits a compiled CSR
    `ShufflePlan` per server: each device holds only its own slice of the
    Map output (`loc_e`, the [nnz]-indexed values it Mapped, O(r nnz / K))
    plus its encode/decode/strip tables (O(plan / K)). One iteration under
    `shard_map` on a ('servers',) mesh is (a) per-shard gather-shift-mask +
    XOR encode through the batched `kernels/xor_code` route, (b) one packed
    dense all_gather of uint32 coded words, (c) per-shard strip + shift-back
    into each receiver's delivery slice. No [n, n] or O(n^2)-shaped array
    exists anywhere on this path; `FusedSparseShuffle` jits the exchange
    once and replays it every iteration, bit-exact against
    `ShufflePlan.execute_coded_sparse` (unicast leftovers ride the same
    all_gather as single-slot full-width columns).

  * **Dense (small-n validation reference)** - `fused_exchange` consumes a
    replicated [n, n] value matrix through [n, n]-indexed schedule tensors;
    kept only to cross-check the collective layout at validation scale.

The column/slot structure comes straight off the compiled `ShufflePlan`
(compile-once) via `compile_plan_csr` - `build_schedule` accepts a `Graph`
and never touches `.adj`, so schedule construction works on CSR-native
graphs beyond `dense_limit`.

Word format: one uint32 per coded column and slot, in *codec bit order*
(`bitcodec.floats_to_words`), so segment s of a value travels left-aligned
as ``(word << shift_s) & mask_s`` - identical bit semantics to the NumPy
plan executor, which is what makes the device path bitwise comparable.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.xor_code import ops as xor_ops
from ..launch.mesh import make_servers_mesh, shard_map_compat
from ..obs import get_tracer
from .allocation import Allocation
from .bitcodec import floats_to_words, words_to_floats
from .graph_models import CSR, Graph
from .shuffle_plan import (PlanShuffleResult, ShufflePlan, _run_ranks,
                           compile_plan_csr)

FULL_MASK = np.uint32(0xFFFFFFFF)


def _sender_layout(plan: ShufflePlan) -> tuple[np.ndarray, np.ndarray]:
    """Per-sender packing of the plan's coded columns.

    Deterministic order within each sender: (group, in-group column rank).
    Returns (colpos [C] - position of column c in its sender's buffer,
    ncols [K] - coded-column count per sender).
    """
    order = np.lexsort((plan.col_rank, plan.col_gm, plan.col_sender))
    _, rank = _run_ranks(plan.col_sender[order])
    colpos = np.empty(plan.col_sender.size, dtype=np.int64)
    colpos[order] = rank
    ncols = np.bincount(plan.col_sender, minlength=plan.K)
    return colpos, ncols


# ---------------------------------------------------------------------------
# Sparse multi-device path (production)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class FusedSparseSchedule:
    """Per-server partition of a compiled CSR plan (all arrays plan-sized).

    Device k's shard (row k of every array) is everything it needs for one
    coded Shuffle: `loc_e` selects the [nnz] edge values it Mapped (column
    vertex in M_k - O(r nnz / K) entries), the `enc_*` tables lay its coded
    columns (+ its unicast leftovers, as single-slot full-width columns)
    into a [W]-word buffer, and the `dec_*`/`strip_*` tables recover its
    delivery slice from the all_gathered [K, W] buffer matrix.

    Sentinels: local index `Lmax` is a guaranteed-zero word; buffer column
    `W` is a guaranteed-zero column (padded after the all_gather); masks of
    sentinel slots are 0, so they OR/XOR away - encode and decode are plain
    gather-shift-mask pipelines with no control flow.
    """

    K: int
    r: int
    W: int                        # per-sender buffer width (words)
    Lmax: int                     # max local-value count over servers
    Dmax: int                     # max delivery count over receivers
    loc_e: np.ndarray             # [K, Lmax] int64 CSR entry (nnz = zero pad)
    enc_l: np.ndarray             # [K, W, r] int32 local index (Lmax = zero)
    enc_shift: np.ndarray         # [K, W, r] uint32 segment left-shift
    enc_mask: np.ndarray          # [K, W, r] uint32 segment keep-mask
    dec_s: np.ndarray             # [K, Dmax, r] int32 sender of segment t
    dec_w: np.ndarray             # [K, Dmax, r] int32 buffer column (W = zero)
    dec_mask: np.ndarray          # [K, Dmax, r] uint32 own-slot keep-mask
    dec_shift: np.ndarray         # [K, Dmax, r] uint32 shift back into place
    strip_l: np.ndarray           # [K, Dmax, r, r-1] int32 local index
    strip_shift: np.ndarray       # [K, Dmax, r, r-1] uint32
    strip_mask: np.ndarray        # [K, Dmax, r, r-1] uint32


def partition_plan(plan: ShufflePlan, csr: CSR,
                   alloc: Allocation) -> FusedSparseSchedule:
    """Partition a compiled CSR plan per server for the fused sparse path.

    Pure compile-time layout (no data): every output array is [nnz]- or
    [plan]-sized. Unicast leftovers are assigned to the smallest server
    that Mapped their column vertex and appended to that sender's buffer as
    single-slot full-width columns, so they ride the same all_gather.
    """
    plan._require_schedule()
    tables = plan.edge_tables(csr, alloc)     # locates edges + validates
    K, r = plan.K, plan.r
    C = plan.col_sender.size
    Pn = plan.pair_k.size
    L = plan.left_k.size
    nstrip = max(r - 1, 0)

    colpos, ncols = _sender_layout(plan)

    # Leftover layout: sender = smallest mapper of the column vertex,
    # appended after that sender's coded columns (stable (k, i, j) order).
    if L:
        lsender = np.argmax(alloc.map_sets[:, plan.left_j], axis=0)
        if not alloc.map_sets[lsender, plan.left_j].all():
            raise RuntimeError("leftover value has no Mapping server")
        lorder = np.argsort(lsender, kind="stable")
        _, lrank = _run_ranks(lsender[lorder])
        leftw = np.empty(L, dtype=np.int64)
        leftw[lorder] = ncols[lsender[lorder]] + lrank
        nleft = np.bincount(lsender, minlength=K)
    else:
        lsender = np.zeros(0, dtype=np.int64)
        leftw = np.zeros(0, dtype=np.int64)
        nleft = np.zeros(K, dtype=np.int64)
    W = max(int((ncols + nleft).max()), 1)

    # Per-server local Map slices: CSR entries whose column vertex the
    # server Mapped (it can recompute exactly these values locally).
    member = alloc.map_sets[:, csr.indices]             # [K, nnz] bool
    counts = member.sum(axis=1)
    Lmax = max(int(counts.max()), 1)
    loc_e = np.full((K, Lmax), csr.nnz, dtype=np.int64)  # nnz = zero pad

    # --- encode tables: valid plan slots + leftover slots, per sender ---
    enc_l = np.full((K, W, r), Lmax, dtype=np.int32)     # Lmax = zero word
    enc_shift = np.zeros((K, W, r), dtype=np.uint32)
    enc_mask = np.zeros((K, W, r), dtype=np.uint32)
    cs, sl = np.nonzero(plan.slot_pair < Pn) if C else (
        np.zeros(0, np.int64), np.zeros(0, np.int64))
    e_of_slot = tables.pair_e[plan.slot_pair[cs, sl]] if cs.size else cs
    s_of_slot = plan.col_sender[cs] if cs.size else cs

    # --- decode tables, first in flat (k, i, j) delivery order ---
    M = plan.all_k.size
    f_s = np.zeros((M, r), dtype=np.int32)
    f_w = np.full((M, r), W, dtype=np.int32)             # W = zero column
    f_mask = np.zeros((M, r), dtype=np.uint32)
    f_shift = np.zeros((M, r), dtype=np.uint32)
    f_sl = np.full((M, r, nstrip), Lmax, dtype=np.int32)
    f_ssh = np.zeros((M, r, nstrip), dtype=np.uint32)
    f_smk = np.zeros((M, r, nstrip), dtype=np.uint32)
    if Pn:
        mpos = plan.pos_covered
        c, slot = plan.pair_col, plan.pair_slot          # [P, r]
        f_s[mpos] = plan.col_sender[c]
        f_w[mpos] = colpos[c]
        f_mask[mpos] = plan.slot_mask[c, slot]
        f_shift[mpos] = np.broadcast_to(plan.seg_shift[None, :], (Pn, r))
        if nstrip:
            ar = np.broadcast_to(np.arange(r)[None, None, :], (Pn, r, r))
            others = ar[~(ar == slot[..., None])].reshape(Pn, r, nstrip)
            c3 = np.broadcast_to(c[:, :, None], (Pn, r, nstrip))
            sp = plan.slot_pair[c3, others]              # [P, r, r-1]
            svalid = sp < Pn
            f_ssh[mpos] = plan.slot_shift[c3, others]
            f_smk[mpos] = plan.slot_mask[c3, others]
            e_strip = tables.pair_e[np.minimum(sp, max(Pn - 1, 0))]
    if L:
        f_s[plan.pos_left, 0] = lsender
        f_w[plan.pos_left, 0] = leftw
        f_mask[plan.pos_left, 0] = FULL_MASK             # full word, shift 0

    # --- per-server local index conversions (one vectorized pass each) ---
    for k in range(K):
        lset = np.flatnonzero(member[k])
        loc_e[k, :lset.size] = lset
        lpos = np.cumsum(member[k]) - 1                  # entry -> local idx
        if cs.size:
            m = s_of_slot == k                           # encode slots k sends
            if not member[k][e_of_slot[m]].all():
                raise RuntimeError(f"sender {k} schedules a value it "
                                   "did not Map")
            enc_l[k, colpos[cs[m]], sl[m]] = lpos[e_of_slot[m]]
            enc_shift[k, colpos[cs[m]], sl[m]] = plan.slot_shift[cs[m], sl[m]]
            enc_mask[k, colpos[cs[m]], sl[m]] = plan.slot_mask[cs[m], sl[m]]
        if L:
            m = lsender == k                             # leftovers k unicasts
            if not member[k][tables.left_e[m]].all():
                raise RuntimeError(f"sender {k} unicasts a value it "
                                   "did not Map")
            enc_l[k, leftw[m], 0] = lpos[tables.left_e[m]]
            enc_mask[k, leftw[m], 0] = FULL_MASK         # full word, shift 0
        if Pn and nstrip:
            m = plan.pair_k == k                         # strips k recomputes
            li = np.where(svalid[m], lpos[e_strip[m]], Lmax)
            if not (member[k][e_strip[m]] | ~svalid[m]).all():
                raise RuntimeError(f"receiver {k} must strip a value it "
                                   "did not Map")
            f_sl[plan.pos_covered[m]] = li.astype(np.int32)

    # --- scatter the flat decode tables into per-receiver padded rows ---
    dcount = np.diff(plan.ptr)
    Dmax = max(int(dcount.max()) if K else 0, 1)
    kk = plan.all_k
    dd = np.arange(M, dtype=np.int64) - plan.ptr[kk]
    dec_s = np.zeros((K, Dmax, r), dtype=np.int32)
    dec_w = np.full((K, Dmax, r), W, dtype=np.int32)
    dec_mask = np.zeros((K, Dmax, r), dtype=np.uint32)
    dec_shift = np.zeros((K, Dmax, r), dtype=np.uint32)
    strip_l = np.full((K, Dmax, r, nstrip), Lmax, dtype=np.int32)
    strip_shift = np.zeros((K, Dmax, r, nstrip), dtype=np.uint32)
    strip_mask = np.zeros((K, Dmax, r, nstrip), dtype=np.uint32)
    dec_s[kk, dd] = f_s
    dec_w[kk, dd] = f_w
    dec_mask[kk, dd] = f_mask
    dec_shift[kk, dd] = f_shift
    strip_l[kk, dd] = f_sl
    strip_shift[kk, dd] = f_ssh
    strip_mask[kk, dd] = f_smk

    return FusedSparseSchedule(
        K=K, r=r, W=W, Lmax=Lmax, Dmax=Dmax, loc_e=loc_e,
        enc_l=enc_l, enc_shift=enc_shift, enc_mask=enc_mask,
        dec_s=dec_s, dec_w=dec_w, dec_mask=dec_mask, dec_shift=dec_shift,
        strip_l=strip_l, strip_shift=strip_shift, strip_mask=strip_mask)


ENCODE_BACKENDS = ("xor-ref", "xor-kernel", "jnp")


class FusedSparseShuffle:
    """Jit-once / replay-every-iteration multi-device coded Shuffle.

    Wraps a compiled plan's per-server partition and the jitted shard_map
    exchange. `execute` is a drop-in peer of
    `ShufflePlan.execute_coded_sparse`: same [nnz] edge-value input, same
    `PlanShuffleResult` (bitwise-equal uint32 words, same bit accounting).

    encode:
      "xor-ref"    - batched kernels/xor_code route, jnp oracle (default).
      "xor-kernel" - same route through the Pallas kernel (interpret=True
                     off-TPU; pass interpret=False on real hardware).
      "jnp"        - plain jnp XOR reduce (no kernel route).
    """

    def __init__(self, plan: ShufflePlan, csr: CSR, alloc: Allocation,
                 mesh: Mesh | None = None, *, encode: str = "xor-ref",
                 interpret: bool = True):
        if encode not in ENCODE_BACKENDS:
            raise ValueError(f"unknown encode backend {encode!r}")
        self.plan = plan
        self.sched = partition_plan(plan, csr, alloc)
        self.mesh = make_servers_mesh(plan.K) if mesh is None else mesh
        if self.mesh.devices.size != plan.K:
            raise ValueError(
                f"mesh has {self.mesh.devices.size} devices but the plan "
                f"has K={plan.K} servers (one device per server)")
        self._encode = encode
        self._interpret = interpret
        self._fn = self._build(encode, interpret, batched=False)
        self._fn_batched = None       # built lazily on the first [nnz, B] call
        s = self.sched
        self._dev_tables = tuple(jnp.asarray(a) for a in (
            s.enc_l, s.enc_shift, s.enc_mask, s.dec_s, s.dec_w, s.dec_mask,
            s.dec_shift, s.strip_l, s.strip_shift, s.strip_mask))

    def rebind(self, plan: ShufflePlan, csr: CSR,
               alloc: Allocation) -> "FusedSparseShuffle":
        """New exchange bound to a mutated (plan, csr) on this instance's
        jitted callables.

        `CompiledEngine.update`'s hook: the per-server partition and device
        tables are rebuilt for the new plan (they index CSR entries, so any
        real delta moves them), but the traced shard_map exchange, mesh,
        and backend flags carry over - the tables are jit *arguments*, so
        XLA re-lowers only if the partition's padded shapes (W, Lmax, Dmax)
        actually changed, and replays the cached executable otherwise.
        """
        ex = object.__new__(FusedSparseShuffle)
        ex.plan = plan
        ex.sched = partition_plan(plan, csr, alloc)
        ex.mesh = self.mesh
        ex._encode = self._encode
        ex._interpret = self._interpret
        ex._fn = self._fn
        ex._fn_batched = self._fn_batched
        s = ex.sched
        ex._dev_tables = tuple(jnp.asarray(a) for a in (
            s.enc_l, s.enc_shift, s.enc_mask, s.dec_s, s.dec_w, s.dec_mask,
            s.dec_shift, s.strip_l, s.strip_shift, s.strip_mask))
        return ex

    def _build(self, encode: str, interpret: bool, batched: bool):
        use_kernel = encode == "xor-kernel"
        # Batched payloads append one trailing B axis to every *word* array
        # (loc, buffers, deliveries); the schedule tables are value-agnostic
        # and broadcast behind it. All device ops stay uint32 shift/mask/XOR,
        # so payload column b is bitwise the unbatched exchange of column b.
        bx = (lambda a: a[..., None]) if batched else (lambda a: a)

        def per_server(loc, enc_l, enc_shift, enc_mask, dec_s, dec_w,
                       dec_mask, dec_shift, strip_l, strip_shift, strip_mask):
            loc = loc[0]                          # [Lmax+1] (or [Lmax+1, B])
            if encode == "jnp":
                slotw = (loc[enc_l[0]] << bx(enc_shift[0])) & bx(enc_mask[0])
                coded = jax.lax.reduce(slotw, jnp.uint32(0),
                                       jax.lax.bitwise_xor, (1,))
            else:
                coded = xor_ops.xor_encode_slots(
                    loc, enc_l[0], enc_shift[0], enc_mask[0],
                    use_kernel=use_kernel, interpret=interpret)
            allbufs = jax.lax.all_gather(coded, "servers")  # [K, W(, B)]
            pad = ((0, 0), (0, 1)) + (((0, 0),) if batched else ())
            allbufs = jnp.pad(allbufs, pad)                 # zero col W
            got = allbufs[dec_s[0], dec_w[0]]               # [Dmax, r(, B)]
            sw = (loc[strip_l[0]] << bx(strip_shift[0])) & bx(strip_mask[0])
            strip = jax.lax.reduce(sw, jnp.uint32(0),
                                   jax.lax.bitwise_xor, (2,))
            rec = ((got ^ strip) & bx(dec_mask[0])) >> bx(dec_shift[0])
            words = jax.lax.reduce(rec, jnp.uint32(0),
                                   jax.lax.bitwise_or, (1,))
            return words[None]                              # [1, Dmax(, B)]

        # pallas_call has no replication rule, so the kernel route must
        # disable the output-replication checker (outputs are per-shard
        # anyway - nothing is claimed replicated).
        f = shard_map_compat(per_server, mesh=self.mesh,
                             in_specs=(P("servers"),) * 11,
                             out_specs=P("servers"), check=not use_kernel)
        return jax.jit(f)

    def exchange_words(self, edge_words: np.ndarray) -> np.ndarray:
        """One coded Shuffle on codec-order uint32 words.

        edge_words [nnz] -> recovered delivery words [M] in the plan's
        (k, i, j) order, bitwise equal to what `execute_coded_sparse`
        would deliver. The whole device computation is uint32 shift/mask/
        XOR - no float ops - which is what makes equality exact.

        Batched edge_words [nnz, B] -> [M, B]: one exchange moves all B
        payload columns (word arrays gain a trailing B axis; the jitted
        schedule tables are shared), column-b bitwise equal to the
        unbatched exchange of that column.
        """
        s = self.sched
        tr = get_tracer()
        ew = np.ascontiguousarray(edge_words, np.uint32)
        batched = ew.ndim == 2
        B = int(ew.shape[1]) if batched else 1
        with tr.span("phase.encode", backend="fused", B=B,
                     nnz=int(edge_words.shape[0])):
            if batched:
                if self._fn_batched is None:
                    self._fn_batched = self._build(self._encode,
                                                   self._interpret,
                                                   batched=True)
                ew = np.concatenate(
                    [ew, np.zeros((1, ew.shape[1]), np.uint32)], axis=0)
                loc = np.zeros((s.K, s.Lmax + 1, ew.shape[1]),
                               dtype=np.uint32)
                fn = self._fn_batched
            else:
                ew = np.append(ew, np.uint32(0))
                loc = np.zeros((s.K, s.Lmax + 1), dtype=np.uint32)
                fn = self._fn
            loc[:, :s.Lmax] = ew[s.loc_e]
        plan = self.plan
        bits = (plan.coded_bits + plan.leftover_bits) * B
        # Host-side timing around the jitted multi-device exchange: block
        # on the device buffers before stamping so the span covers the
        # collective's execution, not just its dispatch.
        with tr.span("phase.exchange", backend="fused", bits=bits, B=B,
                     K=s.K):
            dev = fn(jnp.asarray(loc), *self._dev_tables)
            jax.block_until_ready(dev)
        with tr.span("phase.decode", backend="fused", B=B,
                     deliveries=int(plan.all_k.size)):
            out = np.asarray(dev)
            M = plan.all_k.size
            return out[plan.all_k, np.arange(M, dtype=np.int64)
                       - plan.ptr[plan.all_k]]

    def execute(self, edge_vals: np.ndarray) -> PlanShuffleResult:
        """Drop-in peer of `ShufflePlan.execute_coded_sparse` (batched
        [nnz, B] edge values supported the same way)."""
        plan = self.plan
        edge_vals = np.asarray(edge_vals, np.float32)
        words = self.exchange_words(floats_to_words(edge_vals))
        bits = ((plan.coded_bits + plan.leftover_bits)
                * (edge_vals.shape[1] if edge_vals.ndim == 2 else 1))
        return PlanShuffleResult(plan.all_k, plan.all_i, plan.all_j,
                                 words_to_floats(words), plan.ptr, bits,
                                 plan.n)


def run_fused_sparse(g: Graph, edge_vals: np.ndarray, alloc: Allocation,
                     mesh: Mesh | None = None, *, encode: str = "xor-ref",
                     interpret: bool = True) -> PlanShuffleResult:
    """Convenience one-shot: compile + partition + one sparse exchange."""
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    ex = FusedSparseShuffle(plan, g.csr, alloc, mesh, encode=encode,
                            interpret=interpret)
    return ex.execute(edge_vals)


# ---------------------------------------------------------------------------
# Dense small-n validation reference
# ---------------------------------------------------------------------------


def build_schedule(g: Graph, alloc: Allocation,
                   plan: ShufflePlan | None = None):
    """Static (graph-dependent, data-independent) dense-reference schedule.

    Compiles the ShufflePlan once - adjacency-free via `compile_plan_csr`,
    so a CSR-native graph beyond `dense_limit` never materializes [n, n] -
    and lays its columns out per sender, padded to a common buffer length
    so the all_gather is dense. Returns numpy index tensors consumed by the
    jitted dense exchange (covered pairs only; leftovers are a sparse-path
    concern - see `partition_plan`).
    """
    K, r = alloc.K, alloc.r
    if plan is None:
        plan = compile_plan_csr(g.csr, alloc, validate=False)
    # Per-sender column order comes from the one shared layout rule
    # (`_sender_layout`), so the dense reference and the sparse partition
    # can never disagree on buffer positions.
    colpos, ncols = _sender_layout(plan)
    per_s: list[list[int]] = [[0] * int(ncols[s]) for s in range(K)]
    for c in range(plan.col_sender.size):
        per_s[int(plan.col_sender[c])][int(colpos[c])] = c
    width = int(ncols.max()) if ncols.size else 0

    P_pairs = plan.pair_k.size
    # Encode tensors: for slot t of server s, the XOR of values v[i,j] over
    # receivers. We express it as up-to-r (i, j) index pairs (-1 padded).
    enc_idx = np.full((K, width, r, 2), -1, dtype=np.int32)
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            for sl in range(r):
                p = int(plan.slot_pair[c, sl])
                if p == P_pairs:          # sentinel: empty slot
                    continue
                enc_idx[s, t, sl] = (plan.pair_i[p], plan.pair_j[p])
    # Decode map: receiver k strips every other member's value from the slot.
    # For each (sender s, slot t) useful to k: target (i, j) plus the strip
    # list; represent as target idx and r-1 strip idx pairs.
    dec: dict[int, list] = {k: [] for k in range(K)}
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            occupied = [sl for sl in range(r)
                        if int(plan.slot_pair[c, sl]) != P_pairs]
            for sl in occupied:
                p = int(plan.slot_pair[c, sl])
                k = int(plan.pair_k[p])
                strips = [(int(plan.pair_i[int(plan.slot_pair[c, sl2])]),
                           int(plan.pair_j[int(plan.slot_pair[c, sl2])]))
                          for sl2 in occupied if sl2 != sl]
                tgt = (int(plan.pair_i[p]), int(plan.pair_j[p]))
                dec[k].append((s, t, tgt, strips))
    dwidth = max((len(d) for d in dec.values()), default=0)
    dec_src = np.zeros((K, dwidth, 2), dtype=np.int32)       # (sender, slot)
    dec_tgt = np.full((K, dwidth, 2), -1, dtype=np.int32)    # (i, j)
    dec_strip = np.full((K, dwidth, r - 1, 2), -1, dtype=np.int32) \
        if r > 1 else np.zeros((K, dwidth, 0, 2), np.int32)
    for k, items in dec.items():
        for t, (s, slot_t, (i, j), strips) in enumerate(items):
            dec_src[k, t] = (s, slot_t)
            dec_tgt[k, t] = (i, j)
            for ri, (i2, j2) in enumerate(strips):
                dec_strip[k, t, ri] = (i2, j2)
    return enc_idx, dec_src, dec_tgt, dec_strip


def _as_words(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _as_floats(w):
    return jax.lax.bitcast_convert_type(w, jnp.float32)


def fused_exchange(values: jnp.ndarray, enc_idx, dec_src, dec_tgt, dec_strip,
                   mesh: Mesh):
    """One coded Shuffle as a single all_gather of packed XOR buffers.

    values [n, n] float32 (replicated Map output; each server only reads its
    own columns through the schedule indices). Returns [n, n] recovered
    missing values (0 where not delivered) - identical on every server.
    Validation reference only: the production path is `FusedSparseShuffle`.
    """
    words = _as_words(values)

    def per_server(enc_s, dec_src_s, dec_tgt_s, dec_strip_s):
        # enc_s [1, W, r, 2] on this shard.
        enc_s = enc_s[0]
        valid = enc_s[:, :, 0] >= 0
        vals = words[jnp.clip(enc_s[:, :, 0], 0), jnp.clip(enc_s[:, :, 1], 0)]
        buf = jnp.where(valid, vals, jnp.uint32(0))
        coded = jax.lax.reduce(buf, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        allbufs = jax.lax.all_gather(coded, "servers")       # [K, W]
        # Decode this server's targets.
        d_src, d_tgt, d_strip = dec_src_s[0], dec_tgt_s[0], dec_strip_s[0]
        got = allbufs[d_src[:, 0], d_src[:, 1]]
        sv = d_strip[:, :, 0] >= 0
        strip_vals = words[jnp.clip(d_strip[:, :, 0], 0),
                           jnp.clip(d_strip[:, :, 1], 0)]
        strip = jax.lax.reduce(jnp.where(sv, strip_vals, jnp.uint32(0)),
                               jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        rec = got ^ strip
        out = jnp.zeros(words.shape, jnp.uint32)
        tgt_ok = d_tgt[:, 0] >= 0
        out = out.at[jnp.clip(d_tgt[:, 0], 0),
                     jnp.clip(d_tgt[:, 1], 0)].set(
            jnp.where(tgt_ok, rec, jnp.uint32(0)))
        return jax.lax.psum(out, "servers")   # union of per-server recoveries

    f = shard_map_compat(per_server, mesh=mesh,
                         in_specs=(P("servers"), P("servers"), P("servers"),
                                   P("servers")),
                         out_specs=P())
    out_words = f(jnp.asarray(enc_idx), jnp.asarray(dec_src),
                  jnp.asarray(dec_tgt), jnp.asarray(dec_strip))
    return _as_floats(out_words)


def run_fused(g: Graph, values: np.ndarray, alloc: Allocation, mesh: Mesh):
    """Convenience wrapper: schedule + dense exchange; returns [n, n]."""
    sched = build_schedule(g, alloc)
    return fused_exchange(jnp.asarray(values, jnp.float32), *sched, mesh=mesh)
