"""TPU-idiomatic fused coded Shuffle (DESIGN.md §3, 'fused' path).

The literal scheme multicasts per (r+1)-group columns one at a time - fine on
an Ethernet bus, wrong on an ICI torus. Here every server packs ALL its coded
columns (across all groups it serves) into one dense uint32 buffer and a
single jax.lax.all_gather moves every buffer to every server in one fused
collective; receivers slice their groups and XOR-strip locally (kernels/
xor_code). Bit volume on the wire equals the literal schedule's (padding
aside); latency collapses from O(#groups * #columns) transmissions to one
collective phase - this is the hardware adaptation of the paper's shared-bus
assumption.

The column/slot structure comes straight off the compiled `ShufflePlan`
(compile-once), rather than re-enumerating (r+1)-subsets here; this file only
lays the plan's columns out per sender for the dense all_gather.

Runs under shard_map on a ('servers',) mesh; devices = servers.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..launch.mesh import shard_map_compat
from .allocation import Allocation
from .graph_models import Graph
from .shuffle_plan import compile_plan


def build_schedule(adj: np.ndarray, alloc: Allocation):
    """Static (graph-dependent, data-independent) coded schedule.

    Compiles the ShufflePlan once and lays its columns out per sender,
    padded to a common buffer length so the all_gather is dense. Returns
    numpy index tensors consumed by the jitted exchange.
    """
    K, r = alloc.K, alloc.r
    plan = compile_plan(adj, alloc, validate=False)
    # Deterministic per-sender column order: (group, in-group column rank).
    order = np.lexsort((plan.col_rank, plan.col_gm, plan.col_sender))
    per_s: list[list[int]] = [[] for _ in range(K)]
    for c in order:
        per_s[int(plan.col_sender[c])].append(int(c))
    width = max((len(p) for p in per_s), default=0)

    P_pairs = plan.pair_k.size
    # Encode tensors: for slot t of server s, the XOR of values v[i,j] over
    # receivers. We express it as up-to-r (i, j) index pairs (-1 padded).
    enc_idx = np.full((K, width, r, 2), -1, dtype=np.int32)
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            for sl in range(r):
                p = int(plan.slot_pair[c, sl])
                if p == P_pairs:          # sentinel: empty slot
                    continue
                enc_idx[s, t, sl] = (plan.pair_i[p], plan.pair_j[p])
    # Decode map: receiver k strips every other member's value from the slot.
    # For each (sender s, slot t) useful to k: target (i, j) plus the strip
    # list; represent as target idx and r-1 strip idx pairs.
    dec: dict[int, list] = {k: [] for k in range(K)}
    for s in range(K):
        for t, c in enumerate(per_s[s]):
            occupied = [sl for sl in range(r)
                        if int(plan.slot_pair[c, sl]) != P_pairs]
            for sl in occupied:
                p = int(plan.slot_pair[c, sl])
                k = int(plan.pair_k[p])
                strips = [(int(plan.pair_i[int(plan.slot_pair[c, sl2])]),
                           int(plan.pair_j[int(plan.slot_pair[c, sl2])]))
                          for sl2 in occupied if sl2 != sl]
                tgt = (int(plan.pair_i[p]), int(plan.pair_j[p]))
                dec[k].append((s, t, tgt, strips))
    dwidth = max((len(d) for d in dec.values()), default=0)
    dec_src = np.zeros((K, dwidth, 2), dtype=np.int32)       # (sender, slot)
    dec_tgt = np.full((K, dwidth, 2), -1, dtype=np.int32)    # (i, j)
    dec_strip = np.full((K, dwidth, r - 1, 2), -1, dtype=np.int32) \
        if r > 1 else np.zeros((K, dwidth, 0, 2), np.int32)
    for k, items in dec.items():
        for t, (s, slot_t, (i, j), strips) in enumerate(items):
            dec_src[k, t] = (s, slot_t)
            dec_tgt[k, t] = (i, j)
            for ri, (i2, j2) in enumerate(strips):
                dec_strip[k, t, ri] = (i2, j2)
    return enc_idx, dec_src, dec_tgt, dec_strip


def _as_words(x):
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def _as_floats(w):
    return jax.lax.bitcast_convert_type(w, jnp.float32)


def fused_exchange(values: jnp.ndarray, enc_idx, dec_src, dec_tgt, dec_strip,
                   mesh: Mesh):
    """One coded Shuffle as a single all_gather of packed XOR buffers.

    values [n, n] float32 (replicated Map output; each server only reads its
    own columns through the schedule indices). Returns [n, n] recovered
    missing values (0 where not delivered) - identical on every server.
    """
    words = _as_words(values)

    def per_server(enc_s, dec_src_s, dec_tgt_s, dec_strip_s):
        # enc_s [1, W, r, 2] on this shard.
        enc_s = enc_s[0]
        valid = enc_s[:, :, 0] >= 0
        vals = words[jnp.clip(enc_s[:, :, 0], 0), jnp.clip(enc_s[:, :, 1], 0)]
        buf = jnp.where(valid, vals, jnp.uint32(0))
        coded = jax.lax.reduce(buf, jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        allbufs = jax.lax.all_gather(coded, "servers")       # [K, W]
        # Decode this server's targets.
        d_src, d_tgt, d_strip = dec_src_s[0], dec_tgt_s[0], dec_strip_s[0]
        got = allbufs[d_src[:, 0], d_src[:, 1]]
        sv = d_strip[:, :, 0] >= 0
        strip_vals = words[jnp.clip(d_strip[:, :, 0], 0),
                           jnp.clip(d_strip[:, :, 1], 0)]
        strip = jax.lax.reduce(jnp.where(sv, strip_vals, jnp.uint32(0)),
                               jnp.uint32(0), jax.lax.bitwise_xor, (1,))
        rec = got ^ strip
        out = jnp.zeros(words.shape, jnp.uint32)
        tgt_ok = d_tgt[:, 0] >= 0
        out = out.at[jnp.clip(d_tgt[:, 0], 0),
                     jnp.clip(d_tgt[:, 1], 0)].set(
            jnp.where(tgt_ok, rec, jnp.uint32(0)))
        return jax.lax.psum(out, "servers")   # union of per-server recoveries

    f = shard_map_compat(per_server, mesh=mesh,
                         in_specs=(P("servers"), P("servers"), P("servers"),
                                   P("servers")),
                         out_specs=P())
    out_words = f(jnp.asarray(enc_idx), jnp.asarray(dec_src),
                  jnp.asarray(dec_tgt), jnp.asarray(dec_strip))
    return _as_floats(out_words)


def run_fused(g: Graph, values: np.ndarray, alloc: Allocation, mesh: Mesh):
    """Convenience wrapper: schedule + exchange; returns recovered matrix."""
    sched = build_schedule(g.adj, alloc)
    return fused_exchange(jnp.asarray(values, jnp.float32), *sched, mesh=mesh)
