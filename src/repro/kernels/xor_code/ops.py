"""Jitted public wrappers for XOR encode/decode (fused TPU shuffle path)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .xor_code import xor_encode_pallas


def xor_encode(rows: jnp.ndarray, valid: jnp.ndarray, *, use_kernel: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    if use_kernel:
        return xor_encode_pallas(rows, valid, interpret=interpret)
    return ref.xor_encode(rows, valid)


def xor_decode(coded: jnp.ndarray, known_rows: jnp.ndarray,
               known_valid: jnp.ndarray, *, use_kernel: bool = True,
               interpret: bool = True) -> jnp.ndarray:
    """coded [C, W]; known_rows [r-1, C, W]; -> missing segments [C, W]."""
    strip = xor_encode(known_rows, known_valid, use_kernel=use_kernel,
                       interpret=interpret)
    return jnp.bitwise_xor(coded, strip)


def floats_as_words(x: jnp.ndarray) -> jnp.ndarray:
    """Bit-preserving float32 -> uint32 view (lane codec for the fused path)."""
    return jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)


def words_as_floats(w: jnp.ndarray) -> jnp.ndarray:
    return jax.lax.bitcast_convert_type(w.astype(jnp.uint32), jnp.float32)
