"""Real-dataset ingestion: edge-list loaders + normalization to CSR graphs.

Reads SNAP-style whitespace/comma-separated edge lists (`# `/`% ` comment
lines, one "u v" pair per line, arbitrary non-negative integer labels) and
normalizes them into the engine's undirected simple-graph contract:

  * every line is treated as one undirected edge (symmetrize),
  * self-loops dropped, duplicate edges (either orientation) deduped,
  * labels relabeled to a contiguous [0, n) range (ascending original id),
  * optionally restricted to the largest connected component,

then builds a CSR-native `Graph` - the dense [n, n] view is never touched,
so real datasets load at O(edges). `params["labels"]` maps each normalized
vertex id back to its original label.

A tiny committed real-world fixture (Zachary's karate club, with raw-format
noise: comments, duplicates, a self-loop, a detached component) lives at
`data/karate.edges` for tests and the CI benchmark smoke run.
"""
from __future__ import annotations

import pathlib

import numpy as np

from ..core.graph_models import Graph

__all__ = ["read_edge_list", "normalize_edges", "load_graph",
           "fixture_path", "load_fixture", "write_edge_list"]

FIXTURE_DIR = pathlib.Path(__file__).parent / "data"


def fixture_path(name: str = "karate") -> pathlib.Path:
    """Path of a committed fixture edge list (default: karate club)."""
    return FIXTURE_DIR / f"{name}.edges"


def read_edge_list(source, comments: tuple[str, ...] = ("#", "%"),
                   chunk_bytes: int = 1 << 22,
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Raw (u, v) int64 label arrays from a path or an iterable of lines.

    Accepts whitespace- or comma-separated fields (CRLF tolerated); extra
    per-line fields (weights, timestamps) are ignored. No normalization is
    applied.

    Paths stream in `chunk_bytes` binary chunks through a vectorized byte
    parser (`_parse_block_fast`): separator translation, line/comment
    classification, and digit-run accumulation are all NumPy array passes,
    so a ~500k-line SNAP file parses in milliseconds instead of the
    per-line `int()` loop the ingest path used to bottleneck on. Any block
    the fast path cannot certify (non-digit bytes inside the first two
    fields, e.g. signs or floats) re-parses through the line-by-line
    reference `_parse_lines`, which is also the iterable-of-lines path -
    the two are byte-parity equivalent wherever both succeed.
    """
    if not isinstance(source, (str, pathlib.Path)):
        return _parse_lines(source, comments, 0)
    blocks: list[tuple[np.ndarray, np.ndarray]] = []
    with open(source, "rb") as f:
        carry = b""
        lineno = 0                       # complete lines consumed so far
        while True:
            chunk = f.read(chunk_bytes)
            if not chunk:
                if carry:                # final line without a newline
                    blocks.append(_parse_block(carry + b"\n", comments,
                                               lineno))
                break
            data = carry + chunk
            head, sep, carry = data.rpartition(b"\n")
            if not sep:                  # no newline yet: keep accumulating
                carry = data
                continue
            block = head + b"\n"
            blocks.append(_parse_block(block, comments, lineno))
            # Logical lines consumed: universal-newline semantics, so bare
            # '\r' terminators (fallback-parsed blocks) count too - error
            # line numbers stay global and chunk-size independent.
            lineno += (block.count(b"\n") + block.count(b"\r")
                       - block.count(b"\r\n"))
    if not blocks:
        empty = np.zeros(0, dtype=np.int64)
        return empty, empty.copy()
    return (np.concatenate([u for u, _ in blocks]),
            np.concatenate([v for _, v in blocks]))


def _parse_lines(source, comments: tuple[str, ...], base_lineno: int,
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Line-by-line reference parser (iterables + fast-path fallback).

    `base_lineno` offsets error messages when re-parsing one streamed block
    of a larger file.
    """
    us: list[int] = []
    vs: list[int] = []
    for lineno, line in enumerate(source, base_lineno + 1):
        line = line.strip()
        if not line or line.startswith(comments):
            continue
        fields = line.replace(",", " ").split()
        if len(fields) < 2:
            raise ValueError(f"line {lineno}: need at least two fields, "
                             f"got {line!r}")
        us.append(int(fields[0]))
        vs.append(int(fields[1]))
    return np.asarray(us, dtype=np.int64), np.asarray(vs, dtype=np.int64)


def _parse_block(data: bytes, comments: tuple[str, ...], base_lineno: int,
                 ) -> tuple[np.ndarray, np.ndarray]:
    """One newline-terminated block: fast path, else reference re-parse."""
    out = _parse_block_fast(data, comments)
    if out is None:
        out = _parse_lines(data.decode().splitlines(), comments, base_lineno)
    return out


def _token_values(b: np.ndarray, starts: np.ndarray, lengths: np.ndarray,
                  ) -> np.ndarray:
    """int64 value of each digit run; one vectorized pass per digit place."""
    vals = np.zeros(starts.size, dtype=np.int64)
    for t in range(int(lengths.max()) if starts.size else 0):
        sel = lengths > t
        vals[sel] = vals[sel] * 10 + (b[starts[sel] + t] - ord("0"))
    return vals


def _parse_block_fast(data: bytes, comments: tuple[str, ...],
                      ) -> tuple[np.ndarray, np.ndarray] | None:
    """Vectorized (u, v) extraction from a newline-terminated byte block.

    Returns None when the block needs the reference parser: multi-byte
    comment prefixes, a data line with fewer than two digit runs, a
    non-digit byte at or before the end of a line's second field (sign,
    float, garbage - the reference either accepts or raises there), or a
    field too long for int64.
    """
    if not all(len(c) == 1 for c in comments):
        return None
    b = np.frombuffer(data, dtype=np.uint8)
    nl = b == ord("\n")
    # A bare '\r' (not part of CRLF) is a line terminator under the
    # reference's universal-newline semantics but intra-line whitespace
    # here - let the reference split those lines (str.splitlines does).
    cr = np.flatnonzero(b == ord("\r"))
    if cr.size and not nl[np.minimum(cr + 1, b.size - 1)].all():
        return None
    line_start = np.flatnonzero(np.concatenate([[True], nl[:-1]]))
    line_end = np.flatnonzero(nl)                   # one '\n' per line
    # Classification mirrors the reference's `line.strip()`: only true
    # whitespace is stripped (a leading comma is content, not blank), so
    # the first non-whitespace byte decides blank/comment/data.
    ws = (b == ord(" ")) | (b == ord("\t")) | (b == ord("\r"))
    content = ~ws & ~nl
    first = np.minimum.reduceat(
        np.where(content, np.arange(b.size, dtype=np.int64), b.size),
        line_start)
    blank = first >= line_end
    lead = b[np.minimum(first, b.size - 1)]
    comment = ~blank & np.isin(lead, np.frombuffer(
        "".join(comments).encode(), dtype=np.uint8))
    is_data = ~blank & ~comment

    dig = (b >= ord("0")) & (b <= ord("9"))
    starts = np.flatnonzero(dig & ~np.concatenate([[False], dig[:-1]]))
    lengths = np.flatnonzero(dig & ~np.concatenate([dig[1:], [False]])) \
        + 1 - starts
    tline = np.searchsorted(line_start, starts, side="right") - 1
    on_data = is_data[tline]
    starts, lengths, tline = starts[on_data], lengths[on_data], tline[on_data]
    if lengths.size and int(lengths.max()) > 18:    # int64 overflow risk
        return None
    if np.count_nonzero(np.bincount(tline, minlength=line_start.size)[is_data]
                        < 2):
        return None                                 # short line: reference
    # First two digit runs of each data line (tline is nondecreasing).
    tok0 = np.searchsorted(tline, np.flatnonzero(is_data))
    second_end = starts[tok0 + 1] + lengths[tok0 + 1]
    # A byte that is neither a digit nor a separator, at or before the end
    # of a line's second field, means the fields are not plain unsigned
    # integers - let the reference parser accept or raise there.
    garbage = np.flatnonzero(content & ~dig & (b != ord(",")))
    garbage = garbage[is_data[np.searchsorted(line_start, garbage,
                                              side="right") - 1]]
    if garbage.size:
        gline = np.searchsorted(line_start, garbage, side="right") - 1
        data_id = np.cumsum(is_data) - 1            # line -> data-line rank
        if (garbage <= second_end[data_id[gline]]).any():
            return None
    take = np.concatenate([tok0, tok0 + 1])
    vals = _token_values(b, starts[take], lengths[take])
    return vals[:tok0.size], vals[tok0.size:]


def _components(u: np.ndarray, v: np.ndarray, n: int) -> np.ndarray:
    """[n] min-vertex-id component label per vertex (vectorized min-label
    propagation with pointer jumping; O(edges * log diameter) passes)."""
    comp = np.arange(n, dtype=np.int64)
    while True:
        prev = comp.copy()
        np.minimum.at(comp, u, comp[v])
        np.minimum.at(comp, v, comp[u])
        comp = np.minimum(comp, comp[comp])        # pointer jumping
        if np.array_equal(comp, prev):
            break
    while True:                                     # full compression
        nxt = comp[comp]
        if np.array_equal(nxt, comp):
            return comp
        comp = nxt


def normalize_edges(u: np.ndarray, v: np.ndarray, *,
                    largest_cc: bool = False,
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Normalize raw undirected edge labels; see the module docstring.

    Returns (u2, v2, labels): deduped canonical (u2 < v2) edges over the
    contiguous vertex range [0, labels.size), with labels[new_id] = original
    label (ascending, so relabeling is order-preserving).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    lo, hi = np.minimum(u, v), np.maximum(u, v)    # symmetrize orientation
    keep = lo != hi                                 # strip self-loops
    lo, hi = lo[keep], hi[keep]
    labels, flat = np.unique(np.concatenate([lo, hi]), return_inverse=True)
    n = labels.size
    lo, hi = flat[:lo.size], flat[lo.size:]         # contiguous relabel
    pairs = np.unique(lo * n + hi)                  # dedup undirected pairs
    lo, hi = pairs // n, pairs % n
    if largest_cc:
        if n == 0:
            raise ValueError(
                "edge list has no edges after normalization (empty, "
                "comment-only, or self-loops only); cannot extract a "
                "largest connected component")
        comp = _components(lo, hi, n)
        roots, sizes = np.unique(comp, return_counts=True)
        big = roots[np.argmax(sizes)]
        keep_v = comp == big
        new_id = np.cumsum(keep_v) - 1
        sel = keep_v[lo]                            # == keep_v[hi]
        lo, hi = new_id[lo[sel]], new_id[hi[sel]]
        labels = labels[keep_v]
    return lo, hi, labels


def load_graph(source, *, largest_cc: bool = False, name: str | None = None,
               ) -> Graph:
    """Load + normalize an edge list into a CSR-native `Graph`.

    `params` records the provenance: original label map (`labels`), raw
    line/vertex counts, and whether the largest component was extracted.
    """
    u, v = read_edge_list(source)
    lo, hi, labels = normalize_edges(u, v, largest_cc=largest_cc)
    if name is None:
        name = (pathlib.Path(source).stem
                if isinstance(source, (str, pathlib.Path)) else "edges")
    return Graph.from_edges(lo, hi, labels.size, "real", {
        "name": name, "labels": labels, "raw_lines": int(u.size),
        "largest_cc": largest_cc})


def load_fixture(name: str = "karate", *, largest_cc: bool = True) -> Graph:
    """The committed real-world fixture graph, normalized (LCC by default:
    the raw file deliberately carries a detached noise component)."""
    return load_graph(fixture_path(name), largest_cc=largest_cc, name=name)


def write_edge_list(g: Graph, path, header: str | None = None) -> None:
    """Write one undirected edge per line in normalized vertex ids.

    The edge-list format carries edges only: isolated vertices (e.g.
    `Graph.padded` padding) and original labels are not representable, so
    a `load_graph` round-trip reproduces the CSR exactly iff every vertex
    has degree >= 1 (true for normalized largest-CC datasets); otherwise
    the reloaded graph is the edge-bearing subgraph, relabeled contiguous.
    """
    csr = g.csr
    upper = csr.rows < csr.indices
    with open(path, "w") as f:
        if header:
            for line in header.splitlines():
                f.write(f"# {line}\n")
        for i, j in zip(csr.rows[upper], csr.indices[upper]):
            f.write(f"{i} {j}\n")
