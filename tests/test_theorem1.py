"""Theorem 1's inverse-linear trade-off as a tier-1 test (not a benchmark).

The paper's headline result: the coded scheme's communication load is an
r-fold improvement, L^C(r) -> L^UC / r (ER, Theorem 1; power-law, Theorem
4 - same 1/r shape, slower convergence). Here the *empirical* ratio

    gain(r) = (coded_bits(r) + leftover_bits(r)) * r / uncoded_bits(r)

read off compiled plans of seeded realizations must sit within tolerance
of 1 across an r-grid:

  * lower side: gain(r) >= 1 exactly - a column is as wide as its widest
    segment, so coded_bits >= 32 P / r and leftovers are never cheaper
    than unicast; a value below 1 would beat the converse bound and means
    the bit accounting is broken;
  * upper side: the only overhead is column padding (max over <= r slot
    widths), which concentrates as n grows - tolerances are calibrated
    max-over-seeds at n = 600, K = 6 with ~2x headroom (measured: ER
    <= 1.061, power-law <= 1.457 on this grid).

Deterministic (seeded streaming samplers, schedule-only accounting - no
data, no clocks), so this is a correctness gate, not a flaky perf check.
"""
import pytest

from repro import graphs
from repro.core.allocation import er_allocation
from repro.core.shuffle_plan import compile_plan_csr

K = 6
N = 600                       # divisible by K and C(K, r) for r in 1..3
R_GRID = (1, 2, 3)
SEEDS = (0, 1)
TOL = {"er": 0.10, "pl": 0.55}


def _sample(model, seed):
    if model == "er":
        return graphs.erdos_renyi(N, 0.3, seed=seed)
    return graphs.power_law(N, 2.5, seed=seed)


@pytest.mark.parametrize("model", ["er", "pl"])
def test_theorem1_inverse_linear_tradeoff(model):
    for seed in SEEDS:
        g = _sample(model, seed)
        loads = {}
        for r in R_GRID:
            alloc = er_allocation(N, K, r)
            plan = compile_plan_csr(g.csr, alloc, validate=False)
            coded = plan.coded_bits + plan.leftover_bits
            gain = coded * r / plan.uncoded_bits
            assert gain >= 1.0 - 1e-12, \
                f"{model} seed={seed} r={r}: gain {gain} beats the converse"
            assert gain <= 1.0 + TOL[model], \
                f"{model} seed={seed} r={r}: gain {gain} off Theorem 1"
            loads[r] = plan.coded_load() + plan.leftover_bits / (
                N * N * 32)
        # The trade-off really is decreasing in r (the whole point).
        assert loads[1] > loads[2] > loads[3]


def test_theorem1_r1_is_exactly_uncoded():
    """r = 1: no coding is possible, and the accounting must agree exactly
    (every 'column' is one full 32-bit word of one missing value)."""
    g = _sample("er", 0)
    alloc = er_allocation(N, K, 1)
    plan = compile_plan_csr(g.csr, alloc, validate=False)
    assert plan.coded_bits + plan.leftover_bits == plan.uncoded_bits
