"""Fault tolerance built on the paper's r-fold Map redundancy (DESIGN.md §5).

The coded allocation stores every vertex at r servers, so the loss of up to
r-1 servers destroys no Map shard. On failure of server f:
  * f's Reduce partition R_f is re-assigned round-robin to survivors,
  * survivors fetch the values the new owners are missing (uncoded unicast;
    coded groups that contained f are degraded for exactly f's segments),
  * if r == 1, batches uniquely Mapped at f are *re-Mapped* by survivors
    (counted as recovery compute, not shuffle bits).

`run_with_failure` executes this end-to-end and must match the oracle exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .algorithms import VertexProgram
from .allocation import Allocation
from .bitcodec import T_BITS
from .engine import EngineResult, _reduce_distributed
from .graph_models import Graph


@dataclasses.dataclass(frozen=True)
class RecoveryStats:
    failed: tuple[int, ...]
    remapped_vertices: int         # Map work repeated by survivors (r==1 only)
    recovery_bits: int             # extra shuffle bits for recovery


def degrade_allocation(alloc: Allocation, failed: tuple[int, ...]) -> tuple[Allocation, RecoveryStats]:
    """Reassign failed servers' Reduce partitions; re-Map orphaned batches."""
    survivors = [k for k in range(alloc.K) if k not in failed]
    if not survivors:
        raise ValueError("all servers failed")
    reduce_owner = alloc.reduce_owner.copy()
    orphans = np.flatnonzero(np.isin(reduce_owner, failed))
    reduce_owner[orphans] = np.array(survivors)[np.arange(len(orphans)) % len(survivors)]
    map_sets = alloc.map_sets.copy()
    map_sets[list(failed), :] = False
    # Re-Map any vertex no longer Mapped anywhere (possible only if r <= |failed|).
    unmapped = np.flatnonzero(~map_sets.any(axis=0))
    for idx, v in enumerate(unmapped):
        map_sets[survivors[idx % len(survivors)], v] = True
    degraded = Allocation(alloc.n, alloc.K, alloc.r, alloc.subsets,
                          alloc.batch_of, map_sets, reduce_owner)
    stats = RecoveryStats(tuple(failed), int(len(unmapped)), 0)
    return degraded, stats


def run_with_failure(program: VertexProgram, g: Graph, alloc: Allocation,
                     iters: int, failed: tuple[int, ...],
                     fail_at_iter: int = 0) -> tuple[EngineResult, RecoveryStats]:
    """Run iterations; servers in `failed` die at `fail_at_iter` (post-Map).

    Iterations before the failure use the coded schedule; after the failure
    the degraded allocation shuffles uncoded (a real deployment would rebuild
    the coded schedule for K' = K - |failed| at the next checkpoint; see
    rebalance()).

    Programs with an edge-value form run the O(edges) sparse path (one
    missing-set plan compiled per allocation epoch); others fall back to the
    dense dict-delivery reference. Bit accounting is identical either way.
    """
    from .engine import _reduce_sparse
    from .shuffle_plan import compile_plan_csr
    from .uncoded_shuffle import run_uncoded

    state = program.init(g)
    total_bits = 0
    degraded, stats = degrade_allocation(alloc, failed)
    recovery_bits = 0
    sparse = program.supports_sparse
    if sparse:
        # Compile only the epochs that actually run, adjacency-free off the
        # CSR view (fail_at_iter=0 never uses the pre plan).
        plan_pre = (compile_plan_csr(g.csr, alloc, schedule=False)
                    if fail_at_iter > 0 else None)
        plan_post = (compile_plan_csr(g.csr, degraded, schedule=False)
                     if fail_at_iter < iters else None)
    for it in range(iters):
        alloc_now = alloc if it < fail_at_iter else degraded
        if sparse:
            plan_now = plan_pre if it < fail_at_iter else plan_post
            tables = plan_now.edge_tables(g.csr, alloc_now)
            edge_vals = program.map_edge_values(g, state).astype(np.float32)
            res = plan_now.execute_uncoded_sparse(edge_vals, tables)
            state = _reduce_sparse(program, g, edge_vals, res, tables.gather,
                                   state)
        else:
            values = program.map_values(g, state).astype(np.float32)
            res = run_uncoded(g.adj, values, alloc_now)
            state = _reduce_distributed(program, g, alloc_now, values,
                                        res.delivered, state)
        if it == fail_at_iter:
            recovery_bits = res.bits_sent  # first post-failure shuffle = recovery
        total_bits += res.bits_sent
    result = EngineResult(state, iters, total_bits, f"failover-{len(failed)}")
    return result, dataclasses.replace(stats, recovery_bits=recovery_bits)


def straggler_coded_load(graph, alloc: Allocation,
                         stragglers: tuple[int, ...]) -> float:
    """Normalized coded load when `stragglers` send nothing.

    When sender s straggles, the lexicographically-first healthy member s' of
    its group takes over s's coded columns. s' holds every row of s's table
    EXCEPT its own (Z^{s'} is exactly what s' is missing), so:
      * s' re-sends s's columns with the s'-row omitted (same bits; the other
        receivers strip one fewer row),
      * s'-s own segments that s owed it are unicast by a third healthy
        member (they all Mapped B_{S\\{s'}}) - that unicast is the overhead.

    `graph` is a `Graph`, a raw `CSR` view, or an already-compiled scheduled
    `ShufflePlan` - those route through `straggler_coded_load_plan`, O(plan)
    after one O(edges) CSR compile, so straggler accounting works past
    `dense_limit`. A dense [n, n] adjacency still runs the legacy
    subset-enumeration reference below (exactly equal by construction: the
    plan path only replaces the per-group |Z^k| counts).
    """
    import itertools

    from .bitcodec import T_BITS, segment_bounds
    from .coded_shuffle import group_need
    from .graph_models import CSR, Graph
    from .shuffle_plan import ShufflePlan, compile_plan_csr

    if isinstance(graph, ShufflePlan):
        graph.check_alloc(alloc)
        return straggler_coded_load_plan(graph, stragglers)
    if isinstance(graph, (Graph, CSR)):
        csr = graph.csr if isinstance(graph, Graph) else graph
        return straggler_coded_load_plan(
            compile_plan_csr(csr, alloc, validate=False), stragglers)
    adj = graph
    K, r = alloc.K, alloc.r
    bounds = segment_bounds(r)
    total_bits = 0
    for S in itertools.combinations(range(K), r + 1):
        sizes = {k: len(group_need(adj, alloc, S, k)) for k in S}
        total_bits += _group_straggler_bits(S, sizes, stragglers, r, bounds)
    return total_bits / (alloc.n * alloc.n * T_BITS)


def _group_straggler_bits(S: tuple[int, ...], sizes: dict[int, int],
                          stragglers: tuple[int, ...], r: int,
                          bounds) -> int:
    """Bits one (r+1)-group sends under stragglers; see
    `straggler_coded_load` for the hand-over accounting."""
    healthy = [x for x in S if x not in stragglers]
    if len(healthy) < 2:
        raise ValueError(f"group {S} lacks healthy senders")
    bits = 0
    for s in S:
        rows = []
        for k in S:
            if k == s:
                continue
            others = tuple(sorted(set(S) - {k}))
            a, b = bounds[others.index(s)]
            rows.append((k, sizes[k], b - a))
        ncols = max((sz for _, sz, _ in rows), default=0)
        bits += sum(max((w for _, sz, w in rows if c < sz), default=0)
                    for c in range(ncols))
        if s in stragglers:
            stand_in = next(x for x in healthy if x != s)
            # Overhead: unicast of the stand-in's own segments from row
            # s' of s's table (it cannot XOR what it does not have).
            others = tuple(sorted(set(S) - {stand_in}))
            a, b = bounds[others.index(s)]
            bits += sizes[stand_in] * (b - a)
    return bits


def straggler_coded_load_plan(plan, stragglers: tuple[int, ...]) -> float:
    """`straggler_coded_load` read off a compiled scheduled `ShufflePlan`.

    The dense reference only consumes the per-(group, receiver) needed-value
    counts |Z^k_{S\\{k}}|; those are run lengths of the plan's covered-pair
    table (each pair's group is the bitmask of its segment-0 column), so the
    whole accounting is one O(P) pass plus the same C(K, r+1) group loop -
    no adjacency, hence no dense_limit ceiling. Exactly equal to the dense
    reference on the same realization.
    """
    import itertools

    from .bitcodec import T_BITS, segment_bounds
    from .shuffle_plan import ShufflePlan

    assert isinstance(plan, ShufflePlan)
    plan._require_schedule()
    K, r = plan.K, plan.r
    sizes: dict[tuple[int, int], int] = {}
    if plan.pair_k.size:
        gm = plan.col_gm[plan.pair_col[:, 0]]
        order = np.lexsort((plan.pair_k, gm))
        g_s, k_s = gm[order], plan.pair_k[order]
        new = np.ones(g_s.size, dtype=bool)
        new[1:] = (g_s[1:] != g_s[:-1]) | (k_s[1:] != k_s[:-1])
        starts = np.flatnonzero(new)
        counts = np.diff(np.append(starts, g_s.size))
        for gmv, kv, c in zip(g_s[starts], k_s[starts], counts):
            sizes[(int(gmv), int(kv))] = int(c)
    bounds = segment_bounds(r)
    total_bits = 0
    for S in itertools.combinations(range(K), r + 1):
        mask = sum(1 << x for x in S)
        group_sizes = {k: sizes.get((mask, k), 0) for k in S}
        total_bits += _group_straggler_bits(S, group_sizes, stragglers, r,
                                            bounds)
    return total_bits / (plan.n * plan.n * T_BITS)


def rebalance(alloc: Allocation, K_new: int) -> Allocation:
    """Elastic re-allocation onto K_new servers (same n, same r if feasible).

    Deterministic: allocation depends only on (n, K, r), so scale-up/down is a
    pure re-partition - checkpointed vertex state carries over unchanged.
    """
    from .allocation import divisible_n, er_allocation

    r = min(alloc.r, K_new)
    n2 = divisible_n(alloc.n, K_new, r)
    if n2 != alloc.n:
        raise ValueError(
            f"n={alloc.n} not compatible with K={K_new}, r={r}; pad to {n2}")
    return er_allocation(alloc.n, K_new, r)
