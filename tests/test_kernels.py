"""Per-kernel shape/dtype sweeps: pallas_call (interpret=True) vs ref.py."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.kernels.spmv import ops as spmv_ops
from repro.kernels.spmv import ref as spmv_ref
from repro.kernels.ssd_scan import ops as ssd_ops
from repro.kernels.ssd_scan import ref as ssd_ref
from repro.kernels.xor_code import ops as xor_ops
from repro.kernels.xor_code import ref as xor_ref

RNG = np.random.default_rng(123)


# ---------------- spmv ----------------

@pytest.mark.parametrize("m,n", [(128, 128), (256, 384), (300, 300), (100, 250),
                                 (1, 128), (128, 1)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_spmv_matches_ref(m, n, dtype):
    adj = (RNG.random((m, n)) < 0.2).astype(dtype)
    x = RNG.standard_normal(n).astype(dtype)
    got = spmv_ops.spmv(jnp.array(adj), jnp.array(x))
    want = spmv_ref.spmv(jnp.array(adj), jnp.array(x))
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("bm,bk", [(64, 64), (128, 256), (256, 128)])
def test_spmv_block_shape_sweep(bm, bk):
    adj = (RNG.random((512, 512)) < 0.1).astype(np.float32)
    x = RNG.standard_normal(512).astype(np.float32)
    got = spmv_ops.spmv(jnp.array(adj), jnp.array(x), bm=bm, bk=bk)
    want = spmv_ref.spmv(jnp.array(adj), jnp.array(x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_spmv_pagerank_step_matches_engine_oracle():
    from repro.core import algorithms as algo
    from repro.core import graph_models as gm
    g = gm.erdos_renyi(200, 0.1, seed=5)
    prog = algo.pagerank()
    ref_state = algo.reference_run(prog, g, 1)
    got = spmv_ops.pagerank_step(jnp.array(g.adj, jnp.float32),
                                 jnp.array(prog.init(g)))
    np.testing.assert_allclose(got, ref_state, rtol=1e-5, atol=1e-7)


# ---------------- xor_code ----------------

@pytest.mark.parametrize("r,c,w", [(1, 10, 1), (2, 256, 1), (3, 511, 2),
                                   (4, 1000, 4), (8, 37, 8)])
def test_xor_encode_matches_ref(r, c, w):
    rows = RNG.integers(0, 2**32, size=(r, c, w), dtype=np.uint32)
    valid = RNG.random((r, c)) < 0.6
    got = xor_ops.xor_encode(jnp.array(rows), jnp.array(valid))
    want = xor_ref.xor_encode(jnp.array(rows), jnp.array(valid))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("r", [2, 3, 5])
def test_xor_roundtrip_recovers_missing_row(r):
    """encode(all rows) XOR encode(known rows) == the unknown row."""
    c, w = 300, 2
    rows = RNG.integers(0, 2**32, size=(r, c, w), dtype=np.uint32)
    valid = np.ones((r, c), dtype=bool)
    valid[:, 250:] = RNG.random((r, 50)) < 0.5
    coded = xor_ops.xor_encode(jnp.array(rows), jnp.array(valid))
    dec = xor_ops.xor_decode(coded, jnp.array(rows[1:]), jnp.array(valid[1:]))
    want = np.where(valid[0][:, None], rows[0], 0)
    np.testing.assert_array_equal(np.asarray(dec), want)


def test_xor_float_bitcast_roundtrip():
    x = RNG.standard_normal(64).astype(np.float32)
    w = xor_ops.floats_as_words(jnp.array(x))
    back = xor_ops.words_as_floats(w)
    np.testing.assert_array_equal(np.asarray(back).view(np.uint32),
                                  x.view(np.uint32))


# ---------------- ssd_scan ----------------

def _ssd_inputs(G, L, P, N, dtype=np.float32):
    return (RNG.standard_normal((G, L, P)).astype(dtype),
            RNG.uniform(0.01, 0.2, (G, L)).astype(dtype),
            (-RNG.uniform(0.5, 2.0, G)).astype(dtype),
            RNG.standard_normal((G, L, N)).astype(dtype),
            RNG.standard_normal((G, L, N)).astype(dtype),
            RNG.standard_normal(G).astype(dtype))


@pytest.mark.parametrize("G,L,P,N,chunk", [
    (1, 64, 8, 4, 16), (2, 128, 16, 8, 32), (3, 128, 32, 16, 64),
    (2, 256, 8, 8, 128), (1, 32, 64, 32, 32),
])
def test_ssd_matches_sequential_ref(G, L, P, N, chunk):
    args = _ssd_inputs(G, L, P, N)
    y, h = ssd_ops.ssd(*map(jnp.array, args), chunk=chunk)
    y_ref, h_ref = ssd_ref.ssd_scan_batched(*map(jnp.array, args))
    np.testing.assert_allclose(y, y_ref, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h, h_ref, rtol=5e-4, atol=5e-4)


def test_ssd_chunk_invariance():
    args = _ssd_inputs(2, 128, 16, 8)
    y32, h32 = ssd_ops.ssd(*map(jnp.array, args), chunk=32)
    y64, h64 = ssd_ops.ssd(*map(jnp.array, args), chunk=64)
    np.testing.assert_allclose(y32, y64, rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h32, h64, rtol=5e-4, atol=5e-4)


def test_ssd_h0_continuation():
    """Scanning [first half] then [second half with h0] == one full scan."""
    args = _ssd_inputs(2, 128, 8, 4)
    x, dt, A, B, C, D = map(jnp.array, args)
    y_full, h_full = ssd_ops.ssd(x, dt, A, B, C, D, chunk=32)
    y1, h1 = ssd_ops.ssd(x[:, :64], dt[:, :64], A, B[:, :64], C[:, :64], D,
                         chunk=32)
    y2, h2 = ssd_ops.ssd(x[:, 64:], dt[:, 64:], A, B[:, 64:], C[:, 64:], D,
                         h0=h1, chunk=32)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               rtol=5e-4, atol=5e-4)
    np.testing.assert_allclose(h2, h_full, rtol=5e-4, atol=5e-4)


def test_ssd_decode_step_extends_scan():
    args = _ssd_inputs(2, 64, 8, 4)
    x, dt, A, B, C, D = map(jnp.array, args)
    _, h = ssd_ops.ssd(x, dt, A, B, C, D, chunk=32)
    xe, dte = x[:, -1], dt[:, -1]
    y_step, h_step = ssd_ops.ssd_decode_step(xe, dte, A, B[:, -1], C[:, -1], D, h)
    assert y_step.shape == (2, 8) and h_step.shape == h.shape
    assert np.isfinite(np.asarray(y_step)).all()
