"""internvl2-1b [vlm] - InternViT patch embeddings (stub) + InternLM2 decoder
[arXiv:2404.16821; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b", family="vlm",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab=151655, rope_theta=1_000_000.0,
    frontend="vision", num_patches=256,
)
