"""Pallas kernel package."""
