"""AdamW with fp32 state, global-norm clipping and cosine schedule.

Optimizer state is a pytree congruent with params, so the same sharding rules
apply leaf-for-leaf (m/v inherit the param's logical axes -> fully sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(1.0, (step + 1) / cfg.warmup_steps)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def init_state(params) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state) -> tuple[Any, dict]:
    step = state["step"]
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** (step + 1))
        vhat = v / (1 - cfg.b2 ** (step + 1))
        new_p = p.astype(jnp.float32) - lr * (
            mhat / (jnp.sqrt(vhat) + cfg.eps)
            + cfg.weight_decay * p.astype(jnp.float32))
        return new_p.astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step + 1}
