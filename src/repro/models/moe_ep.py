"""True expert-parallel MoE dispatch via shard_map + all_to_all.

EXPERIMENTS.md §Perf cell-B iteration 1 showed that einsum-only dispatch
cannot express EP: XLA all-gathers the token axis because [T, E, C] wants the
same mesh axis on T and E. This module does what the annotations cannot:

  * experts shard over 'data' (E_loc = E/D per shard), each expert's FFN
    still splits over 'model' (f_loc = d_ff/T),
  * tokens one-hot-dispatch LOCALLY into per-destination-shard buffers,
  * one jax.lax.all_to_all moves token activations to their expert owners
    (bytes ~ T*topk*d, vs FSDP re-gathering every expert's weights),
  * expert FFN runs local-to-the-shard, psum over 'model' for the split f,
  * reverse all_to_all returns outputs; combine weights finish locally.

Differentiable (a2a transposes to a2a). Single-pod meshes ('data','model');
falls back to the dense-einsum path otherwise. Per-source-shard capacity
C = T_loc*topk*cf/E (same drop semantics as the dense path when nothing
overflows; tests use a generous capacity factor for exact comparison).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, MoEConfig
from ..launch.mesh import shard_map_compat
from ..sharding import rules


def _dispatch_combine(xt, logits, e: MoEConfig, C: int):
    """Shared with the dense path: one-hot capacity dispatch/combine."""
    T = xt.shape[0]
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    topv, topi = jax.lax.top_k(gates, e.top_k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(topi, e.num_experts, dtype=jnp.int32)
    flat = onehot.reshape(T * e.top_k, e.num_experts)
    pos = jnp.cumsum(flat, axis=0) * flat - 1
    pos = pos.reshape(T, e.top_k, e.num_experts)
    keep = (pos < C) & (pos >= 0)
    slot = jax.nn.one_hot(jnp.where(keep, pos, C), C + 1,
                          dtype=xt.dtype)[..., :C]
    dispatch = (slot * keep[..., None].astype(xt.dtype)).sum(1)
    combine = (slot * (topv[..., None] * keep.astype(jnp.float32))[..., None]
               ).sum(1).astype(jnp.float32)
    return dispatch, combine


def moe_ffn_ep(p, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """Drop-in for moe_ffn under a ('data','model') mesh; EP over 'data'."""
    mesh = rules._mesh()
    e = cfg.moe
    if (mesh is None or set(mesh.shape) != {"data", "model"}
            or e.num_experts % mesh.shape["data"]):
        from .moe import moe_ffn
        return moe_ffn(p, cfg, x)
    D = mesh.shape["data"]
    E_loc = e.num_experts // D

    def body(xs, router, wg, wu, wd):
        B, S, d = xs.shape
        T = B * S
        xt = xs.reshape(T, d)
        logits = jnp.einsum("td,de->te", xt, router)
        C = max(8, int(T * e.top_k * e.capacity_factor / e.num_experts)
                // 8 * 8)
        dispatch, combine = _dispatch_combine(xt, logits, e, C)
        xe = jnp.einsum("td,tec->ecd", xt, dispatch)       # [E, C, d] local
        # a2a: send each destination shard its E_loc experts' buffers.
        xe = xe.reshape(D, E_loc, C, d)
        xr = jax.lax.all_to_all(xe, "data", split_axis=0, concat_axis=0,
                                tiled=False)               # [D_src,E_loc,C,d]
        xr = xr.transpose(1, 0, 2, 3).reshape(E_loc, D * C, d)
        g = jnp.einsum("ecd,edf->ecf", xr, wg)             # f_loc on 'model'
        u = jnp.einsum("ecd,edf->ecf", xr, wu)
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd)
        y = jax.lax.psum(y, "model")                       # f was split
        # reverse a2a: outputs back to token owners.
        y = y.reshape(E_loc, D, C, d).transpose(1, 0, 2, 3)
        yb = jax.lax.all_to_all(y, "data", split_axis=0, concat_axis=0,
                                tiled=False)               # [D_dst,E_loc,C,d]
        ye = yb.reshape(e.num_experts, C, d)
        yt = jnp.einsum("ecd,tec->td", ye.astype(jnp.float32), combine)
        return yt.astype(xs.dtype).reshape(B, S, d)

    # Weight specs: router replicated; experts over 'data', f over 'model'.
    in_specs = (P("data", None, None), P(), P("data", None, "model"),
                P("data", None, "model"), P("data", "model", None))
    args = [x, p["router"], p["w_gate"], p["w_up"], p["w_down"]]
    f = shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                         out_specs=P("data", None, None), check=False)
    out = f(*args)
    if e.num_shared:
        # Shared expert stays on the standard dense GeGLU path outside the
        # manual region (its weights are mlp-sharded over 'model').
        from .layers import geglu
        out = out + geglu(x, p["shared_gate"], p["shared_up"],
                          p["shared_down"], act=cfg.act)
    return out
