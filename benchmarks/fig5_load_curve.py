"""Paper Fig. 5: average communication load vs computation load r.

ER(n, p=0.1), K=5, averaged over graph realizations; overlays the uncoded
baseline, the coded scheme, and the information-theoretic lower bound
(Theorem 1 converse). Dense-free: graphs come from the streaming
`repro.graphs` samplers and the loads are read off CSR-compiled
ShufflePlans (`loads.empirical_loads(g, alloc)`, plan arrays O(edges)), so
full mode sweeps n in the thousands without ever touching `.adj` - closer
to the paper's asymptotics than the original n=300 validation size, and
free to scale past `dense_limit`.
"""
import numpy as np

from repro import graphs, obs
from repro.core import loads
from repro.core.allocation import divisible_n, er_allocation

K, P, SAMPLES = 5, 0.1, 5


def run(report, smoke=False):
    n = divisible_n(60 if smoke else 3000, K, 2)
    samples = 2 if smoke else SAMPLES
    rows = []
    for r in range(1, K + 1):
        alloc = er_allocation(n, K, r)
        lu, lc = [], []
        with obs.stopwatch() as sw:
            for s in range(samples):
                g = graphs.erdos_renyi(n, P, seed=1000 + s)
                measured = loads.empirical_loads(g, alloc)
                lu.append(measured["uncoded"])
                lc.append(measured["coded"])
        us = sw.us / samples / (2 * K)
        row = {
            "r": r,
            "uncoded": float(np.mean(lu)),
            "coded": float(np.mean(lc)),
            "lower_bound": loads.lower_bound_er(P, r, K),
            "uncoded_theory": loads.uncoded_load_er(P, r, K),
            "gain": float(np.mean(lu) / np.mean(lc)) if np.mean(lc) else float("nan"),
        }
        rows.append(row)
        report(f"fig5_r{r}", us, f"coded={row['coded']:.4f} "
               f"lb={row['lower_bound']:.4f} gain={row['gain']:.2f}")
    # Optimality gap at finite n (paper: "small optimality gap").
    gaps = [row["coded"] / row["lower_bound"]
            for row in rows if row["lower_bound"] > 0]
    report("fig5_optimality_gap", 0.0, f"max_coded/lb={max(gaps):.3f}")
    return rows
