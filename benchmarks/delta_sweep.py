"""Incremental plan maintenance: `apply_delta` vs recompiling from scratch.

The PR 9 tentpole makes a compiled `ShufflePlan` follow a mutating graph in
O(plan + |delta|): `CSR.apply_delta` splices the sorted edge streams and
`ShufflePlan.apply_delta` splices every plan array in place of the fresh
lexsort + group-scan pipeline, under the locked contract that the result is
*bitwise identical* to `compile_plan_csr` on the mutated graph.

The sweep holds n ~ 1e5 fixed and grows the batch |delta| from 0.1% to 1%
of the edge set. Per point it reports the incremental wall-clock (plan-only
and including the CSR + edge-table splice) against a fresh compile, asserts
the bitwise contract on the largest batch, and asserts the acceptance gate:
>= 10x faster than recompiling while |delta| <= 1% of edges.

The smoke row is the CI-gated `scale_delta_pagerank_*` record in
`BENCH_scale.json` (`benchmarks/check_regression.py`); smoke mode also
closes the loop through `CompiledEngine.update` against a fresh session.
"""
from __future__ import annotations

import pathlib
import sys

import numpy as np

try:
    from repro.core import algorithms as algo
except ImportError:
    _root = pathlib.Path(__file__).resolve().parents[1]
    sys.path[:0] = [str(_root), str(_root / "src")]
    from repro.core import algorithms as algo

from repro import graphs, obs
from repro.core import engine
from repro.core.allocation import divisible_n, er_allocation
from repro.core.graph_models import Graph
from repro.core.shuffle_plan import compile_plan_csr

GATE = 10.0          # acceptance: >= 10x vs fresh recompile at |delta| <= 1%


def _mk_delta(g, frac, rng):
    """Balanced batch mutating `frac` of the undirected edge set."""
    csr = g.csr
    m = csr.nnz // 2
    k = max(1, int(m * frac) // 2)
    up = csr.rows < csr.indices                 # one direction per edge
    eids = np.flatnonzero(up)
    dels = eids[rng.choice(eids.size, size=k, replace=False)]
    delete = list(zip(csr.rows[dels].tolist(), csr.indices[dels].tolist()))
    have = set(zip(csr.rows.tolist(), csr.indices.tolist()))
    insert, seen = [], set()
    while len(insert) < k:
        u, v = int(rng.integers(g.n)), int(rng.integers(g.n))
        if u == v:
            continue
        key = (min(u, v), max(u, v))
        if key in seen or (u, v) in have:
            continue
        seen.add(key)
        insert.append(key)
    return graphs.EdgeDelta.for_graph(g, insert=insert, delete=delete)


def _best_of(reps, *fns):
    """Best wall-clock per function, interleaved so background-load noise
    lands on every contestant equally. Returns ([best..], [last_out..])."""
    best = [float("inf")] * len(fns)
    outs = [None] * len(fns)
    for _ in range(reps):
        for i, fn in enumerate(fns):
            with obs.stopwatch() as sw:
                outs[i] = fn()
            best[i] = min(best[i], sw.s)
    return best, outs


def run(report, smoke=False):
    n_req, K, r, reps = (240, 4, 2, 3) if smoke else (100_000, 4, 2, 7)
    fracs = [0.01] if smoke else [0.001, 0.005, 0.01]
    n = divisible_n(n_req, K, r)
    rng = np.random.default_rng(7)
    g = graphs.erdos_renyi(n, 10 / n, seed=7)
    alloc = er_allocation(n, K, r)
    plan = compile_plan_csr(g.csr, alloc)
    plan.edge_tables(g.csr, alloc)

    rows = []
    for frac in fracs:
        delta = _mk_delta(g, frac, rng)
        csr2 = g.csr.apply_delta(delta)

        def _full():                         # CSR + plan + edge tables
            csr_full = g.csr.apply_delta(delta)
            return plan.apply_delta(g.csr, alloc, delta, csr_new=csr_full)

        (t_plan, t_fresh, t_full), (out, _, _) = _best_of(
            reps,
            lambda: plan.apply_delta(g.csr, alloc, delta),
            lambda: compile_plan_csr(g.csr, alloc),
            _full)
        plan2, dstats = out
        speedup = t_fresh / t_plan
        assert dstats.schedule_changed
        assert speedup >= GATE or smoke, (
            f"|delta|={frac:.1%}: apply_delta only {speedup:.1f}x faster "
            f"than fresh compile (gate {GATE:.0f}x)")
        report(f"delta_plan_f{frac:g}", t_plan * 1e6,
               f"n={n} nnz={g.csr.nnz} |delta|={len(delta)} "
               f"plan_ms={t_plan * 1e3:.1f} full_ms={t_full * 1e3:.1f} "
               f"fresh_ms={t_fresh * 1e3:.1f} speedup={speedup:.1f}x")
        rows.append({"frac": frac, "delta": len(delta), "s_plan": t_plan,
                     "s_full": t_full, "s_fresh": t_fresh,
                     "speedup": speedup})
        if frac == fracs[-1]:                # bitwise gate, largest batch
            fresh = compile_plan_csr(csr2, alloc)
            for f in ("pair_k", "pair_i", "pair_j", "slot_pair", "pos_left",
                      "col_sender", "pair_col", "pair_slot", "all_k"):
                a, b = getattr(plan2, f), getattr(fresh, f)
                assert a.dtype == b.dtype and np.array_equal(a, b), f

    if smoke:       # end-to-end: a mutated session == a fresh session
        prog = algo.pagerank()
        eng = engine.compile(prog, g, alloc, "coded", path="sparse")
        delta = _mk_delta(g, 0.01, rng)
        with obs.stopwatch() as sw_upd:
            eng2 = eng.update(delta)
        g2 = Graph(model=g.model, params=dict(g.params),
                   csr=g.csr.apply_delta(delta))
        want = engine.compile(prog, g2, alloc, "coded", path="sparse").run(4)
        got = eng2.run(4)
        assert np.array_equal(got.state, want.state)
        assert got.shuffle_bits == want.shuffle_bits
        report(f"scale_delta_pagerank_n{n}", sw_upd.s * 1e6,
               f"K={K} r={r} |delta|={len(delta)} engine.update == fresh "
               f"session, plan speedup={rows[-1]['speedup']:.1f}x (PR 9)")
    return {"n": n, "K": K, "r": r, "s_fresh": t_fresh, "rows": rows}


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]

    def _report(name, us, derived=""):
        print(f"{name},{us:.1f},{derived}", flush=True)

    run(_report, smoke=smoke)
