"""Real-dataset ingestion: edge-list loaders + normalization to CSR graphs.

Reads SNAP-style whitespace/comma-separated edge lists (`# `/`% ` comment
lines, one "u v" pair per line, arbitrary non-negative integer labels) and
normalizes them into the engine's undirected simple-graph contract:

  * every line is treated as one undirected edge (symmetrize),
  * self-loops dropped, duplicate edges (either orientation) deduped,
  * labels relabeled to a contiguous [0, n) range (ascending original id),
  * optionally restricted to the largest connected component,

then builds a CSR-native `Graph` - the dense [n, n] view is never touched,
so real datasets load at O(edges). `params["labels"]` maps each normalized
vertex id back to its original label.

A tiny committed real-world fixture (Zachary's karate club, with raw-format
noise: comments, duplicates, a self-loop, a detached component) lives at
`data/karate.edges` for tests and the CI benchmark smoke run.
"""
from __future__ import annotations

import pathlib

import numpy as np

from ..core.graph_models import Graph

__all__ = ["read_edge_list", "normalize_edges", "load_graph",
           "fixture_path", "load_fixture", "write_edge_list"]

FIXTURE_DIR = pathlib.Path(__file__).parent / "data"


def fixture_path(name: str = "karate") -> pathlib.Path:
    """Path of a committed fixture edge list (default: karate club)."""
    return FIXTURE_DIR / f"{name}.edges"


def read_edge_list(source, comments: tuple[str, ...] = ("#", "%"),
                   ) -> tuple[np.ndarray, np.ndarray]:
    """Raw (u, v) int64 label arrays from a path or an iterable of lines.

    Accepts whitespace- or comma-separated fields; extra per-line fields
    (weights, timestamps) are ignored. No normalization is applied.
    """
    if isinstance(source, (str, pathlib.Path)):
        with open(source) as f:
            return read_edge_list(list(f), comments)
    us: list[int] = []
    vs: list[int] = []
    for lineno, line in enumerate(source, 1):
        line = line.strip()
        if not line or line.startswith(comments):
            continue
        fields = line.replace(",", " ").split()
        if len(fields) < 2:
            raise ValueError(f"line {lineno}: need at least two fields, "
                             f"got {line!r}")
        us.append(int(fields[0]))
        vs.append(int(fields[1]))
    return np.asarray(us, dtype=np.int64), np.asarray(vs, dtype=np.int64)


def _components(u: np.ndarray, v: np.ndarray, n: int) -> np.ndarray:
    """[n] min-vertex-id component label per vertex (vectorized min-label
    propagation with pointer jumping; O(edges * log diameter) passes)."""
    comp = np.arange(n, dtype=np.int64)
    while True:
        prev = comp.copy()
        np.minimum.at(comp, u, comp[v])
        np.minimum.at(comp, v, comp[u])
        comp = np.minimum(comp, comp[comp])        # pointer jumping
        if np.array_equal(comp, prev):
            break
    while True:                                     # full compression
        nxt = comp[comp]
        if np.array_equal(nxt, comp):
            return comp
        comp = nxt


def normalize_edges(u: np.ndarray, v: np.ndarray, *,
                    largest_cc: bool = False,
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Normalize raw undirected edge labels; see the module docstring.

    Returns (u2, v2, labels): deduped canonical (u2 < v2) edges over the
    contiguous vertex range [0, labels.size), with labels[new_id] = original
    label (ascending, so relabeling is order-preserving).
    """
    u = np.asarray(u, dtype=np.int64)
    v = np.asarray(v, dtype=np.int64)
    lo, hi = np.minimum(u, v), np.maximum(u, v)    # symmetrize orientation
    keep = lo != hi                                 # strip self-loops
    lo, hi = lo[keep], hi[keep]
    labels, flat = np.unique(np.concatenate([lo, hi]), return_inverse=True)
    n = labels.size
    lo, hi = flat[:lo.size], flat[lo.size:]         # contiguous relabel
    pairs = np.unique(lo * n + hi)                  # dedup undirected pairs
    lo, hi = pairs // n, pairs % n
    if largest_cc:
        if n == 0:
            raise ValueError(
                "edge list has no edges after normalization (empty, "
                "comment-only, or self-loops only); cannot extract a "
                "largest connected component")
        comp = _components(lo, hi, n)
        roots, sizes = np.unique(comp, return_counts=True)
        big = roots[np.argmax(sizes)]
        keep_v = comp == big
        new_id = np.cumsum(keep_v) - 1
        sel = keep_v[lo]                            # == keep_v[hi]
        lo, hi = new_id[lo[sel]], new_id[hi[sel]]
        labels = labels[keep_v]
    return lo, hi, labels


def load_graph(source, *, largest_cc: bool = False, name: str | None = None,
               ) -> Graph:
    """Load + normalize an edge list into a CSR-native `Graph`.

    `params` records the provenance: original label map (`labels`), raw
    line/vertex counts, and whether the largest component was extracted.
    """
    u, v = read_edge_list(source)
    lo, hi, labels = normalize_edges(u, v, largest_cc=largest_cc)
    if name is None:
        name = (pathlib.Path(source).stem
                if isinstance(source, (str, pathlib.Path)) else "edges")
    return Graph.from_edges(lo, hi, labels.size, "real", {
        "name": name, "labels": labels, "raw_lines": int(u.size),
        "largest_cc": largest_cc})


def load_fixture(name: str = "karate", *, largest_cc: bool = True) -> Graph:
    """The committed real-world fixture graph, normalized (LCC by default:
    the raw file deliberately carries a detached noise component)."""
    return load_graph(fixture_path(name), largest_cc=largest_cc, name=name)


def write_edge_list(g: Graph, path, header: str | None = None) -> None:
    """Write one undirected edge per line in normalized vertex ids.

    The edge-list format carries edges only: isolated vertices (e.g.
    `Graph.padded` padding) and original labels are not representable, so
    a `load_graph` round-trip reproduces the CSR exactly iff every vertex
    has degree >= 1 (true for normalized largest-CC datasets); otherwise
    the reloaded graph is the edge-bearing subgraph, relabeled contiguous.
    """
    csr = g.csr
    upper = csr.rows < csr.indices
    with open(path, "w") as f:
        if header:
            for line in header.splitlines():
                f.write(f"# {line}\n")
        for i, j in zip(csr.rows[upper], csr.indices[upper]):
            f.write(f"{i} {j}\n")
