"""Pure-jnp oracle for the coded-Shuffle XOR packing.

Segments are carried as uint32 words (the fused TPU shuffle path codes whole
float32 values per lane rather than sub-word bit splits; see DESIGN.md §7.2 -
the value axis is pre-split into r lanes so the per-lane XOR is equivalent).
"""
import jax
import jax.numpy as jnp


def xor_encode(rows: jnp.ndarray, valid: jnp.ndarray) -> jnp.ndarray:
    """Column-wise XOR of the alignment table.

    rows:  [r, C, W] uint32 - row k = segments destined for receiver k.
    valid: [r, C] bool      - entry presence (rows are left-aligned, ragged).
    ->     [C, W] uint32 coded columns (absent entries contribute 0).
    """
    masked = jnp.where(valid[..., None], rows, jnp.uint32(0))
    return jax.lax.reduce(masked, jnp.uint32(0), jax.lax.bitwise_xor, (0,))


def xor_decode(coded: jnp.ndarray, known_rows: jnp.ndarray,
               known_valid: jnp.ndarray) -> jnp.ndarray:
    """Strip locally-known rows from the coded columns.

    coded:       [C, W] uint32 received columns.
    known_rows:  [r-1, C, W] uint32 segments the receiver Mapped itself.
    known_valid: [r-1, C] bool.
    ->           [C, W] uint32 - the receiver's own missing segments.
    """
    strip = xor_encode(known_rows, known_valid)
    return jnp.bitwise_xor(coded, strip)
